"""Ensemble batch axis: the batched lowering must be f64-identical to
``vmap`` of the single-member path across B × rank × strategy ×
fuse_steps (ISSUE acceptance sweep), the per-member traffic model must
reward batching, the ``:b{B}`` key component must separate cache
records per batch extent, and plan validation must reject the
unsupported batched-aux-temporal combination."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

jax.config.update("jax_enable_x64", True)

from repro.core.stencil import derivative_operator_set  # noqa: E402
from repro.core.trafficmodel import (  # noqa: E402
    stencil_batched_hbm_bytes_per_member_step,
)
from repro.kernels import ops as kops  # noqa: E402
from repro.kernels import ref  # noqa: E402
from repro.kernels.plan import plan_stencil, strategy_sid  # noqa: E402

DOMAINS = {1: (64,), 2: (12, 24), 3: (8, 10, 16)}
BLOCKS = {1: (32,), 2: (6, 12), 3: (3, 5, 8)}


def _problem(
    rank: int, batch: int, n_f: int = 2, fuse_steps: int = 1, seed: int = 0
):
    """Self-map problem (n_out == n_f), operand padded for
    ``fuse_steps`` fused sweeps (halo width r·S)."""
    opset = derivative_operator_set(rank, 2, spacing=0.4)
    names = ["dxx", "dyy", "dzz"][:rank]

    def phi(d):
        lap = sum(d[k] for k in names)
        return jnp.stack([d["val"][0] + 0.05 * lap[0],
                          d["val"][1] - 0.02 * lap[1]])

    rng = np.random.default_rng(seed)
    f = jnp.asarray(
        rng.standard_normal((batch, n_f) + DOMAINS[rank]), jnp.float64
    )
    h = opset.radius * fuse_steps
    pad = ((0, 0), (0, 0)) + ((h, h),) * rank
    fp = jnp.pad(f, pad, mode="wrap")
    return opset, phi, fp


# Streaming needs a non-lane axis, so swc_stream starts at rank 2.
SWEEP = [
    (batch, rank, strategy, fuse_steps)
    for batch in (1, 4, 8)
    for rank in (1, 2, 3)
    for strategy in ("swc", "swc_stream")
    for fuse_steps in (1, 2)
    if not (strategy == "swc_stream" and rank == 1)
]


@pytest.mark.parametrize("batch,rank,strategy,fuse_steps", SWEEP)
def test_batched_matches_vmap_of_single_member(
    batch, rank, strategy, fuse_steps
):
    opset, phi, fp = _problem(rank, batch, fuse_steps=fuse_steps)
    out = kops.fused_stencil_nd(
        fp, opset, phi, 2, strategy=strategy, block=BLOCKS[rank],
        fuse_steps=fuse_steps, interpret=True,
    )
    expect = jax.vmap(
        lambda f: kops.fused_stencil_nd(
            f, opset, phi, 2, strategy="hwc", fuse_steps=fuse_steps,
        )
    )(fp)
    assert out.shape == (batch, 2) + DOMAINS[rank]
    np.testing.assert_allclose(
        np.asarray(out), np.asarray(expect), rtol=0, atol=1e-12
    )


def test_batched_ref_oracle_is_vmap():
    opset, phi, fp = _problem(2, 4)
    got = ref.fused_stencil_batched(fp, opset, phi)
    expect = jax.vmap(lambda f: ref.fused_stencil(f, opset, phi))(fp)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(expect))
    got_s = ref.fused_stencil_steps_batched(fp, opset, phi, 3)
    expect_s = jax.vmap(
        lambda f: ref.fused_stencil_steps(f, opset, phi, 3)
    )(fp)
    np.testing.assert_array_equal(np.asarray(got_s), np.asarray(expect_s))


def test_batched_aux_depth1_matches_vmap():
    opset = derivative_operator_set(2, 2, spacing=0.4)

    def phi(d, aux):
        return jnp.stack([d["val"][0] + 0.05 * (d["dxx"] + d["dyy"])[0]
                          + aux[0]])

    rng = np.random.default_rng(3)
    f = jnp.asarray(rng.standard_normal((4, 1, 12, 24)), jnp.float64)
    aux = jnp.asarray(rng.standard_normal((4, 1, 12, 24)), jnp.float64)
    r = opset.radius
    fp = jnp.pad(f, ((0, 0), (0, 0), (r, r), (r, r)), mode="wrap")
    out = kops.fused_stencil_nd(
        fp, opset, phi, 1, aux=aux, strategy="swc", block=(6, 12),
        interpret=True,
    )
    expect = jax.vmap(
        lambda fm, am: kops.fused_stencil_nd(
            fm, opset, phi, 1, aux=am, strategy="hwc"
        )
    )(fp, aux)
    np.testing.assert_allclose(
        np.asarray(out), np.asarray(expect), rtol=0, atol=1e-12
    )


# --- traffic model --------------------------------------------------------------


def test_per_member_bytes_strictly_decrease_with_batch():
    """The batching argument (ISSUE motivation): launch overhead
    amortizes across members, so modeled HBM bytes per member strictly
    decrease from B=1 to B=8 — for a benchmarked (fig11-sized) shape,
    plain and streamed, fused and unfused."""
    for stream in (False, True):
        for fuse_steps in (1, 2):
            per_member = [
                stencil_batched_hbm_bytes_per_member_step(
                    (256, 512), (8, 128), (1, 1), 1, 1, 4,
                    batch=b, fuse_steps=fuse_steps, stream=stream,
                )
                for b in (1, 2, 4, 8)
            ]
            assert all(
                a > b for a, b in zip(per_member, per_member[1:])
            ), (stream, fuse_steps, per_member)


def test_batched_bytes_reduce_to_unbatched_plus_overhead():
    from repro.core.trafficmodel import (
        STENCIL_LAUNCH_OVERHEAD_BYTES,
        stencil_hbm_bytes_per_step,
    )

    base = stencil_hbm_bytes_per_step((64, 64), (8, 32), (1, 1), 2, 2, 4)
    b1 = stencil_batched_hbm_bytes_per_member_step(
        (64, 64), (8, 32), (1, 1), 2, 2, 4, batch=1
    )
    assert b1 == base + STENCIL_LAUNCH_OVERHEAD_BYTES


# --- keys and validation --------------------------------------------------------


def test_batch_joins_strategy_id_and_tuning_key():
    assert strategy_sid("swc", 2, batch=4) == "swc:b4"
    assert strategy_sid("swc", 2) == "swc"  # B=1 keys exactly as before
    assert strategy_sid("swc_stream", 3, fuse_steps=2, batch=8) == (
        "swc_stream:sz:f2:b8"
    )
    opset = derivative_operator_set(2, 2, spacing=0.4)
    plans = {
        b: plan_stencil(
            opset, (b, 2, 14, 26), 2, strategy="swc", block=(6, 12),
            dtype="float64", batch=b,
        )
        for b in (1, 4, 8)
    }
    keys = {b: p.tuning_key().cache_id for b, p in plans.items()}
    assert len(set(keys.values())) == 3  # one record per batch extent
    assert ":b4" in keys[4] and ":b8" in keys[8]
    assert ":b" not in keys[1]


def test_plan_infers_batch_from_operand_rank():
    opset = derivative_operator_set(2, 2, spacing=0.4)
    plan = plan_stencil(
        opset, (4, 2, 14, 26), 2, strategy="swc", block=(6, 12),
        dtype="float64",
    )
    assert plan.batch == 4 and plan.interior == (12, 24)
    with pytest.raises(ValueError):
        plan_stencil(
            opset, (4, 2, 14, 26), 2, strategy="swc", block=(6, 12),
            dtype="float64", batch=2,  # disagrees with the leading axis
        )


def test_plan_rejects_batched_aux_temporal():
    opset = derivative_operator_set(2, 2, spacing=0.4)
    with pytest.raises(ValueError, match="aux"):
        plan_stencil(
            opset, (4, 1, 14, 26), 1, strategy="swc", block=(6, 12),
            dtype="float64", n_aux=1, fuse_steps=2,
        )


def test_candidate_enumeration_depends_on_batch():
    """The batched VMEM working set scales with B, so a budget that
    admits large blocks at B=1 must prune them at B=8 — candidate
    selection genuinely depends on the batch extent."""
    from repro.tuning import enumerate_candidates_nd, vmem_working_set

    domain, radii = (64, 128), (1, 1)
    budget = 512 * 1024
    c1 = enumerate_candidates_nd(
        domain, radii, n_f=4, n_out=4, itemsize=4, vmem_budget=budget
    )
    c8 = enumerate_candidates_nd(
        domain, radii, n_f=4, n_out=4, itemsize=4, vmem_budget=budget,
        batch=8,
    )
    assert c1 and c8
    blocks1 = {c.block for c in c1 if c.block is not None}
    blocks8 = {c.block for c in c8 if c.block is not None}
    assert blocks8 < blocks1  # batch-scaled VMEM prunes the big blocks
    assert all(
        c.vmem_bytes == vmem_working_set(
            c.block, radii, 4, 4, 4, batch=8
        )
        for c in c8 if c.block is not None
    )
