"""Generalized-operator acceptance suite: MMS convergence slopes,
weight-generator properties, and golden parity with the hardwired
order-6 operators.

Three layers, mirroring the pipeline the accuracy axis flows through:

* property tests on the Fornberg weight generators (polynomial
  exactness, zero-sum, parity symmetry, odd-accuracy rejection) —
  the weights themselves;
* golden-parity regressions pinning the generated order-6 weights to
  the literal textbook coefficients and the generated φ sequences to a
  hand-built operator set through every caching regime × depth ×
  batch — the lowering;
* MMS convergence sweeps (``repro.verify.mms``) fitting observed
  error slopes at every order × rank × boundary family — the whole
  pad → plan → emit → φ pipeline, where ANY systematic defect bends
  the slope away from nominal.

Slope bounds: f64 sweeps must land within 0.25 BELOW nominal (the
acceptance criterion); the upper bound is generous (+1.2) because
Dirichlet offset-row sweeps superconverge pre-asymptotically (observed
+0.45 … +0.61, approaching nominal from above under refinement). f32
is checked at orders 2 and 4 on grids coarse enough that truncation
dominates the f32 roundoff floor (which GROWS as h shrinks — the
relative error of a second derivative floors at ~eps/h²), and order 8
under a loosened absolute-error criterion: at f32, order-8 truncation
error drops below roundoff on any grid large enough to fit the
stencil, so no slope is observable and the gate is the error floor
itself.
"""
import jax

jax.config.update("jax_enable_x64", True)

import math  # noqa: E402
import os  # noqa: E402
import subprocess  # noqa: E402
import sys  # noqa: E402
from pathlib import Path  # noqa: E402

import jax.numpy as jnp  # noqa: E402
import numpy as np  # noqa: E402
import pytest  # noqa: E402

try:
    import hypothesis.strategies as st
    from hypothesis import given, settings
except ImportError:  # pragma: no cover - exercised on bare containers
    from _minihypothesis import given, settings
    from _minihypothesis import strategies as st

from repro.core.fusion import FusedStencilOp  # noqa: E402
from repro.core.stencil import (  # noqa: E402
    OperatorSet,
    StencilSpec,
    central_difference_coeffs,
    identity_stencil,
    laplacian_stencil,
    offset_difference_coeffs,
)
from repro.kernels.plan import (  # noqa: E402
    DEFAULT_ACCURACY,
    plan_stencil,
    strategy_sid,
)
from repro.verify.mms import fit_slope, run_convergence  # noqa: E402

SRC = str(Path(__file__).resolve().parent.parent / "src")


@pytest.fixture
def cache_dir(tmp_path, monkeypatch):
    monkeypatch.setenv("REPRO_TUNE_CACHE", str(tmp_path))
    return tmp_path


# --- weight-generator properties ----------------------------------------------


def _assert_polynomial_exact(w: np.ndarray, offsets: np.ndarray, deriv: int):
    """An npts-point interpolatory derivative rule is exact on every
    polynomial of degree < npts: Σ w_k k^p = (d/dx)^m x^p |_0, which is
    m! at p = m and 0 otherwise."""
    for p in range(len(w)):
        terms = w * offsets.astype(float) ** p
        want = float(math.factorial(deriv)) if p == deriv else 0.0
        # Tolerance scales with the cancellation magnitude of the sum.
        tol = 1e-10 * max(1.0, float(np.abs(terms).sum()))
        assert abs(float(terms.sum()) - want) < tol, (deriv, p)


@given(deriv=st.integers(1, 4), accuracy=st.sampled_from([2, 4, 6, 8]))
@settings(max_examples=32, deadline=None)
def test_central_weights_polynomial_exactness(deriv, accuracy):
    w = np.asarray(central_difference_coeffs(deriv, accuracy))
    r = (len(w) - 1) // 2
    _assert_polynomial_exact(w, np.arange(-r, r + 1), deriv)


@given(
    deriv=st.integers(1, 3),
    accuracy=st.sampled_from([2, 4, 6, 8]),
    seat=st.integers(0, 10_000),
)
@settings(max_examples=32, deadline=None)
def test_offset_weights_polynomial_exactness(deriv, accuracy, seat):
    npts = deriv + accuracy
    left = seat % npts  # any seat of the evaluation point in the window
    w = np.asarray(offset_difference_coeffs(deriv, accuracy, left))
    assert len(w) == npts
    _assert_polynomial_exact(w, np.arange(-left, npts - left), deriv)


@given(deriv=st.integers(1, 4), accuracy=st.sampled_from([2, 4, 6, 8]))
@settings(max_examples=32, deadline=None)
def test_weights_sum_to_zero_for_derivatives(deriv, accuracy):
    # p = 0 exactness, stated on its own: a derivative annihilates
    # constants, so every weight row sums to zero.
    w = np.asarray(central_difference_coeffs(deriv, accuracy))
    assert abs(float(w.sum())) < 1e-10 * float(np.abs(w).sum())
    wo = np.asarray(offset_difference_coeffs(deriv, accuracy, 0))
    assert abs(float(wo.sum())) < 1e-10 * float(np.abs(wo).sum())


@given(deriv=st.integers(1, 4), accuracy=st.sampled_from([2, 4, 6, 8]))
@settings(max_examples=32, deadline=None)
def test_central_weights_parity(deriv, accuracy):
    # Central stencils inherit the derivative's parity: even derivatives
    # are symmetric, odd antisymmetric (center weight exactly zero).
    w = np.asarray(central_difference_coeffs(deriv, accuracy))
    sign = 1.0 if deriv % 2 == 0 else -1.0
    np.testing.assert_allclose(w[::-1], sign * w, rtol=0, atol=1e-14)
    if deriv % 2 == 1:
        assert w[len(w) // 2] == 0.0


@pytest.mark.parametrize("accuracy", [1, 3, 5, 7])
def test_odd_accuracy_rejected(accuracy):
    with pytest.raises(ValueError):
        central_difference_coeffs(2, accuracy)
    with pytest.raises(ValueError):
        offset_difference_coeffs(1, accuracy, 0)


def test_negative_offset_seat_rejected():
    with pytest.raises(ValueError):
        offset_difference_coeffs(1, 4, -1)


# --- golden parity with the hardwired order-6 operators -----------------------

# The literal order-6 central coefficients the repo's operators were
# originally hardwired with (and every FD reference tabulates).
GOLDEN_O6_D1 = (-1 / 60, 3 / 20, -3 / 4, 0.0, 3 / 4, -3 / 20, 1 / 60)
GOLDEN_O6_D2 = (1 / 90, -3 / 20, 3 / 2, -49 / 18, 3 / 2, -3 / 20, 1 / 90)


def test_generated_weights_match_hardwired_order6():
    np.testing.assert_allclose(
        central_difference_coeffs(1, 6), GOLDEN_O6_D1, rtol=0, atol=1e-12
    )
    np.testing.assert_allclose(
        central_difference_coeffs(2, 6), GOLDEN_O6_D2, rtol=0, atol=1e-12
    )


def _golden_laplacian(rank: int, spacing: float) -> StencilSpec:
    """Hand-built order-6 Laplacian from the literal coefficients —
    deliberately NO OperatorSpec metadata, so this set can only go
    through the ordinary tap pipeline."""
    taps: dict[tuple[int, ...], float] = {}
    scale = spacing**-2
    for a in range(rank):
        for k, w in zip(range(-3, 4), GOLDEN_O6_D2):
            off = [0] * rank
            off[a] = k
            o = tuple(off)
            taps[o] = taps.get(o, 0.0) + w * scale
    items = sorted(taps.items())
    return StencilSpec(
        tuple(o for o, _ in items), tuple(c for _, c in items), name="lap"
    )


@pytest.mark.parametrize("fuse_steps", [1, 2])
@pytest.mark.parametrize("strategy", ["hwc", "swc", "swc_stream", "tc"])
def test_generated_phi_matches_golden_order6(strategy, fuse_steps):
    """The generated accuracy-6 tap sequences must reproduce the
    hardwired operators through the SAME lowering — every caching
    regime, fused depth 1 and 2, unbatched and batched."""
    h = 0.37
    dtype = jnp.float32 if strategy == "tc" else jnp.float64
    gen = OperatorSet(
        (identity_stencil(2), laplacian_stencil(2, 6, spacing=h))
    )
    gold = OperatorSet((identity_stencil(2), _golden_laplacian(2, h)))

    def phi(d):
        return d["val"] + 1e-3 * d["lap"]

    rng = np.random.default_rng(11)
    f = jnp.asarray(rng.standard_normal((1, 16, 32)), dtype)
    fb = jnp.asarray(rng.standard_normal((2, 1, 16, 32)), dtype)
    for x in (f, fb):
        out_gen = FusedStencilOp(
            gen, phi, 1, strategy=strategy, fuse_steps=fuse_steps
        )(x)
        out_gold = FusedStencilOp(
            gold, phi, 1, strategy=strategy, fuse_steps=fuse_steps
        )(x)
        if strategy == "tc":
            # Weight-level parity is pinned at 1e-12 above; after the
            # f32 cast the two coefficient sets are bit-identical, so
            # the MXU outputs agree to f32 resolution.
            np.testing.assert_allclose(
                np.asarray(out_gen), np.asarray(out_gold),
                rtol=0, atol=2e-6,
            )
        else:
            np.testing.assert_allclose(
                np.asarray(out_gen), np.asarray(out_gold),
                rtol=0, atol=1e-12,
            )


# --- accuracy as a cache-key axis ---------------------------------------------


def test_strategy_sid_accuracy_axis():
    # Non-default orders append :o{A} as the final suffix; the paper
    # default (6) and "unknown" (0) keep the legacy unmarked form so
    # every pre-existing cache record stays valid.
    assert strategy_sid("swc", 3, accuracy=4) == "swc:o4"
    assert strategy_sid("swc", 3, accuracy=6) == "swc"
    assert strategy_sid("swc", 3, accuracy=0) == "swc"
    assert (
        strategy_sid("swc_stream", 3, fuse_steps=2, accuracy=8)
        == "swc_stream:sz:f2:o8"
    )
    sids = {strategy_sid("swc", 3, accuracy=a) for a in (0, 2, 4, 6, 8)}
    assert len(sids) == 4  # 0 and 6 alias by design; 2/4/8 distinct


def test_plan_keys_distinguish_orders():
    ids = set()
    for acc in (2, 4, 6, 8):
        ops = OperatorSet((laplacian_stencil(2, acc, spacing=0.5),))
        r = ops.radius_per_axis()
        padded = (1, 16 + 2 * r[0], 32 + 2 * r[1])
        plan = plan_stencil(ops, padded, 1)
        assert plan.accuracy == acc
        if acc == DEFAULT_ACCURACY:
            assert ":o" not in plan.strategy_id
        else:
            assert plan.strategy_id.endswith(f":o{acc}")
        ids.add(plan.strategy_id)
    assert len(ids) == 4


def test_order4_tuning_roundtrip_cold_warm_subprocess(cache_dir):
    """block='auto' on a non-default-order opset: the cold call
    measures and persists under an :o4 key, a warm call replays it
    with zero new measurements, and a FRESH PROCESS replays the same
    record (key stability across processes) — while an order-6 op on
    the same domain never collides with it."""
    from repro.tuning import TuningCache
    from repro.tuning import session as sess_mod

    h = 0.25
    rng = np.random.default_rng(5)
    f = jnp.asarray(rng.standard_normal((1, 16, 32)), jnp.float32)

    def phi(d):
        return d["val"] + 1e-3 * d["lap"]

    def op_at(acc):
        ops = OperatorSet(
            (identity_stencil(2), laplacian_stencil(2, acc, spacing=h))
        )
        return FusedStencilOp(ops, phi, 1, strategy="swc", block="auto")

    out_cold = op_at(4)(f)
    keys = list(TuningCache().items())
    assert any(":o4" in k for k in keys), keys

    before = sess_mod.MEASURE_COUNT
    out_warm = op_at(4)(f)
    assert sess_mod.MEASURE_COUNT == before  # warm hit: no re-measure
    np.testing.assert_array_equal(np.asarray(out_cold), np.asarray(out_warm))

    # Same domain at the default order must MISS the :o4 record (and
    # measure afresh) — the orders never share a key.
    op_at(6)(f)
    assert sess_mod.MEASURE_COUNT > before
    keys = list(TuningCache().items())
    assert any(":o4" in k for k in keys)
    assert any(":o4" not in k and "swc" in k for k in keys)

    code = f"""
import numpy as np
import jax.numpy as jnp
from repro.core.fusion import FusedStencilOp
from repro.core.stencil import OperatorSet, identity_stencil, laplacian_stencil
from repro.tuning import session as sess_mod

ops = OperatorSet(
    (identity_stencil(2), laplacian_stencil(2, 4, spacing={h}))
)
rng = np.random.default_rng(5)
f = jnp.asarray(rng.standard_normal((1, 16, 32)), jnp.float32)
out = FusedStencilOp(
    ops, lambda d: d["val"] + 1e-3 * d["lap"], 1,
    strategy="swc", block="auto",
)(f)
assert sess_mod.MEASURE_COUNT == 0, sess_mod.MEASURE_COUNT
print("REUSED_OK")
"""
    env = dict(os.environ)
    env["REPRO_TUNE_CACHE"] = str(cache_dir)
    env["PYTHONPATH"] = SRC + os.pathsep + env.get("PYTHONPATH", "")
    out = subprocess.run(
        [sys.executable, "-c", code], capture_output=True, text=True, env=env
    )
    assert out.returncode == 0, out.stderr
    assert "REUSED_OK" in out.stdout


# --- MMS convergence slopes ---------------------------------------------------

SLOPE_DEFICIT = 0.25  # acceptance: observed order within 0.25 of nominal
SLOPE_EXCESS = 1.2  # Dirichlet offset rows superconverge pre-asymptotically


@pytest.mark.parametrize("boundary", ["periodic", "dirichlet"])
@pytest.mark.parametrize("rank", [1, 2, 3])
@pytest.mark.parametrize("accuracy", [2, 4, 8])
def test_mms_slope_f64(accuracy, rank, boundary):
    res = run_convergence(rank, accuracy, boundary)
    assert res.slope >= accuracy - SLOPE_DEFICIT, res
    assert res.slope <= accuracy + SLOPE_EXCESS, res


@pytest.mark.parametrize("boundary", ["periodic", "dirichlet"])
@pytest.mark.parametrize("rank", [1, 2])
def test_mms_slope_f32_order2(rank, boundary):
    # f32 needs grids coarse enough that truncation error dominates the
    # roundoff floor (~eps/h² relative, GROWING under refinement).
    ns = (8, 12, 16, 24) if boundary == "dirichlet" else None
    res = run_convergence(rank, 2, boundary, dtype="float32", ns=ns)
    assert res.slope >= 2 - SLOPE_DEFICIT, res
    assert res.slope <= 2 + SLOPE_EXCESS, res


@pytest.mark.parametrize("boundary", ["periodic", "dirichlet"])
@pytest.mark.parametrize("rank", [1, 2])
def test_mms_slope_f32_order4(rank, boundary):
    res = run_convergence(
        rank, 4, boundary, dtype="float32", ns=(8, 12, 16)
    )
    assert res.slope >= 4 - SLOPE_DEFICIT, res
    assert res.slope <= 4 + SLOPE_EXCESS, res


@pytest.mark.parametrize("boundary", ["periodic", "dirichlet"])
def test_mms_f32_order8_error_floor(boundary):
    # The loosened order-8 f32 criterion: truncation falls below the
    # f32 roundoff floor on every stencil-sized grid, so no slope is
    # observable — the gate is the floor itself staying small.
    res = run_convergence(1, 8, boundary, dtype="float32")
    assert max(res.errors) <= 2e-3, res


def test_mms_neumann_ghost_fill_order_gap():
    # The satellite regression: edge-replicate "neumann" is a 1st-order
    # ghost fill and caps the observed slope near 0.5; the
    # mirror-about-node "neumann2" even extension releases the interior
    # order for the zero-gradient manufactured field.
    lo = run_convergence(1, 6, "neumann")
    hi = run_convergence(1, 6, "neumann2")
    assert lo.slope < 1.2, lo
    assert hi.slope > 4.0, hi
    assert hi.slope - lo.slope > 2.0


def test_mms_slope_strategy_invariant():
    # The slope is a property of the weights, not the lowering: the
    # software-cached regime must reproduce the hwc-measured order.
    res = run_convergence(2, 4, "periodic", strategy="swc")
    assert res.slope >= 4 - SLOPE_DEFICIT, res


def test_fit_slope_drops_exact_zeros():
    assert fit_slope([0.1, 0.05], [1e-2, 0.0]) == float("inf")
    s = fit_slope([0.1, 0.05, 0.025], [1e-2, 2.5e-3, 6.25e-4])
    assert abs(s - 2.0) < 1e-9
