"""Deterministic fallback for the tiny slice of the ``hypothesis`` API
that ``tests/test_kernel_properties.py`` uses.

``hypothesis`` belongs to the ``test``/``dev`` extras and is what CI
installs — but on a bare interpreter the property tests used to be
skipped wholesale (``pytest.importorskip``), which meant the stencil
invariants (linearity, shift equivariance, fusion equivalence,
causality) were silently unexercised exactly where people run
``pytest`` casually. This shim keeps them RUNNING everywhere: seeded
random sampling over the same strategies, no shrinking or
coverage-guided search (install real hypothesis for that).

Implemented subset: ``strategies.integers``, ``strategies.sampled_from``,
``@given(**kwargs)``, ``@settings(max_examples=…, deadline=…)``. The
draw sequence is seeded per test name, so failures reproduce.
"""
from __future__ import annotations

import random
import zlib


class _Strategy:
    """A draw rule: ``draw(rng) -> value``."""

    def __init__(self, draw):
        self.draw = draw


class strategies:
    """Namespace mirroring ``hypothesis.strategies`` (import as st)."""

    @staticmethod
    def integers(min_value: int, max_value: int) -> _Strategy:
        return _Strategy(lambda rng: rng.randint(min_value, max_value))

    @staticmethod
    def sampled_from(seq) -> _Strategy:
        items = list(seq)
        return _Strategy(lambda rng: rng.choice(items))


def settings(max_examples: int | None = None, deadline=None, **_ignored):
    """Record ``max_examples`` on the (already ``given``-wrapped)
    test; ``deadline`` and anything else is accepted and ignored."""

    def deco(fn):
        if max_examples is not None:
            fn._mh_max_examples = max_examples
        return fn

    return deco


def given(**strats):
    """Run the test once per drawn example (seeded, deterministic)."""

    def deco(fn):
        def wrapper():
            n = getattr(wrapper, "_mh_max_examples", 20)
            seed = zlib.adler32(fn.__qualname__.encode())
            rng = random.Random(seed)
            for _ in range(n):
                drawn = {k: s.draw(rng) for k, s in strats.items()}
                fn(**drawn)

        # NOT functools.wraps: copying __wrapped__ would make pytest
        # introspect the original signature and demand the drawn
        # parameters as fixtures. The wrapper is deliberately 0-ary.
        wrapper.__name__ = fn.__name__
        wrapper.__qualname__ = fn.__qualname__
        wrapper.__doc__ = fn.__doc__
        wrapper.__module__ = fn.__module__
        return wrapper

    return deco
