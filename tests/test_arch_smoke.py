"""Per-architecture smoke tests: REDUCED config of the same family, one
forward + one train (loss+grad) step on CPU, asserting shapes + no NaNs
(assignment requirement f)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.registry import (
    ARCH_IDS,
    get_config,
    get_model,
    reduced_config,
)

BATCH, SEQ = 2, 32


def make_batch(cfg, key):
    tokens = jax.random.randint(key, (BATCH, SEQ), 0, cfg.vocab)
    batch = {"tokens": tokens, "labels": jnp.roll(tokens, -1, axis=1)}
    if cfg.is_encdec:
        batch["tokens"] = tokens[:, : cfg.max_target_len]
        batch["labels"] = jnp.roll(batch["tokens"], -1, axis=1)
        batch["frames"] = jax.random.normal(
            key, (BATCH, cfg.encoder_seq, cfg.d_model)
        )
    if cfg.family == "vlm":
        batch["patch_embeds"] = jax.random.normal(
            key, (BATCH, cfg.n_patches, cfg.d_model)
        )
    return batch


def finite(tree) -> bool:
    return all(
        np.isfinite(np.asarray(x, dtype=np.float32)).all()
        for x in jax.tree_util.tree_leaves(tree)
    )


@pytest.mark.parametrize("arch_id", ARCH_IDS)
def test_forward_and_train_step(arch_id):
    cfg = reduced_config(get_config(arch_id))
    api = get_model(cfg)
    key = jax.random.PRNGKey(0)
    params = api.init_params(cfg, key)
    batch = make_batch(cfg, key)

    loss, metrics = api.lm_loss(params, cfg, batch)
    assert loss.shape == ()
    assert np.isfinite(float(loss)), f"{arch_id}: non-finite loss"
    # Untrained model ≈ uniform over the vocab.
    assert float(loss) < np.log(cfg.vocab) + 3.0

    grads = jax.grad(lambda p: api.lm_loss(p, cfg, batch)[0])(params)
    assert finite(grads), f"{arch_id}: non-finite grads"
    # Gradients must reach the embedding table.
    gsum = float(jnp.sum(jnp.abs(grads["embed"].astype(jnp.float32))))
    assert gsum > 0.0, f"{arch_id}: zero embed grads"


@pytest.mark.parametrize(
    "arch_id", [a for a in ARCH_IDS if a != "whisper-small"]
)
def test_decode_step(arch_id):
    cfg = reduced_config(get_config(arch_id))
    api = get_model(cfg)
    key = jax.random.PRNGKey(1)
    params = api.init_params(cfg, key)
    tokens = jax.random.randint(key, (BATCH, 1), 0, cfg.vocab)
    cache = api.init_decode_cache(cfg, BATCH, 64)
    logits, cache = api.decode_step(params, cfg, tokens, cache)
    assert logits.shape == (BATCH, cfg.vocab)
    assert np.isfinite(np.asarray(logits, dtype=np.float32)).all()
    assert int(cache.length) == 1


def test_whisper_decode():
    from repro.models import encdec

    cfg = reduced_config(get_config("whisper-small"))
    key = jax.random.PRNGKey(2)
    params = encdec.init_params(cfg, key)
    frames = jax.random.normal(key, (BATCH, cfg.encoder_seq, cfg.d_model))
    enc = encdec.encode(params, cfg, frames)
    cache = encdec.init_decode_cache(params, cfg, enc)
    tokens = jax.random.randint(key, (BATCH, 1), 0, cfg.vocab)
    logits, cache = encdec.decode_step(params, cfg, tokens, cache)
    assert logits.shape == (BATCH, cfg.vocab)
    assert np.isfinite(np.asarray(logits, dtype=np.float32)).all()


@pytest.mark.parametrize("arch_id", ["mixtral-8x7b", "mamba2-780m",
                                     "recurrentgemma-9b"])
def test_long_context_decode_is_bounded(arch_id):
    """long_500k archs: decode cache memory must not scale with context."""
    cfg = reduced_config(get_config(arch_id))
    api = get_model(cfg)
    small = api.init_decode_cache(cfg, 1, 64)
    huge = api.init_decode_cache(cfg, 1, 524288)
    size = lambda c: sum(  # noqa: E731
        np.prod(x.shape) for x in jax.tree_util.tree_leaves(c)
    )
    assert size(huge) == size(small), f"{arch_id}: cache grows with context"
