"""Per-kernel allclose sweeps (shapes × dtypes) against the pure-jnp
oracles in kernels/ref.py (assignment requirement c).

The hypothesis property tests live in ``test_kernel_properties.py``,
which skips itself via ``pytest.importorskip`` when ``hypothesis`` (a
test extra) is absent — this module collects and runs on a bare
interpreter.
"""
import jax

jax.config.update("jax_enable_x64", True)

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.stencil import derivative_operator_set
from repro.kernels import ops, ref
# Kernel tests exercise the legacy 1-D entry point directly by design.
from repro.kernels.stencil1d import xcorr1d_pallas  # repolint: allow[legacy-kernel-import]

RNG = np.random.default_rng(42)


def _phi_test(d):
    lap = d["dxx"] + d["dyy"] + d["dzz"]
    o0 = d["val"][0] + 0.1 * lap[0] + d["dx"][1] * d["dy"][0]
    o1 = jnp.tanh(d["val"][1]) + d["dxy"][0] + d["dz"][1] * d["dxz"][0]
    return jnp.stack([o0, o1])


# --- 1-D cross-correlation sweeps ---------------------------------------------


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.float64])
@pytest.mark.parametrize("radius", [0, 1, 5, 32, 200])
@pytest.mark.parametrize(
    "strategy,unroll",
    [("baseline", 1), ("pointwise", 4), ("pointwise", 7), ("elementwise", 4)],
)
def test_xcorr1d_sweep(dtype, radius, strategy, unroll):
    n = 2048
    f = jnp.asarray(RNG.standard_normal(n + 2 * radius), dtype)
    g = jnp.asarray(RNG.standard_normal(2 * radius + 1), dtype)
    out = xcorr1d_pallas(
        f, g, strategy=strategy, block_size=512, unroll=unroll,
        interpret=True,
    )
    expect = ref.xcorr1d(f, g)
    tol = 1e-4 if dtype == jnp.float32 else 1e-10
    np.testing.assert_allclose(
        np.asarray(out), np.asarray(expect), rtol=tol, atol=tol * 10
    )


def test_xcorr1d_nondivisible_n():
    f = jnp.asarray(RNG.standard_normal(1000 + 6), jnp.float32)
    g = jnp.asarray(RNG.standard_normal(7), jnp.float32)
    out = ops.xcorr1d(f, g, strategy="baseline", block_size=256,
                      interpret=True)
    np.testing.assert_allclose(
        np.asarray(out), np.asarray(ref.xcorr1d(f, g)), rtol=1e-4, atol=1e-4
    )


# --- fused 3-D kernel sweeps ---------------------------------------------------


@pytest.mark.parametrize("strategy", ["swc", "swc_stream"])
@pytest.mark.parametrize("accuracy", [2, 4, 6])
@pytest.mark.parametrize("block", [(4, 4, 8), (8, 8, 16), (2, 8, 16)])
def test_fused3d_sweep(strategy, accuracy, block):
    opset = derivative_operator_set(3, accuracy, spacing=0.2)
    r = opset.radius
    n_f, nz, ny, nx = 3, 8, 8, 16
    f = jnp.asarray(
        RNG.standard_normal((n_f, nz + 2 * r, ny + 2 * r, nx + 2 * r)),
        jnp.float32,
    )
    out = ops.fused_stencil_nd(
        f, opset, _phi_test, 2, block=block, strategy=strategy,
        interpret=True,
    )
    expect = ref.fused_stencil(f, opset, _phi_test)
    np.testing.assert_allclose(
        np.asarray(out), np.asarray(expect), rtol=2e-3, atol=2e-3
    )


def test_fused3d_aux_inputs():
    opset = derivative_operator_set(3, 4, spacing=0.3)
    r = opset.radius
    f = jnp.asarray(RNG.standard_normal((2, 8 + 2 * r, 8 + 2 * r, 16 + 2 * r)),
                    jnp.float32)
    aux = jnp.asarray(RNG.standard_normal((2, 8, 8, 16)), jnp.float32)

    def phi(d, a):
        return d["val"] * 0.5 + a * d["dxx"]

    out = ops.fused_stencil_nd(
        f, opset, phi, 2, aux=aux, block=(4, 4, 8), strategy="swc",
        interpret=True,
    )
    expect = ref.fused_stencil(f, opset, phi, aux=aux)
    np.testing.assert_allclose(
        np.asarray(out), np.asarray(expect), rtol=1e-4, atol=1e-4
    )


# --- depthwise conv sweeps ------------------------------------------------------


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("bsck", [(1, 64, 8, 4), (3, 100, 16, 4),
                                  (2, 257, 32, 7)])
def test_conv1d_depthwise_sweep(dtype, bsck):
    b, s, c, k = bsck
    x = jnp.asarray(RNG.standard_normal((b, s, c)), dtype)
    w = jnp.asarray(RNG.standard_normal((k, c)), dtype)
    out = ops.conv1d_depthwise(x, w, interpret=True, block_seq=128)
    expect = ref.conv1d_depthwise_causal(x, w)
    tol = 2e-2 if dtype == jnp.bfloat16 else 1e-5
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(expect, np.float32),
        rtol=tol, atol=tol,
    )
