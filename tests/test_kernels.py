"""Per-kernel allclose sweeps (shapes × dtypes) against the pure-jnp
oracles in kernels/ref.py, plus hypothesis property tests on the
stencil-engine invariants (assignment requirement c)."""
import jax

jax.config.update("jax_enable_x64", True)

import hypothesis
import hypothesis.strategies as st
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings

from repro.core.stencil import derivative_operator_set
from repro.kernels import ops, ref
from repro.kernels.stencil1d import xcorr1d_pallas
from repro.kernels.stencil3d import fused_stencil3d_pallas

RNG = np.random.default_rng(42)


def _phi_test(d):
    lap = d["dxx"] + d["dyy"] + d["dzz"]
    o0 = d["val"][0] + 0.1 * lap[0] + d["dx"][1] * d["dy"][0]
    o1 = jnp.tanh(d["val"][1]) + d["dxy"][0] + d["dz"][1] * d["dxz"][0]
    return jnp.stack([o0, o1])


# --- 1-D cross-correlation sweeps ---------------------------------------------


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.float64])
@pytest.mark.parametrize("radius", [0, 1, 5, 32, 200])
@pytest.mark.parametrize(
    "strategy,unroll",
    [("baseline", 1), ("pointwise", 4), ("pointwise", 7), ("elementwise", 4)],
)
def test_xcorr1d_sweep(dtype, radius, strategy, unroll):
    n = 2048
    f = jnp.asarray(RNG.standard_normal(n + 2 * radius), dtype)
    g = jnp.asarray(RNG.standard_normal(2 * radius + 1), dtype)
    out = xcorr1d_pallas(
        f, g, strategy=strategy, block_size=512, unroll=unroll,
        interpret=True,
    )
    expect = ref.xcorr1d(f, g)
    tol = 1e-4 if dtype == jnp.float32 else 1e-10
    np.testing.assert_allclose(
        np.asarray(out), np.asarray(expect), rtol=tol, atol=tol * 10
    )


def test_xcorr1d_nondivisible_n():
    f = jnp.asarray(RNG.standard_normal(1000 + 6), jnp.float32)
    g = jnp.asarray(RNG.standard_normal(7), jnp.float32)
    out = ops.xcorr1d(f, g, strategy="baseline", block_size=256,
                      interpret=True)
    np.testing.assert_allclose(
        np.asarray(out), np.asarray(ref.xcorr1d(f, g)), rtol=1e-4, atol=1e-4
    )


# --- fused 3-D kernel sweeps ---------------------------------------------------


@pytest.mark.parametrize("strategy", ["swc", "swc_stream"])
@pytest.mark.parametrize("accuracy", [2, 4, 6])
@pytest.mark.parametrize("block", [(4, 4, 8), (8, 8, 16), (2, 8, 16)])
def test_fused3d_sweep(strategy, accuracy, block):
    opset = derivative_operator_set(3, accuracy, spacing=0.2)
    r = opset.radius
    n_f, nz, ny, nx = 3, 8, 8, 16
    f = jnp.asarray(
        RNG.standard_normal((n_f, nz + 2 * r, ny + 2 * r, nx + 2 * r)),
        jnp.float32,
    )
    out = fused_stencil3d_pallas(
        f, opset, _phi_test, 2, block=block, strategy=strategy,
        interpret=True,
    )
    expect = ref.fused_stencil(f, opset, _phi_test)
    np.testing.assert_allclose(
        np.asarray(out), np.asarray(expect), rtol=2e-3, atol=2e-3
    )


def test_fused3d_aux_inputs():
    opset = derivative_operator_set(3, 4, spacing=0.3)
    r = opset.radius
    f = jnp.asarray(RNG.standard_normal((2, 8 + 2 * r, 8 + 2 * r, 16 + 2 * r)),
                    jnp.float32)
    aux = jnp.asarray(RNG.standard_normal((2, 8, 8, 16)), jnp.float32)

    def phi(d, a):
        return d["val"] * 0.5 + a * d["dxx"]

    out = fused_stencil3d_pallas(
        f, opset, phi, 2, aux=aux, block=(4, 4, 8), strategy="swc",
        interpret=True,
    )
    expect = ref.fused_stencil(f, opset, phi, aux=aux)
    np.testing.assert_allclose(
        np.asarray(out), np.asarray(expect), rtol=1e-4, atol=1e-4
    )


# --- depthwise conv sweeps ------------------------------------------------------


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("bsck", [(1, 64, 8, 4), (3, 100, 16, 4),
                                  (2, 257, 32, 7)])
def test_conv1d_depthwise_sweep(dtype, bsck):
    b, s, c, k = bsck
    x = jnp.asarray(RNG.standard_normal((b, s, c)), dtype)
    w = jnp.asarray(RNG.standard_normal((k, c)), dtype)
    out = ops.conv1d_depthwise(x, w, interpret=True, block_seq=128)
    expect = ref.conv1d_depthwise_causal(x, w)
    tol = 2e-2 if dtype == jnp.bfloat16 else 1e-5
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(expect, np.float32),
        rtol=tol, atol=tol,
    )


# --- hypothesis property tests --------------------------------------------------


@settings(max_examples=25, deadline=None)
@given(
    r=st.integers(0, 8),
    n=st.integers(16, 128),
    seed=st.integers(0, 2**31 - 1),
)
def test_xcorr_linearity(r, n, seed):
    """ζ is linear: ζ(αf + βh) = αζ(f) + βζ(h) (paper Sec. 2.4)."""
    rng = np.random.default_rng(seed)
    f = rng.standard_normal(n + 2 * r)
    h = rng.standard_normal(n + 2 * r)
    g = rng.standard_normal(2 * r + 1)
    a, b = rng.standard_normal(2)
    lhs = ref.xcorr1d_numpy(a * f + b * h, g)
    rhs = a * ref.xcorr1d_numpy(f, g) + b * ref.xcorr1d_numpy(h, g)
    np.testing.assert_allclose(lhs, rhs, rtol=1e-9, atol=1e-9)


@settings(max_examples=25, deadline=None)
@given(
    r=st.integers(1, 6),
    shift=st.integers(1, 5),
    seed=st.integers(0, 2**31 - 1),
)
def test_xcorr_shift_equivariance(r, shift, seed):
    """Stencils commute with translation on a periodic domain."""
    rng = np.random.default_rng(seed)
    n = 64
    f = rng.standard_normal(n)
    g = rng.standard_normal(2 * r + 1)

    def apply(fv):
        fp = np.concatenate([fv[-r:], fv, fv[:r]])
        return ref.xcorr1d_numpy(fp, g)

    np.testing.assert_allclose(
        apply(np.roll(f, shift)), np.roll(apply(f), shift),
        rtol=1e-9, atol=1e-9,
    )


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 2**31 - 1), accuracy=st.sampled_from([2, 4, 6]))
def test_fusion_equals_unfused(seed, accuracy):
    """φ(A·B) fused == evaluating each operator separately then φ."""
    rng = np.random.default_rng(seed)
    opset = derivative_operator_set(3, accuracy, spacing=0.5)
    r = opset.radius
    f = jnp.asarray(
        rng.standard_normal((2, 6 + 2 * r, 6 + 2 * r, 8 + 2 * r)),
        jnp.float64,
    )
    fused = ref.fused_stencil(f, opset, _phi_test)
    # unfused: evaluate each operator separately on a singleton-radius
    # view of the padded array (same interior geometry)
    R = opset.radius_per_axis()
    derivs = {}
    for spec in opset.ops:
        rr = spec.radius_per_axis() or (0, 0, 0)
        view = f[
            :,
            R[0] - rr[0] : f.shape[1] - (R[0] - rr[0]),
            R[1] - rr[1] : f.shape[2] - (R[1] - rr[1]),
            R[2] - rr[2] : f.shape[3] - (R[2] - rr[2]),
        ]
        derivs[spec.name] = ref.apply_operator_set(
            view, type(opset)((spec,))
        )[spec.name]
    np.testing.assert_allclose(
        np.asarray(fused), np.asarray(_phi_test(derivs)),
        rtol=1e-12, atol=1e-12,
    )


@settings(max_examples=15, deadline=None)
@given(
    seed=st.integers(0, 2**31 - 1),
    k=st.integers(1, 6),
    s=st.integers(8, 64),
)
def test_conv1d_causality(seed, k, s):
    """Output at t must not depend on inputs after t."""
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.standard_normal((1, s, 4)), jnp.float32)
    w = jnp.asarray(rng.standard_normal((k, 4)), jnp.float32)
    base = np.asarray(ref.conv1d_depthwise_causal(x, w))
    t = s // 2
    x2 = x.at[:, t + 1 :].set(999.0)
    pert = np.asarray(ref.conv1d_depthwise_causal(x2, w))
    np.testing.assert_array_equal(base[:, : t + 1], pert[:, : t + 1])
