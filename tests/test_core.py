"""Core stencil-math, autotune, optimizer, and roofline-parser tests."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import rooflinelib as rl
from repro.tuning import (
    enumerate_candidates_nd,
    halo_overhead,
    vmem_working_set,
)
from repro.core.stencil import (
    OperatorSet,
    axis_stencil,
    central_difference_coeffs,
    derivative_operator_set,
    diffusion_kernel_1d,
    fornberg_weights,
    laplacian_stencil,
    mixed_partial_stencil,
)


def test_fornberg_matches_known_coefficients():
    # 2nd-order first derivative: [-1/2, 0, 1/2]
    np.testing.assert_allclose(
        central_difference_coeffs(1, 2), [-0.5, 0.0, 0.5], atol=1e-12
    )
    # 6th-order second derivative (the paper's MHD stencil)
    np.testing.assert_allclose(
        central_difference_coeffs(2, 6),
        [1 / 90, -3 / 20, 3 / 2, -49 / 18, 3 / 2, -3 / 20, 1 / 90],
        atol=1e-12,
    )
    # weights reproduce exact derivatives of polynomials
    w = fornberg_weights(0.0, np.arange(-3, 4), 2)[:, 2]
    x = np.arange(-3, 4, dtype=float)
    for p in range(6):
        d2 = np.dot(w, x**p)
        expect = p * (p - 1) * 0.0 ** (p - 2) if p >= 2 else 0.0
        np.testing.assert_allclose(d2, expect, atol=1e-8)


def test_derivative_set_matches_paper_configuration():
    """accuracy=6, 3-D: 10 operators, pruned n_k = 127 (paper Sec. 4.4)."""
    ops = derivative_operator_set(3, 6)
    assert ops.n_s == 10
    assert ops.n_k == 127
    assert ops.radius_per_axis() == (3, 3, 3)
    A, cols = ops.matrix()
    assert A.shape == (10, 127)
    # every column (tap) used by at least one operator
    assert (np.abs(A).sum(axis=0) > 0).all()


def test_mixed_partial_on_polynomial():
    """d²(x·y)/dxdy == 1 exactly for any accuracy order."""
    for acc in (2, 4, 6):
        spec = mixed_partial_stencil(2, 0, 1, acc, (1.0, 1.0))
        val = sum(
            c * (o[0] * o[1]) for o, c in zip(spec.offsets, spec.coeffs)
        )
        np.testing.assert_allclose(val, 1.0, atol=1e-10)


def test_laplacian_stencil_sums_axes():
    lap = laplacian_stencil(3, 6, 1.0)
    c2 = central_difference_coeffs(2, 6)
    # center tap = 3 × center coefficient
    center = dict(zip(lap.offsets, lap.coeffs))[(0, 0, 0)]
    np.testing.assert_allclose(center, 3 * c2[3], atol=1e-12)


def test_diffusion_kernel_merges_identity():
    g = diffusion_kernel_1d(6, dt=0.1, alpha=2.0)
    c2 = central_difference_coeffs(2, 6)
    np.testing.assert_allclose(g[3], 1.0 + 0.2 * c2[3], atol=1e-12)


def test_operator_set_rejects_duplicate_names():
    s = axis_stencil(1, 0, 1, 2, name="dx")
    with pytest.raises(ValueError):
        OperatorSet((s, s))


# --- autotune -------------------------------------------------------------------


def test_vmem_filter_discards_oversized_blocks():
    cands = enumerate_candidates_nd(
        (256, 256, 256), (3, 3, 3), n_f=8, n_out=8, itemsize=4,
        vmem_budget=2 * 1024 * 1024,
    )
    assert cands, "some candidate must fit"
    assert all(c.vmem_bytes <= 2 * 1024 * 1024 for c in cands)
    # candidate accounting agrees with the working-set formula
    assert all(
        c.vmem_bytes == vmem_working_set(c.block, (3, 3, 3), 8, 8, 4)
        for c in cands
    )


def test_halo_overhead_monotone_in_block_size():
    small = halo_overhead((4, 4, 32), (3, 3, 3))
    big = halo_overhead((16, 16, 128), (3, 3, 3))
    assert big < small  # bigger blocks amortize the halo


def test_candidates_ranked_by_score():
    cands = enumerate_candidates_nd(
        (64, 64, 128), (3, 3, 3), n_f=8, n_out=8, itemsize=4
    )
    scores = [c.score for c in cands]
    assert scores == sorted(scores)


# --- roofline / HLO parsing ------------------------------------------------------


def test_collective_parser_on_synthetic_hlo():
    hlo = """
  %ag = f32[64,128]{1,0} all-gather(%x), replica_groups=[16,4]<=[64], dimensions={0}
  %ar = bf16[1024]{0} all-reduce(%y), replica_groups={{0,1,2,3}}, to_apply=%add
  %rs = f32[32]{0} reduce-scatter(%z), replica_groups=[8,8]<=[64]
  %cp = f32[16,16]{1,0} collective-permute(%w), source_target_pairs={{0,1}}
"""
    stats = rl.parse_collectives(hlo)
    assert stats.counts["all-gather"] == 1
    assert stats.result_bytes["all-gather"] == 64 * 128 * 4
    # group size 4 → wire = bytes × 3/4
    assert stats.wire_bytes["all-gather"] == int(64 * 128 * 4 * 3 / 4)
    assert stats.counts["all-reduce"] == 1
    assert stats.wire_bytes["all-reduce"] == int(2 * 1024 * 2 * 3 / 4)
    assert stats.counts["reduce-scatter"] == 1
    assert stats.wire_bytes["reduce-scatter"] == 32 * 4 * 7
    assert stats.counts["collective-permute"] == 1
    assert stats.wire_bytes["collective-permute"] == 16 * 16 * 4


def test_roofline_terms_and_dominance():
    r = rl.Roofline(
        flops=1e13, hbm_bytes=1e10, collective_result_bytes=0,
        collective_wire_bytes=1e9, chips=256, hw=rl.TPU_V5E,
    )
    assert r.compute_s == pytest.approx(1e13 / 197e12)
    assert r.memory_s == pytest.approx(1e10 / 819e9)
    assert r.collective_s == pytest.approx(1e9 / 50e9)
    assert r.dominant == "compute"
    assert 0 < r.roofline_fraction(0.5e13) <= 1.0


def test_machine_balance_matches_brief():
    assert rl.TPU_V5E.machine_balance(2) == pytest.approx(197e12 / 819e9)


# --- optimizer -------------------------------------------------------------------


def test_adamw_decreases_quadratic():
    from repro.optim import AdamWConfig, adamw_init, adamw_update

    cfg = AdamWConfig(lr_peak=0.1, warmup_steps=1, total_steps=100,
                      weight_decay=0.0)
    params = {"w": jnp.asarray([2.0, -3.0])}
    state = adamw_init(params)

    def loss(p):
        return jnp.sum(p["w"] ** 2)

    for _ in range(60):
        g = jax.grad(loss)(params)
        params, state, _ = adamw_update(cfg, g, state, params)
    assert float(loss(params)) < 0.1


def test_adamw_skips_decay_on_norms():
    from repro.optim.adamw import _decays

    class K:
        def __init__(self, key):
            self.key = key

    assert _decays((K("blocks"), K("wq")))
    assert not _decays((K("blocks"), K("ln1")))
    assert not _decays((K("blocks"), K("A_log")))


def test_grad_clip():
    from repro.optim import clip_by_global_norm

    g = {"a": jnp.full((4,), 10.0)}
    clipped, norm = clip_by_global_norm(g, 1.0)
    assert float(norm) == pytest.approx(20.0)
    total = float(jnp.sqrt(jnp.sum(clipped["a"] ** 2)))
    assert total == pytest.approx(1.0, rel=1e-5)
