"""Tuning subsystem tests: cache round-trip persistence, key stability
across processes, schema-bump invalidation, VMEM fallback, and the
``block="auto"`` acceptance criterion — identical numerics to an
explicit block, with a second process reusing the persisted record
without re-measurement."""
import json
import os
import subprocess
import sys
from pathlib import Path

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.fusion import FusedStencilOp
from repro.core.stencil import derivative_operator_set
from repro.tuning import (
    SCHEMA_VERSION,
    TuningCache,
    TuningKey,
    TuningRecord,
    TuningSession,
    fused_nd_candidates,
)
from repro.tuning.session import auto_block_nd

SRC = str(Path(__file__).resolve().parent.parent / "src")

KEY = TuningKey(
    kernel="fused_stencil3d", strategy="swc", domain=(8, 8, 16),
    radii=(1, 1, 1), n_f=2, n_out=1, dtype="float32", backend="cpu",
)


@pytest.fixture
def cache_dir(tmp_path, monkeypatch):
    monkeypatch.setenv("REPRO_TUNE_CACHE", str(tmp_path))
    return tmp_path


def _subprocess_env(cache_dir) -> dict:
    env = dict(os.environ)
    env["REPRO_TUNE_CACHE"] = str(cache_dir)
    env["PYTHONPATH"] = SRC + os.pathsep + env.get("PYTHONPATH", "")
    return env


# --- cache ---------------------------------------------------------------------


def test_cache_roundtrip_persistence(cache_dir):
    rec = TuningRecord(
        block=(4, 8, 16), timings_us={"4x8x16": 12.5, "8x8x16": 17.0},
        source="measured",
    )
    TuningCache().put(KEY, rec)
    # A fresh cache object re-reads from disk (new-process simulation).
    got = TuningCache().get(KEY)
    assert got is not None
    assert got.block == (4, 8, 16)  # tuple restored from JSON list
    assert got.timings_us == rec.timings_us
    assert got.source == "measured"
    assert got.schema == SCHEMA_VERSION
    assert got.created > 0


def test_cache_key_stable_across_processes(cache_dir):
    code = (
        "from repro.tuning import TuningKey\n"
        "print(TuningKey(kernel='fused_stencil3d', strategy='swc',"
        " domain=(8, 8, 16), radii=(1, 1, 1), n_f=2, n_out=1,"
        " dtype='float32', backend='cpu').cache_id)\n"
    )
    out = subprocess.run(
        [sys.executable, "-c", code], capture_output=True, text=True,
        env=_subprocess_env(cache_dir), check=True,
    )
    assert out.stdout.strip() == KEY.cache_id


def test_schema_bump_invalidates_records(cache_dir):
    TuningCache().put(
        KEY, TuningRecord(block=(4, 8, 16), timings_us={}, source="model")
    )
    path = cache_dir / "cache.json"
    raw = json.loads(path.read_text())
    for rec in raw["records"].values():
        rec["schema"] = SCHEMA_VERSION - 1  # pretend an older build wrote it
    path.write_text(json.dumps(raw))
    assert TuningCache().get(KEY) is None


def test_cache_put_merges_with_disk(cache_dir):
    """Two cache objects (concurrent-process stand-ins) don't clobber
    each other's records."""
    a, b = TuningCache(), TuningCache()
    key2 = TuningKey(
        kernel="xcorr1d", strategy="baseline:u1", domain=(1024,),
        radii=(2,), n_f=1, n_out=1, dtype="float32", backend="cpu",
    )
    a.put(KEY, TuningRecord(block=(8, 8, 16), timings_us={}, source="model"))
    b.put(key2, TuningRecord(block=2048, timings_us={}, source="model"))
    fresh = TuningCache()
    assert fresh.get(KEY) is not None
    assert fresh.get(key2) is not None
    assert fresh.get(key2).block == 2048  # int block round-trips as int


# --- session -------------------------------------------------------------------


def test_session_cache_hit_skips_measurement(cache_dir):
    cands = fused_nd_candidates((8, 8, 16), (1, 1, 1), 2, 1, 4)
    calls = []

    def measure(cand):
        calls.append(cand.block)
        return 1.0 if cand.block != cands[0].block else 0.5

    sess = TuningSession(top_k=2)
    rec1 = sess.tune(KEY, cands, measure)
    assert rec1.source == "measured" and len(calls) == 2
    rec2 = sess.tune(KEY, cands, measure)
    assert len(calls) == 2  # fast path: no new measurements
    assert rec2.block == rec1.block


def test_session_upgrades_model_record_when_measurable(cache_dir):
    """A cost-model record (persisted under jit tracing) is re-tuned —
    not returned from the fast path — once a caller can measure."""
    cands = fused_nd_candidates((8, 8, 16), (1, 1, 1), 2, 1, 4)
    sess = TuningSession(top_k=2)
    traced = sess.tune(KEY, cands, measure=None)
    assert traced.source == "model"

    calls = []

    def measure(cand):
        calls.append(cand.block)
        return 1.0

    upgraded = sess.tune(KEY, cands, measure)
    assert upgraded.source == "measured" and len(calls) == 2
    # ...and the measured record now IS the fast path.
    again = sess.tune(KEY, cands, measure)
    assert len(calls) == 2 and again.source == "measured"


def test_session_all_discarded_falls_back_to_model(cache_dir):
    cands = fused_nd_candidates((8, 8, 16), (1, 1, 1), 2, 1, 4)

    def measure(cand):
        raise RuntimeError("launch failed")  # paper: discarded launches

    rec = TuningSession().tune(KEY, cands, measure)
    assert rec.source == "model"
    assert rec.block == cands[0].block


def _tiny_problem():
    opset = derivative_operator_set(3, 2, spacing=0.3)

    def phi(d):
        lap = d["dxx"] + d["dyy"] + d["dzz"]
        return jnp.stack([d["val"][0] + 0.1 * lap[0]])

    rng = np.random.default_rng(7)
    f = jnp.asarray(rng.standard_normal((2, 8, 8, 16)), jnp.float32)
    return opset, phi, f


def test_auto_block_vmem_fallback(cache_dir):
    """No candidate fits a (tiny) VMEM budget: auto degrades to the
    smallest-footprint block without measuring, and the kernel still
    runs with it."""
    from repro.kernels import ops as kops
    from repro.tuning import session as sess_mod

    opset, phi, f = _tiny_problem()
    r = opset.radius
    fp = jnp.pad(f, ((0, 0),) + ((r, r),) * 3, mode="wrap")
    before = sess_mod.MEASURE_COUNT
    block = auto_block_nd(fp, opset, phi, 1, strategy="swc",
                          interpret=True, vmem_budget=64)
    assert sess_mod.MEASURE_COUNT == before  # no launches attempted
    # _tiny_problem builds an accuracy-2 opset: the non-default order
    # joins the strategy id as :o2.
    rec = TuningCache().get(
        TuningKey("fused_stencil3d", "swc:o2", (8, 8, 16), (r,) * 3, 2, 1,
                  "float32", sess_mod.current_backend())
    )
    assert rec is not None and rec.source == "fallback"
    out = kops.fused_stencil_nd(
        fp, opset, phi, 1, strategy="swc", block=block, interpret=True
    )
    assert out.shape == (1, 8, 8, 16)


# --- block="auto" end to end (acceptance criterion) ---------------------------


def test_auto_matches_explicit_and_persists_across_processes(cache_dir):
    opset, phi, f = _tiny_problem()
    auto_op = FusedStencilOp(opset, phi, 1, strategy="swc", block="auto")
    explicit = FusedStencilOp(opset, phi, 1, strategy="swc",
                              block=(4, 4, 16))
    out_auto = auto_op(f)
    out_exp = explicit(f)
    np.testing.assert_array_equal(
        np.asarray(out_auto), np.asarray(out_exp)
    )

    records = TuningCache().items()
    assert len(records) == 1
    rec = next(iter(records.values()))
    assert rec.source == "measured" and rec.timings_us

    # Second process: same auto op must replay the persisted record with
    # ZERO measurements, and produce the same numerics.
    code = f"""
import numpy as np
import jax.numpy as jnp
from repro.core.fusion import FusedStencilOp
from repro.core.stencil import derivative_operator_set
from repro.tuning import session as sess_mod

opset = derivative_operator_set(3, 2, spacing=0.3)
def phi(d):
    lap = d["dxx"] + d["dyy"] + d["dzz"]
    return jnp.stack([d["val"][0] + 0.1 * lap[0]])
rng = np.random.default_rng(7)
f = jnp.asarray(rng.standard_normal((2, 8, 8, 16)), jnp.float32)
out = FusedStencilOp(opset, phi, 1, strategy="swc", block="auto")(f)
assert sess_mod.MEASURE_COUNT == 0, sess_mod.MEASURE_COUNT
expect = np.asarray(
    FusedStencilOp(opset, phi, 1, strategy="swc", block=(4, 4, 16))(f)
)
np.testing.assert_array_equal(np.asarray(out), expect)
print("REUSED_OK")
"""
    out = subprocess.run(
        [sys.executable, "-c", code], capture_output=True, text=True,
        env=_subprocess_env(cache_dir),
    )
    assert out.returncode == 0, out.stderr
    assert "REUSED_OK" in out.stdout


def test_xcorr1d_auto_matches_explicit(cache_dir):
    from repro.kernels import ops as kops
    from repro.kernels import ref

    rng = np.random.default_rng(3)
    f = jnp.asarray(rng.standard_normal(4096 + 4), jnp.float32)
    g = jnp.asarray(rng.standard_normal(5), jnp.float32)
    out = kops.xcorr1d(f, g, strategy="baseline", block_size="auto",
                       interpret=True)
    np.testing.assert_allclose(
        np.asarray(out), np.asarray(ref.xcorr1d(f, g)),
        rtol=1e-4, atol=1e-4,
    )
    assert any(
        k.startswith("xcorr1d|") for k in TuningCache().items()
    )


def test_conv1d_auto_matches_explicit(cache_dir):
    from repro.kernels import ops as kops
    from repro.kernels import ref

    rng = np.random.default_rng(4)
    x = jnp.asarray(rng.standard_normal((2, 256, 16)), jnp.float32)
    w = jnp.asarray(rng.standard_normal((4, 16)), jnp.float32)
    out = kops.conv1d_depthwise(x, w, block_seq="auto", interpret=True)
    np.testing.assert_allclose(
        np.asarray(out), np.asarray(ref.conv1d_depthwise_causal(x, w)),
        rtol=1e-5, atol=1e-5,
    )
    assert any(
        k.startswith("conv1d_depthwise|") for k in TuningCache().items()
    )


# --- corruption quarantine (ISSUE 8) --------------------------------------------


def _seed_cache(cache_dir):
    TuningCache().put(
        KEY, TuningRecord(block=(4, 8, 16), timings_us={}, source="measured")
    )
    return cache_dir / "cache.json"


def test_truncated_cache_is_quarantined_and_rebuilt(cache_dir):
    """A cache.json cut short mid-write (crashed writer) is renamed
    aside — not silently shadowed — and the next put starts clean."""
    path = _seed_cache(cache_dir)
    data = path.read_bytes()
    path.write_bytes(data[: len(data) // 2])

    fresh = TuningCache()
    assert fresh.get(KEY) is None  # corrupt view is empty, not wrong
    assert (cache_dir / "cache.json.corrupt").exists()
    assert (cache_dir / "cache.json.corrupt").read_bytes() == data[: len(data) // 2]

    fresh.put(KEY, TuningRecord(block=(8, 8, 16), timings_us={}, source="measured"))
    assert TuningCache().get(KEY).block == (8, 8, 16)


def test_garbage_cache_is_quarantined(cache_dir):
    path = _seed_cache(cache_dir)
    path.write_text("{garbage: definitely, not json\x00")
    assert TuningCache().get(KEY) is None
    assert (cache_dir / "cache.json.corrupt").exists()
    assert not path.exists()  # moved aside, not copied


def test_valid_json_wrong_layout_is_quarantined(cache_dir):
    path = _seed_cache(cache_dir)
    path.write_text('["not", "a", "cache", "document"]')
    assert TuningCache().get(KEY) is None
    assert (cache_dir / "cache.json.corrupt").exists()


def test_repeated_corruption_numbers_the_corpses(cache_dir):
    for n in range(3):
        path = _seed_cache(cache_dir)
        path.write_text("not json at all")
        assert TuningCache().get(KEY) is None
    names = sorted(p.name for p in cache_dir.glob("cache.json.corrupt*"))
    assert names == [
        "cache.json.corrupt", "cache.json.corrupt.1", "cache.json.corrupt.2",
    ]


def test_missing_cache_is_cold_start_not_corruption(cache_dir):
    assert TuningCache().get(KEY) is None
    assert list(cache_dir.glob("cache.json.corrupt*")) == []


_STRESS_WORKER = """
import sys
from repro.tuning import TuningCache, TuningKey, TuningRecord

worker = int(sys.argv[1])
cache = TuningCache()
for i in range(8):
    key = TuningKey(
        kernel="stress", strategy=f"w{worker}", domain=(i,),
        radii=(1,), n_f=1, n_out=1, dtype="float32", backend="cpu",
    )
    cache.put(key, TuningRecord(block=2 ** (i + 4), timings_us={}, source="measured"))
"""


def test_multiprocess_put_loses_no_record(cache_dir):
    """N processes hammering put() concurrently: the advisory lock +
    read-merge-write must preserve every record from every worker."""
    n_workers = 4
    procs = [
        subprocess.Popen(
            [sys.executable, "-c", _STRESS_WORKER, str(w)],
            env=_subprocess_env(cache_dir),
            stdout=subprocess.PIPE, stderr=subprocess.PIPE,
        )
        for w in range(n_workers)
    ]
    for p in procs:
        _, err = p.communicate(timeout=120)
        assert p.returncode == 0, err.decode()
    items = TuningCache().items()
    stress = {k for k in items if k.startswith("stress|")}
    assert len(stress) == n_workers * 8, sorted(stress)
    for w in range(n_workers):
        for i in range(8):
            key_id = f"stress|w{w}|{i}|1|1|1|float32|cpu"
            assert key_id in items, key_id
            assert items[key_id].block == 2 ** (i + 4)


# --- failed-candidate rows (ISSUE 8) --------------------------------------------


def test_failed_candidates_recorded_and_skipped_on_retune(cache_dir):
    """A candidate whose measurement raises becomes a ``failed`` row of
    the persisted record; a later (forced) re-tune never re-launches
    it."""
    cands = fused_nd_candidates((8, 8, 16), (1, 1, 1), 2, 1, 4)
    bad = {cands[0].block}
    calls = []

    def measure(cand):
        calls.append(cand.block)
        if cand.block in bad:
            raise RuntimeError("RESOURCE_EXHAUSTED: VMEM")
        return 1.0

    sess = TuningSession(top_k=2)
    rec = sess.tune(KEY, cands, measure)
    assert rec.source == "measured"
    assert rec.block != cands[0].block
    assert len(rec.failed) == 1
    assert "RESOURCE_EXHAUSTED" in next(iter(rec.failed.values()))

    # Persisted: a fresh session sees the failed row.
    assert len(TuningCache().get(KEY).failed) == 1

    calls.clear()
    rec2 = TuningSession(top_k=2).tune(KEY, cands, measure, force=True)
    assert cands[0].block not in calls  # known-bad skipped
    assert len(calls) == 2  # top-k drawn from the healthy pool
    assert rec2.failed == rec.failed  # carried forward


def test_all_failed_still_resolves_and_marks_every_row(cache_dir):
    cands = fused_nd_candidates((8, 8, 16), (1, 1, 1), 2, 1, 4)

    def measure(cand):
        raise RuntimeError("launch failed")

    sess = TuningSession(top_k=2)
    rec = sess.tune(KEY, cands, measure)
    assert rec.source == "model"
    assert len(rec.failed) == 2  # every attempted candidate marked


def test_failed_rows_roundtrip_and_old_records_parse(cache_dir):
    rec = TuningRecord(
        block=(4, 8, 16), timings_us={"4x8x16": 9.0}, source="measured",
        failed={"8x8x16": "InjectedCompileFailure: boom"},
    )
    TuningCache().put(KEY, rec)
    got = TuningCache().get(KEY)
    assert got.failed == {"8x8x16": "InjectedCompileFailure: boom"}
    # Pre-ISSUE-8 records (no "failed" key) parse with no failures.
    d = rec.to_json()
    del d["failed"]
    assert TuningRecord.from_json(d).failed == {}


def test_injected_candidate_fault_lands_in_failed_rows(cache_dir):
    """The module-level active injector (the chaos seam) turns a
    targeted candidate fault into a failed row, and tuning still
    resolves a winner."""
    from repro.ft.faults import FaultInjector, FaultSpec
    from repro.ft import faults as ftfaults

    cands = fused_nd_candidates((8, 8, 16), (1, 1, 1), 2, 1, 4)
    inj = FaultInjector([
        FaultSpec("tune.candidate", "compile", label="*", times=1),
    ])
    with ftfaults.active(inj):
        rec = TuningSession(top_k=2).tune(KEY, cands, lambda c: 1.0)
    assert rec.source == "measured"
    assert len(rec.failed) == 1
    assert "InjectedCompileFailure" in next(iter(rec.failed.values()))
    assert inj.fired[0][0] == "tune.candidate"
