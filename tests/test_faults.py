"""Fault-injection and failure-domain tests (ISSUE 8).

Covers the injector itself (spec validation, firing budgets,
conjunctive selectors, seeded chaos-plan determinism), every rung of
the serving recovery ladder (retry with backoff → strategy degradation
→ batch bisection → quarantine), NaN/inf output validation, and the
widened ``Supervisor.recoverable`` exception tuple.
"""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.ft.faults import (
    FaultInjector,
    FaultSpec,
    InjectedCompileFailure,
    InjectedResourceExhausted,
    chaos_specs,
)
from repro.ft.supervisor import SimulatedFailure, Supervisor
from repro.launch.serve_sim import (
    RequestQueue,
    RetryPolicy,
    SimRequest,
    SimServer,
)


def _req(rid, shape=(8, 16), n_steps=2):
    f0 = jnp.zeros((1,) + shape, jnp.float32) + 1e-5 * (rid + 1)
    return SimRequest(rid, f0, n_steps)


def _server(**kw):
    kw.setdefault("strategy", "swc")
    kw.setdefault("max_batch", 4)
    kw.setdefault("retry", RetryPolicy(max_retries=2, backoff_s=0.0))
    return SimServer(**kw)


# --- the injector itself ---------------------------------------------------


def test_spec_validates_site_and_kind():
    with pytest.raises(ValueError, match="unknown fault site"):
        FaultSpec("serve.nonsense", "compile")
    with pytest.raises(ValueError, match="invalid for site"):
        FaultSpec("serve.batch", "nan")  # nan is an output fault


def test_budget_transient_fires_once_persistent_forever():
    inj = FaultInjector([
        FaultSpec("serve.batch", "compile", times=1),
    ])
    with pytest.raises(InjectedCompileFailure):
        inj.on_batch(0, [0], "swc")
    inj.on_batch(1, [0], "swc")  # budget consumed: no raise
    assert len(inj.fired) == 1

    inj = FaultInjector([
        FaultSpec("serve.batch", "oom", times=0),  # persistent
    ])
    for index in range(3):
        with pytest.raises(InjectedResourceExhausted):
            inj.on_batch(index, [0], "swc")
    assert len(inj.fired) == 3


def test_selectors_are_conjunctive():
    inj = FaultInjector([
        FaultSpec(
            "serve.batch", "compile", req_id=3, strategy="swc", times=0
        ),
    ])
    inj.on_batch(0, [1, 2], "swc")  # req 3 absent
    inj.on_batch(1, [3], "hwc")  # wrong strategy
    assert inj.fired == []
    with pytest.raises(InjectedCompileFailure):
        inj.on_batch(2, [2, 3], "swc")


def test_candidate_label_selector_substring_and_wildcard():
    inj = FaultInjector([
        FaultSpec("tune.candidate", "compile", label="8x16", times=0),
    ])
    inj.on_candidate("32x32")  # no match
    with pytest.raises(InjectedCompileFailure):
        inj.on_candidate("8x16@f2:s")  # substring match
    inj = FaultInjector([
        FaultSpec("tune.candidate", "oom", label="*", times=1),
    ])
    with pytest.raises(InjectedResourceExhausted):
        inj.on_candidate("anything")


def test_chaos_specs_deterministic_and_targeted():
    ids = list(range(12))
    specs_a, plan_a = chaos_specs(7, ids)
    specs_b, plan_b = chaos_specs(7, ids)
    assert plan_a == plan_b
    assert [(s.site, s.kind, s.req_id) for s in specs_a] == [
        (s.site, s.kind, s.req_id) for s in specs_b
    ]
    assert plan_a["poison"] in ids
    assert plan_a["transient"] in ids
    assert plan_a["poison"] != plan_a["transient"]
    # A different seed reshuffles (over many ids this is stable enough
    # to assert for the specific seeds used here).
    _, plan_c = chaos_specs(8, ids)
    assert plan_c != plan_a


def test_corrupt_cache_garbage_and_truncate(tmp_path):
    target = tmp_path / "cache.json"
    target.write_text('{"records": {}}')
    inj = FaultInjector([FaultSpec("cache.file", "truncate", times=1)])
    assert inj.corrupt_cache(target)
    assert len(target.read_bytes()) < len('{"records": {}}')
    inj = FaultInjector([FaultSpec("cache.file", "garbage", times=1)])
    assert inj.corrupt_cache(target)
    with pytest.raises(ValueError):
        import json

        json.loads(target.read_text())
    # Exhausted injector: no further corruption.
    before = target.read_bytes()
    assert not inj.corrupt_cache(target)
    assert target.read_bytes() == before


# --- serving failure domains ----------------------------------------------


def test_transient_batch_failure_retries_to_completion():
    inj = FaultInjector([
        FaultSpec("serve.batch", "compile", req_id=0, times=1),
    ])
    server = _server(faults=inj)
    results = server.serve(RequestQueue([_req(0), _req(1)]))
    assert sorted(results) == [0, 1]
    assert server.error_reports == {}
    [rep] = server.reports
    assert rep.retries == 1
    assert rep.strategy == "swc"  # healed without leaving the rung
    assert rep.statuses == {0: "retried", 1: "retried"}
    assert server.request_status == {0: "retried", 1: "retried"}


def test_strategy_failure_degrades_down_the_ladder():
    """A strategy-attributed persistent failure (every swc launch
    raises) exhausts retries, then the bucket degrades to the hwc rung
    and completes there — and stays degraded for later batches."""
    inj = FaultInjector([
        FaultSpec("serve.batch", "oom", strategy="swc", times=0),
    ])
    server = _server(faults=inj, max_batch=2)
    results = server.serve(RequestQueue([_req(i) for i in range(4)]))
    assert sorted(results) == [0, 1, 2, 3]
    assert server.error_reports == {}
    assert [rep.strategy for rep in server.reports] == ["hwc", "hwc"]
    assert server.reports[0].statuses == {0: "degraded", 1: "degraded"}
    # The rung stuck: the second batch went straight to hwc (no new
    # swc attempts → exactly 3 oom firings from the first batch).
    assert len(inj.fired) == 3
    assert server._strategy_for  # rung persisted for the bucket


def test_poison_request_is_bisected_and_quarantined():
    """A request-attributed failure (the batch fails at EVERY rung as
    long as the poison member is present) drives bisection: the poison
    is isolated and quarantined, every other member completes, and the
    bucket's degradation rung is reset afterwards."""
    inj = FaultInjector([
        FaultSpec("serve.batch", "compile", req_id=2, times=0),
    ])
    server = _server(faults=inj)
    results = server.serve(RequestQueue([_req(i) for i in range(4)]))
    assert sorted(results) == [0, 1, 3]
    assert set(server.error_reports) == {2}
    assert "InjectedCompileFailure" in server.error_reports[2]["error"]
    assert server.request_status[2] == "quarantined"
    # Healthy members completed (possibly on a degraded rung reached
    # while the poison was still attributed to the strategy).
    assert server.request_status[0] != "quarantined"
    assert server.request_status[3] != "quarantined"
    # Quarantine re-attributed the fault to the request: rung reset.
    assert server._strategy_for == {}
    # The quarantined singleton got its own report row.
    quarantine_reports = [
        rep for rep in server.reports
        if rep.statuses.get(2) == "quarantined"
    ]
    assert len(quarantine_reports) == 1
    assert quarantine_reports[0].batch == 1


def test_nan_output_quarantines_only_the_poisoned_member():
    inj = FaultInjector([
        FaultSpec("serve.output", "nan", req_id=1, times=0),
    ])
    server = _server(faults=inj)
    results = server.serve(RequestQueue([_req(i) for i in range(3)]))
    assert sorted(results) == [0, 2]
    assert set(server.error_reports) == {1}
    assert "non-finite" in server.error_reports[1]["error"]
    [rep] = server.reports  # no bisection: the batch itself succeeded
    assert rep.statuses == {0: "ok", 1: "quarantined", 2: "ok"}
    for rid in (0, 2):
        assert np.isfinite(results[rid]).all()


def test_validate_output_can_be_disabled():
    inj = FaultInjector([
        FaultSpec("serve.output", "inf", req_id=0, times=0),
    ])
    server = _server(faults=inj, validate_output=False)
    results = server.serve(RequestQueue([_req(0)]))
    assert np.isinf(results[0]).all()
    assert server.error_reports == {}


def test_slow_fault_stalls_without_failing():
    inj = FaultInjector(
        [FaultSpec("serve.batch", "slow", index=0, times=1)],
        slow_s=0.05,
    )
    server = _server(faults=inj)
    results = server.serve(RequestQueue([_req(0)]))
    assert sorted(results) == [0]
    assert inj.fired == [
        ("serve.batch", "slow", "index=0 reqs=[0] strategy=swc")
    ]
    assert server.reports[0].seconds >= 0.05


def test_retry_policy_ladder_and_auto_reentry():
    policy = RetryPolicy()
    assert policy.degrade("tc") == "swc_stream"
    assert policy.degrade("swc_stream") == "swc"
    assert policy.degrade("swc") == "hwc"
    assert policy.degrade("hwc") is None
    assert policy.degrade("auto") == "swc"
    assert policy.degrade("mystery") is None
    assert policy.backoff(1) == policy.backoff_s
    assert policy.backoff(2) == 2 * policy.backoff_s


# --- supervisor recoverable tuple -----------------------------------------


class _FakeCkptMgr:
    """In-memory checkpoint manager: just enough surface for
    ``Supervisor.run`` (save/wait/latest_step)."""

    def __init__(self):
        self.saved = {}

    def save(self, step, state):
        self.saved[step] = state

    def wait(self):
        pass

    def latest_step(self):
        return max(self.saved) if self.saved else None


def _flaky_step(fail_at, exc):
    fired = []

    def step_fn(state, step):
        if step == fail_at and not fired:
            fired.append(step)
            raise exc
        return state + 1

    return step_fn


def test_supervisor_default_only_recovers_simulated_failure():
    sup = Supervisor(_FakeCkptMgr(), ckpt_every=5)
    with pytest.raises(OSError):
        sup.run(
            0, _flaky_step(7, OSError("flaky fs")), 10,
            restore_fn=lambda s, step: (step or 0, step or 0),
        )


def test_supervisor_recoverable_tuple_widens_restart_trigger():
    mgr = _FakeCkptMgr()
    sup = Supervisor(
        mgr, ckpt_every=5, recoverable=(SimulatedFailure, OSError)
    )
    state, report = sup.run(
        0, _flaky_step(7, OSError("flaky fs")), 10,
        restore_fn=lambda s, step: (mgr.saved[step], step),
    )
    assert report["restarts"] == 1
    assert report["failed_steps"] == [7]
    assert state == 10  # replayed 5 → 10 after restore
