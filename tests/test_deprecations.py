"""Legacy API surface: every historical rank-3 entry point is a
``DeprecationWarning`` shim over the rank-generic API, and the pytest
``filterwarnings`` error filter (pyproject.toml) guarantees no in-repo
caller still goes through one. ``pytest.warns`` installs its own
catch-all recorder, so asserting the shims warn coexists with the
error filter."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.stencil import derivative_operator_set
from repro.kernels import ops as kops
# Deprecation tests target the legacy module itself by design.
from repro.kernels.stencil3d import fused_stencil3d_pallas  # repolint: allow[legacy-kernel-import]
from repro.tuning import (
    auto_block_3d,
    domain_axis_options,
    enumerate_candidates,
    fused3d_candidates,
    fused3d_key,
    lookup_fused3d,
)

DEPRECATED = pytest.warns(DeprecationWarning, match="is deprecated; use")


@pytest.fixture
def cache_dir(tmp_path, monkeypatch):
    monkeypatch.setenv("REPRO_TUNE_CACHE", str(tmp_path))
    return tmp_path


def _tiny_problem():
    opset = derivative_operator_set(3, 2, spacing=0.5)

    def phi(d):
        return jnp.stack([d["val"][0] + 0.1 * (d["dxx"] + d["dyy"] + d["dzz"])[0]])

    rng = np.random.default_rng(11)
    f = jnp.asarray(rng.standard_normal((1, 4, 4, 8)), jnp.float32)
    r = opset.radius
    fp = jnp.pad(f, ((0, 0),) + ((r, r),) * 3, mode="wrap")
    return opset, phi, f, fp


def test_fused_stencil3d_shim_warns_and_matches_nd():
    opset, phi, _, fp = _tiny_problem()
    with DEPRECATED:
        old = kops.fused_stencil3d(
            fp, opset, phi, 1, strategy="hwc", interpret=True
        )
    new = kops.fused_stencil_nd(
        fp, opset, phi, 1, strategy="hwc", interpret=True
    )
    np.testing.assert_array_equal(np.asarray(old), np.asarray(new))


def test_fused_stencil3d_pallas_shim_warns():
    opset, phi, f, fp = _tiny_problem()
    with DEPRECATED:
        out = fused_stencil3d_pallas(
            fp, opset, phi, 1, block=(4, 4, 8), interpret=True
        )
    assert out.shape == (1,) + f.shape[1:]


def test_tuning_key_and_candidate_shims_warn():
    with DEPRECATED:
        key = fused3d_key((8, 8, 16), (1, 1, 1), 2, 1, "float32", "swc")
    assert key.domain == (8, 8, 16)
    with DEPRECATED:
        cands = fused3d_candidates((8, 8, 16), (1, 1, 1), 2, 1, 4)
    assert cands
    with DEPRECATED:
        # The historical signature's x-tile options start at 128, so use
        # a lane-sized x extent.
        legacy = enumerate_candidates((8, 8, 128), (1, 1, 1), 2, 1, 4)
    assert legacy
    with DEPRECATED:
        opts = domain_axis_options((8, 8, 16))
    assert len(opts) == 3


def test_auto_and_lookup_shims_warn(cache_dir):
    opset, phi, f, fp = _tiny_problem()
    with DEPRECATED:
        # A 64-byte VMEM budget forces the no-measurement fallback path,
        # keeping the shim test cheap (no timed launches).
        block = auto_block_3d(
            fp, opset, phi, 1, strategy="swc", interpret=True,
            vmem_budget=64,
        )
    assert len(block) == 3
    with DEPRECATED:
        rec = lookup_fused3d(f, opset, 1, "swc")
    assert rec is not None and rec.source == "fallback"
