"""Tests for ``repro.analysis`` — the static plan auditor.

Four angles:

* the shipped shape registry audits clean (bounds + vmem + keys);
* the mutation harness detects every seeded defect class, so a clean
  audit is evidence and not vacuity;
* ``strategy_sid`` injectivity and the persisted-record round-trip,
  including a RAW-JSON regression for every post-PR-6 axis (fuse depth,
  stream flag, resolved strategy, unroll) and the legacy-record
  default (``unroll`` absent → 1);
* a property sweep: random valid plans are auditor-clean and
  round-trip through ``plan_from_record``.

Property tests use real ``hypothesis`` when installed and fall back to
the seeded sampler in ``tests/_minihypothesis.py`` otherwise (same
contract as ``test_kernel_properties.py``).
"""
import json

try:
    import hypothesis.strategies as st
    from hypothesis import given, settings
except ImportError:  # bare interpreter: seeded fallback, not a skip
    from _minihypothesis import given, settings
    from _minihypothesis import strategies as st

from repro.analysis import (
    CLASSES,
    audit_plan,
    audit_record_roundtrip,
    audit_sid_injectivity,
    check_vmem,
    parse_sid,
    run_audit,
)
from repro.analysis.mutants import run_harness
from repro.core.stencil import derivative_operator_set
from repro.kernels.plan import plan_from_record, plan_stencil
from repro.tuning.cache import TuningRecord

OPS2 = derivative_operator_set(2, accuracy=2)  # radius 1


# --- the shipped registry audits clean -----------------------------------------


def test_registry_smoke_audit_is_finding_free():
    report = run_audit(full=False, vmem_tol=0.0, enumerate_candidates=False)
    assert report["findings"] == []
    assert report["counts"]["registry_plans"] >= 50
    assert report["counts"]["sid_combos"] >= 1000
    assert report["counts"]["record_roundtrips"] >= 50


# --- the auditor is not vacuous: every defect class is detectable --------------


def test_mutation_harness_detects_every_mutant():
    results = run_harness()
    assert results["__clean__"]["detected"], (
        "fixture plans must audit clean before mutation: "
        f"{results['__clean__']['classes']}"
    )
    missed = [
        name for name, r in results.items()
        if name != "__clean__" and not r["detected"]
    ]
    assert not missed, f"undetected mutants: {missed}"


def test_mutation_harness_covers_the_finding_classes():
    results = run_harness()
    detected = set()
    for name, r in results.items():
        if name != "__clean__" and r["detected"]:
            detected.update(set(r["classes"]) & set(r["expected"]))
    # every machine-checkable defect family has a live detector
    assert {"bounds", "uninit", "coverage", "phi", "vmem", "key"} <= detected
    assert detected <= set(CLASSES)


# --- key injectivity -----------------------------------------------------------


def test_sid_injectivity_exhaustive():
    findings, n_combos = audit_sid_injectivity()
    assert findings == []
    assert n_combos >= 1000  # the full axis product, not a sample


def test_parse_sid_roundtrips_marked_axes():
    for sid in (
        "swc", "swc:u2", "swc:f3", "swc:u4:b2", "tc:f2:b4:o8",
        "swc_stream:f2:a0:o4", "swc:b2:a1", "auto:f2",
    ):
        parsed = parse_sid(sid)
        assert parsed is not None, sid


# --- persisted-record round-trip (post-PR-6 axes, raw JSON) --------------------


def _roundtrip(plan, ops):
    assert audit_record_roundtrip(plan, ops) == []


def test_record_roundtrip_unroll():
    _roundtrip(plan_stencil(OPS2, (2, 10, 258), 2, strategy="swc", unroll=2), OPS2)


def test_record_roundtrip_stream():
    _roundtrip(plan_stencil(OPS2, (2, 66, 258), 2, strategy="swc_stream"), OPS2)


def test_record_roundtrip_temporal():
    _roundtrip(
        plan_stencil(OPS2, (2, 68, 260), 2, strategy="swc", fuse_steps=2), OPS2
    )


def test_record_roundtrip_batch_and_aux():
    _roundtrip(plan_stencil(OPS2, (4, 2, 10, 258), 2, strategy="swc"), OPS2)
    _roundtrip(plan_stencil(OPS2, (1, 10, 258), 2, n_aux=1), OPS2)


def test_record_roundtrip_accuracy_axis():
    ops6 = derivative_operator_set(2, accuracy=6)
    _roundtrip(plan_stencil(ops6, (2, 14, 262), 2, strategy="swc"), ops6)


def test_raw_json_record_rebuilds_unrolled_plan():
    """A persisted v2 record — as raw JSON, every post-PR-6 field — must
    rebuild the exact plan whose tuning decision it stores."""
    plan = plan_stencil(OPS2, (2, 10, 258), 2, strategy="swc", unroll=2)
    raw = json.dumps({
        "block": list(plan.block),
        "timings_us": {"8x128:u2": 12.5},
        "source": "measured",
        "schema": 2,
        "created": 1.0,
        "fuse_steps": 1,
        "stream": False,
        "strategy_resolved": "swc",
        "failed": {},
        "unroll": 2,
    })
    rec = TuningRecord.from_json(json.loads(raw))
    assert rec.unroll == 2
    back = plan_from_record(OPS2, (2, 8, 256), 2, rec)
    assert back == plan


def test_raw_json_legacy_record_defaults_unroll_1():
    """Pre-unroll records (no ``unroll`` key in the JSON) must parse as
    unroll=1, matching their unmarked tuning keys."""
    raw = json.dumps({
        "block": [8, 128],
        "timings_us": {},
        "source": "measured",
        "schema": 2,
        "fuse_steps": 2,
        "stream": True,
        "strategy_resolved": "swc_stream",
    })
    rec = TuningRecord.from_json(json.loads(raw))
    assert rec.unroll == 1
    back = plan_from_record(OPS2, (2, 64, 256), 2, rec)
    expect = plan_stencil(
        OPS2, (2, 68, 260), 2, strategy="swc_stream", fuse_steps=2,
        block=(8, 128),
    )
    assert back == expect


# --- vmem fidelity -------------------------------------------------------------


def test_vmem_shadow_measurement_matches_model():
    for plan in (
        plan_stencil(OPS2, (2, 10, 258), 2, strategy="swc", unroll=2),
        plan_stencil(OPS2, (1, 10, 258), 2, n_aux=1),
        plan_stencil(OPS2, (2, 66, 258), 2, strategy="swc_stream"),
    ):
        res = audit_plan(plan, OPS2)
        assert res.findings == []
        assert check_vmem(plan, res.measured_vmem) == []


def test_vmem_check_flags_mismatch():
    plan = plan_stencil(OPS2, (2, 10, 258), 2, strategy="swc")
    res = audit_plan(plan, OPS2)
    wrong = res.measured_vmem * 2
    findings = check_vmem(plan, wrong)
    assert findings and findings[0].cls == "vmem"


# --- property sweep: random valid plans audit clean ----------------------------


@settings(max_examples=12, deadline=None)
@given(
    strategy=st.sampled_from(("swc", "swc_stream", "tc")),
    accuracy=st.sampled_from((2, 4, 6)),
    interior_y=st.sampled_from((16, 32, 64)),
    fuse=st.sampled_from((1, 2)),
    unroll=st.sampled_from((1, 2)),
    batch=st.sampled_from((1, 2)),
)
def test_random_valid_plans_audit_clean(
    strategy, accuracy, interior_y, fuse, unroll, batch
):
    ops = derivative_operator_set(2, accuracy=accuracy)
    r = ops.radius
    if strategy != "swc" or fuse > 1:
        unroll = 1  # unroll composes only with depth-1 pipelined swc
    pad = 2 * r * fuse
    shape = (2, interior_y + pad, 256 + pad)
    if batch > 1:
        shape = (batch,) + shape
    plan = plan_stencil(
        ops, shape, 2, strategy=strategy, fuse_steps=fuse, unroll=unroll
    )
    res = audit_plan(plan, ops)
    assert res.findings == [], [f.detail for f in res.findings]
    assert check_vmem(plan, res.measured_vmem) == []
    assert audit_record_roundtrip(plan, ops) == []
