"""End-to-end system behaviour tests: training convergence on learnable
data, decode-vs-forward consistency per family, MoE routing behaviour,
and small-mesh jit step integration (the dry-run path on 8 CPU devices,
actually executed)."""
import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import dataclasses  # noqa: E402

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
import numpy as np  # noqa: E402
import pytest  # noqa: E402

from repro.configs.registry import (  # noqa: E402
    SHAPES,
    get_config,
    get_model,
    reduced_config,
)
from repro.data import MarkovLMDataset  # noqa: E402
from repro.distrib import sharding as shlib  # noqa: E402
from repro.launch.mesh import make_mesh  # noqa: E402
from repro.launch.specs import (  # noqa: E402
    abstract_decode_cache,
    train_input_specs,
)
from repro.launch.steps import jit_serve_step, jit_train_step  # noqa: E402
from repro.optim import AdamWConfig, adamw_init  # noqa: E402


def test_training_reduces_loss_on_markov_data():
    """A small dense model must learn an order-1 Markov chain."""
    cfg = dataclasses.replace(
        get_config("qwen2.5-3b"),
        n_layers=2, d_model=128, n_heads=4, n_kv_heads=2, head_dim=32,
        d_ff=256, vocab=64, dtype="float32", remat="none",
    )
    api = get_model(cfg)
    ds = MarkovLMDataset(vocab=64, seq_len=64, branching=4, seed=1)
    params = api.init_params(cfg, jax.random.PRNGKey(0))
    from repro.optim import adamw_update

    opt_cfg = AdamWConfig(lr_peak=5e-3, warmup_steps=5, total_steps=80)
    opt = adamw_init(params)

    @jax.jit
    def step(params, opt, batch):
        (loss, _), g = jax.value_and_grad(api.lm_loss, has_aux=True)(
            params, cfg, batch
        )
        params, opt, _ = adamw_update(opt_cfg, g, opt, params)
        return params, opt, loss

    losses = []
    for i in range(80):
        batch = {k: jnp.asarray(v) for k, v in ds.batch(i, 0, 16).items()}
        params, opt, loss = step(params, opt, batch)
        losses.append(float(loss))
    # entropy rate ln(4) ≈ 1.386; untrained ≈ ln(64) ≈ 4.16
    assert losses[-1] < losses[0] - 1.0, (losses[0], losses[-1])
    assert np.isfinite(losses).all()


@pytest.mark.parametrize("arch_id", ["qwen2.5-3b", "mamba2-780m",
                                     "recurrentgemma-9b", "mixtral-8x7b"])
def test_decode_matches_forward(arch_id):
    """Step-by-step decode must reproduce teacher-forced logits.

    MoE needs drop-free capacity: training-path capacity drops are a
    batch-level effect the per-token decode path (correctly) lacks."""
    cfg = dataclasses.replace(reduced_config(get_config(arch_id)),
                              dtype="float32", capacity_factor=8.0)
    api = get_model(cfg)
    key = jax.random.PRNGKey(3)
    params = api.init_params(cfg, key)
    tokens = jax.random.randint(key, (2, 12), 0, cfg.vocab)
    logits_full, _ = api.forward(params, cfg, tokens)
    cache = api.init_decode_cache(cfg, 2, 16)
    errs = []
    for t in range(12):
        lg, cache = api.decode_step(params, cfg, tokens[:, t : t + 1], cache)
        errs.append(float(jnp.abs(lg - logits_full[:, t]).max()))
    assert max(errs) < 5e-4, errs


def test_moe_routes_to_multiple_experts():
    from repro.models.moe import init_moe_params, moe_ffn, router_topk

    cfg = reduced_config(get_config("qwen3-moe-30b-a3b"))
    key = jax.random.PRNGKey(0)
    p = jax.tree.map(lambda x: x[0], init_moe_params(cfg, key, 1))
    x = jax.random.normal(key, (2, 64, cfg.d_model))
    out, aux = moe_ffn(x, p, cfg)
    assert out.shape == x.shape
    # balanced-ish routing at init: aux near its floor of 1.0
    assert 0.9 < float(aux) < 3.0
    logits = x.reshape(-1, cfg.d_model) @ p["router"]
    gates, idx, _ = router_topk(logits[None], cfg.top_k)
    np.testing.assert_allclose(np.asarray(gates.sum(-1)), 1.0, rtol=1e-5)


def test_moe_capacity_drops_overflow():
    from repro.models.moe import init_moe_params, moe_ffn

    cfg = dataclasses.replace(
        reduced_config(get_config("qwen3-moe-30b-a3b")),
        capacity_factor=0.05,
    )
    key = jax.random.PRNGKey(0)
    p = jax.tree.map(lambda x: x[0], init_moe_params(cfg, key, 1))
    x = jax.random.normal(key, (2, 64, cfg.d_model))
    out_tight, _ = moe_ffn(x, p, cfg)
    out_loose, _ = moe_ffn(
        x, p, dataclasses.replace(cfg, capacity_factor=2.0)
    )
    assert float(jnp.abs(out_tight).mean()) < 0.5 * float(
        jnp.abs(out_loose).mean()
    )


def test_jit_train_step_on_8_device_mesh():
    """The dry-run lowering path, actually EXECUTED on 8 fake devices."""
    cfg = dataclasses.replace(
        reduced_config(get_config("qwen2.5-3b")), dtype="float32"
    )
    mesh = make_mesh((2, 4), ("data", "model"))
    shape = dataclasses.replace(SHAPES["train_4k"], seq_len=32,
                                global_batch=4)
    with shlib.rules_context(mesh):
        batch_abs = train_input_specs(cfg, shape)
        step, (p_sh, o_sh, b_sh) = jit_train_step(
            cfg, mesh, batch_abs, donate=False
        )
        api = get_model(cfg)
        params = jax.device_put(
            api.init_params(cfg, jax.random.PRNGKey(0)), p_sh
        )
        opt = jax.device_put(adamw_init(params), o_sh)
        key = jax.random.PRNGKey(1)
        tokens = jax.random.randint(key, (4, 32), 0, cfg.vocab)
        batch = {
            "tokens": jax.device_put(tokens, b_sh["tokens"]),
            "labels": jax.device_put(jnp.roll(tokens, -1, 1),
                                     b_sh["labels"]),
        }
        params2, opt2, metrics = step(params, opt, batch)
    assert np.isfinite(float(metrics["loss"]))
    delta = jax.tree.map(
        lambda a, b: float(jnp.abs(a.astype(jnp.float32)
                                   - b.astype(jnp.float32)).max()),
        params, params2,
    )
    assert max(jax.tree_util.tree_leaves(delta)) > 0.0


def test_jit_serve_step_on_8_device_mesh():
    cfg = dataclasses.replace(
        reduced_config(get_config("mamba2-780m")), dtype="float32"
    )
    mesh = make_mesh((2, 4), ("data", "model"))
    shape = dataclasses.replace(SHAPES["decode_32k"], seq_len=64,
                                global_batch=4)
    with shlib.rules_context(mesh):
        cache_abs = abstract_decode_cache(cfg, shape)
        batch_abs = {"tokens": jax.ShapeDtypeStruct((4, 1), jnp.int32)}
        step, (p_sh, c_sh, b_sh) = jit_serve_step(
            cfg, mesh, batch_abs, cache_abs, donate_cache=False
        )
        api = get_model(cfg)
        params = jax.device_put(
            api.init_params(cfg, jax.random.PRNGKey(0)), p_sh
        )
        cache = jax.device_put(api.init_decode_cache(cfg, 4, 64), c_sh)
        tokens = jax.device_put(jnp.zeros((4, 1), jnp.int32),
                                b_sh["tokens"])
        logits, cache2 = step(params, cache, {"tokens": tokens})
    assert logits.shape == (4, cfg.vocab)
    assert np.isfinite(np.asarray(logits)).all()
    assert int(cache2.length) == 1
