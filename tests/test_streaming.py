"""Explicit-streaming tests (the rank-generic, fusion-aware
``swc_stream`` plan axis).

Covers the PR acceptance criteria — rank-2 (y-stream) vs rank-3
(z-stream) parity against the ``ref.py`` oracles across float32/float64,
fused-streaming parity for S ∈ {1, 2, 3} against the sequential
``fused_stencil_steps`` reference, stream-axis/depth tuning-key
uniqueness, and the traffic model's ability to score (and ``"auto"``'s
ability to select) a fused streaming configuration.
"""
import jax

jax.config.update("jax_enable_x64", True)

import jax.numpy as jnp  # noqa: E402
import numpy as np  # noqa: E402
import pytest  # noqa: E402

from repro.core.fusion import integrate  # noqa: E402
from repro.core.stencil import derivative_operator_set  # noqa: E402
from repro.core.trafficmodel import (  # noqa: E402
    stencil_hbm_bytes_per_step,
    stencil_stream_hbm_bytes_per_step,
)
from repro.kernels import ops as kops  # noqa: E402
from repro.kernels import ref  # noqa: E402
from repro.kernels.plan import plan_stencil  # noqa: E402
from repro.physics.diffusion import DiffusionProblem, simulate  # noqa: E402
from repro.tuning import lookup_fused_nd  # noqa: E402
from repro.tuning.costmodel import enumerate_candidates_nd  # noqa: E402

RNG = np.random.default_rng(31)

# Multi-chunk stream extents, deliberately not tile-aligned on x. The
# stream axis is sized to hold the deepest carried halo tested plus one
# chunk (2·r·S + τ₀ with r = 2, S ≤ 3 — the plan-validation bound).
SHAPES = {2: (20, 24), 3: (15, 10, 24)}
BLOCKS = {2: (4, 12), 3: (3, 5, 12)}


def _problem(ndim, dtype, n_steps, accuracy=4, n_f=2):
    """A self-map problem (n_out == n_f) + operand padded for
    ``n_steps`` fused sweeps."""
    opset = derivative_operator_set(ndim, accuracy, spacing=0.3)
    names = opset.names

    def phi(d):
        acc = sum(d[n] for n in names)
        return jnp.stack(
            [
                jnp.tanh(acc[0]) + d["val"][-1] * 0.1,
                d["val"][0] + 0.05 * acc[-1],
            ]
        )

    r = opset.radius
    shape = SHAPES[ndim]
    f = jnp.asarray(
        RNG.standard_normal(
            (n_f,) + tuple(s + 2 * r * n_steps for s in shape)
        ),
        dtype,
    )
    return opset, phi, f


# --- kernel parity vs the oracles ----------------------------------------------


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.float64])
@pytest.mark.parametrize("ndim", [2, 3])
def test_stream_matches_reference_both_ranks(ndim, dtype):
    """Rank-2 y-streaming gets the same prefetch/carried-halo kernel as
    rank-3 z-streaming, and both match the jnp oracle."""
    opset, phi, f = _problem(ndim, dtype, 1)
    out = kops.fused_stencil_nd(
        f, opset, phi, 2, strategy="swc_stream", block=BLOCKS[ndim],
        interpret=True,
    )
    expect = ref.fused_stencil(f, opset, phi)
    assert out.shape == (2,) + SHAPES[ndim]
    tol = 1e-4 if dtype == jnp.float32 else 1e-10
    np.testing.assert_allclose(
        np.asarray(out), np.asarray(expect), rtol=tol, atol=tol
    )


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.float64])
@pytest.mark.parametrize("ndim", [2, 3])
@pytest.mark.parametrize("fuse_steps", [1, 2, 3])
def test_fused_stream_matches_sequential_reference(ndim, fuse_steps, dtype):
    """Temporal fusion composes with streaming: carrying 2·r·S halo
    planes through the stream reproduces the sequential oracle."""
    opset, phi, f = _problem(ndim, dtype, fuse_steps)
    out = kops.fused_stencil_nd(
        f, opset, phi, 2, strategy="swc_stream", block=BLOCKS[ndim],
        fuse_steps=fuse_steps, interpret=True,
    )
    expect = ref.fused_stencil_steps(f, opset, phi, fuse_steps)
    tol = 2e-4 if dtype == jnp.float32 else 1e-10
    np.testing.assert_allclose(
        np.asarray(out), np.asarray(expect), rtol=tol, atol=tol
    )


def test_fused_stream_per_step_phis():
    """A per-sweep φ sequence (the RK-substep shape, sans carry) runs
    through the streaming temporal sweeps too."""
    opset = derivative_operator_set(2, 4, spacing=0.3)
    phis = (
        lambda d: d["val"] + 0.3 * d["dxx"],
        lambda d: d["val"] + 0.7 * d["dyy"],
    )
    r = opset.radius
    f = jnp.asarray(
        RNG.standard_normal((1,) + tuple(s + 4 * r for s in SHAPES[2])),
        jnp.float64,
    )
    out = kops.fused_stencil_nd(
        f, opset, phis, 1, strategy="swc_stream", block=BLOCKS[2],
        fuse_steps=2, interpret=True,
    )
    expect = ref.fused_stencil_steps(f, opset, phis, 2)
    np.testing.assert_allclose(
        np.asarray(out), np.asarray(expect), rtol=1e-10, atol=1e-10
    )


def test_diffusion_simulate_stream_parity():
    """Fused streaming diffusion (the acceptance workload) matches the
    strategy-agnostic sequential run at ranks 2 and 3."""
    for shape in ((32, 32), (16, 12, 16)):
        p = DiffusionProblem(shape, accuracy=6)
        f0 = p.init_field(seed=3)
        base = simulate(p, f0, 4, strategy="hwc")
        fused = simulate(p, f0, 4, strategy="swc_stream", fuse_steps=2)
        np.testing.assert_allclose(
            np.asarray(fused), np.asarray(base), rtol=1e-5, atol=1e-7
        )


def test_integrate_stream_remainder_resolves_own_key(
    tmp_path, monkeypatch
):
    """``n_steps % fuse_steps != 0`` under ``swc_stream``: the
    remainder launch matches the sequential run bit-for-bit in step
    count, and — with ``block="auto"`` — resolves through its OWN
    depth-``rem`` tuning key instead of inheriting the block tuned for
    the full depth (whose halo/VMEM geometry is different)."""
    from repro.tuning import TuningCache

    monkeypatch.setenv("REPRO_TUNE_CACHE", str(tmp_path))
    p = DiffusionProblem((32, 32), accuracy=6)
    f0 = p.init_field(seed=7)
    base = simulate(p, f0, 5, strategy="hwc")  # 5 = 2·2 + 1
    op = p.step_op("swc_stream", block="auto", fuse_steps=2)
    fused = integrate(op, f0, 5)
    np.testing.assert_allclose(
        np.asarray(fused), np.asarray(base), rtol=1e-5, atol=1e-7
    )
    keys = list(TuningCache().items())
    assert any("swc_stream:sy:f2|" in k for k in keys), keys
    assert any(
        "swc_stream:sy|" in k for k in keys
    ), keys  # the depth-1 remainder tuned its own record


def test_integrate_auto_depth_remainder_reresolves(tmp_path, monkeypatch):
    """``fuse_steps="auto"`` + a remainder: the shallower launch goes
    back through ``block="auto"`` (its own key) rather than reusing the
    deep-depth winner's block, and the step count stays exact."""
    from repro.tuning import TuningCache

    monkeypatch.setenv("REPRO_TUNE_CACHE", str(tmp_path))
    p = DiffusionProblem((32, 64), accuracy=6)
    f0 = p.init_field(seed=9)
    op = p.step_op("swc", block="auto", fuse_steps="auto")
    depth = op.resolved(f0).fuse_steps  # cache-warming probe
    assert depth > 1  # the traffic model picks a fused depth
    n_steps = depth + 1  # guarantees a depth-1 remainder
    out = integrate(op, f0, n_steps)
    base = simulate(p, f0, n_steps, strategy="hwc")
    np.testing.assert_allclose(
        np.asarray(out), np.asarray(base), rtol=1e-5, atol=1e-7
    )
    keys = list(TuningCache().items())
    # joint-search record plus the remainder's own depth-1 record
    assert any("|swc:fauto|" in k for k in keys), keys
    assert any("|swc|" in k for k in keys), keys


def test_stream_rejects_unroll_and_aux():
    opset, phi, f = _problem(2, jnp.float32, 1)
    with pytest.raises(ValueError, match="unroll"):
        plan_stencil(opset, f.shape, 2, strategy="swc_stream", unroll=2)
    with pytest.raises(ValueError, match="aux"):
        plan_stencil(opset, f.shape, 2, strategy="swc_stream", n_aux=1)


def test_fused_stream_too_small_stream_axis_raises():
    """The fused stream walk needs the stream axis to hold the carried
    halo (2·r·S planes) plus one chunk: a domain below that bound is
    rejected at plan validation with a clear error instead of failing
    deep in the emitter, and the planner first tries to shrink the
    chunk (the self-healing path for default/auto blocks)."""
    opset = derivative_operator_set(2, 6, spacing=0.3)  # r = 3
    # y interior 8 < 2·3·2 + 1: no chunk size can satisfy the bound.
    padded = (1, 8 + 12, 64 + 12)
    with pytest.raises(ValueError, match="carried halo plus one chunk"):
        plan_stencil(
            opset, padded, 1, strategy="swc_stream", fuse_steps=2
        )
    # y interior 16: cap = 16 - 12 = 4 — the default (16, 128) block's
    # stream chunk self-heals to 4 instead of raising.
    plan = plan_stencil(
        opset, (1, 16 + 12, 64 + 12), 1, strategy="swc_stream",
        fuse_steps=2,
    )
    assert plan.block[0] == 4
    # depth 1 carries no fused halo: the bound does not apply.
    plan_stencil(opset, (1, 8 + 6, 64 + 6), 1, strategy="swc_stream")


# --- tuning keys: stream axis × depth ------------------------------------------


def test_tuning_key_stream_depth_uniqueness():
    """Every (strategy, stream axis, depth) combination keys its own
    cache record, and re-derivation is stable."""
    ids = {}
    for ndim in (2, 3):
        for strat in ("swc", "swc_stream"):
            for depth in (1, 2):
                opset, _, f = _problem(ndim, jnp.float32, depth)
                plan = plan_stencil(
                    opset, f.shape, 2, strategy=strat, fuse_steps=depth
                )
                key = plan.tuning_key("cpu")
                again = plan_stencil(
                    opset, f.shape, 2, strategy=strat, fuse_steps=depth
                ).tuning_key("cpu")
                assert key.cache_id == again.cache_id
                ids[(ndim, strat, depth)] = key.cache_id
    assert len(set(ids.values())) == len(ids)
    # the stream axis letter is part of the strategy id
    opset2, _, f2 = _problem(2, jnp.float32, 2)
    plan2 = plan_stencil(
        opset2, f2.shape, 2, strategy="swc_stream", fuse_steps=2
    )
    # _problem builds accuracy-4 opsets: the non-default order joins
    # the id as the final :o4 suffix.
    assert plan2.strategy_id == "swc_stream:sy:f2:o4"
    opset3, _, f3 = _problem(3, jnp.float32, 1)
    plan3 = plan_stencil(opset3, f3.shape, 2, strategy="swc_stream")
    assert plan3.strategy_id == "swc_stream:sz:o4"


# --- traffic model + auto resolution -------------------------------------------


def test_stream_traffic_model_drops_stream_axis_refetch():
    """The streaming model reads each cross-stream column once (plus one
    carried halo) where the pipelined model re-fetches the stream-axis
    halo per block — so for halo-bound tilings streaming models strictly
    less HBM traffic, and the joint enumeration can rank a streaming
    candidate first."""
    domain, radii = (256, 256, 256), (3, 3, 3)
    block = (8, 32, 256)
    pipe = stencil_hbm_bytes_per_step(domain, block, radii, 1, 1, 4, 2)
    stream = stencil_stream_hbm_bytes_per_step(
        domain, block, radii, 1, 1, 4, 2
    )
    assert stream < pipe
    cands = enumerate_candidates_nd(
        domain, radii, 1, 1, 4,
        fuse_steps_options=(1, 2, 3, 4),
        stream_options=(False, True),
    )
    assert cands[0].stream, cands[0]


def test_stream_auto_depth_resolves_and_matches_reference(
    tmp_path, monkeypatch
):
    """``strategy="swc_stream", block="auto", fuse_steps="auto"`` picks
    a fused streaming configuration from the traffic model, persists it
    under the stream-axis ``:fauto`` key, and matches the sequential
    reference at the chosen depth."""
    monkeypatch.setenv("REPRO_TUNE_CACHE", str(tmp_path))
    p = DiffusionProblem((64, 128), accuracy=6)
    op = p.step_op("swc_stream", block="auto", fuse_steps="auto")
    f0 = p.init_field(seed=5)
    out = jax.jit(op)(f0)  # traced: structural (cost-model) winner
    rec = lookup_fused_nd(f0, op.ops, 1, "swc_stream", fuse_steps="auto")
    assert rec is not None and rec.source == "model"
    assert rec.fuse_steps > 1
    from repro.tuning import TuningCache

    key_ids = list(TuningCache().items())
    assert any("swc_stream:sy:fauto" in k for k in key_ids), key_ids
    expect = integrate(p.step_op("hwc"), f0, rec.fuse_steps)
    np.testing.assert_allclose(
        np.asarray(out), np.asarray(expect), rtol=2e-5, atol=1e-7
    )
