"""``strategy="auto"`` — the cross-strategy tuning loop.

Covers the PR acceptance criteria: resolution parity against each
concrete strategy (rank × dtype), the schema-v2 record round-trip
(cold-measure → cache-write → warm-hit reproducing the identical
(strategy, block, depth, stream) tuple with zero re-measurement, in
this process and a fresh one), the jit-traced structural path, the
cost-model unit behavior (a cache-heavy shape picks ``swc_stream``, a
tiny shape falls back to ``hwc``), and the warm-cache regression for
the previously-dropped ``stream`` flag.
"""
import os
import subprocess
import sys
from pathlib import Path

import jax

jax.config.update("jax_enable_x64", True)

import jax.numpy as jnp  # noqa: E402
import numpy as np  # noqa: E402
import pytest  # noqa: E402

from repro.core.fusion import FusedStencilOp, integrate  # noqa: E402
from repro.core.stencil import derivative_operator_set  # noqa: E402
from repro.kernels.plan import plan_from_record  # noqa: E402
from repro.physics.diffusion import DiffusionProblem  # noqa: E402
from repro.tuning import (  # noqa: E402
    SCHEMA_VERSION,
    TuningCache,
    TuningRecord,
    enumerate_cross_strategy_nd,
    fused_nd_key,
    lookup_fused_nd,
)
from repro.tuning import session as sess_mod  # noqa: E402

SRC = str(Path(__file__).resolve().parent.parent / "src")

SHAPES = {1: (1 << 10,), 2: (32, 64), 3: (16, 12, 16)}


@pytest.fixture
def cache_dir(tmp_path, monkeypatch):
    monkeypatch.setenv("REPRO_TUNE_CACHE", str(tmp_path))
    return tmp_path


# --- resolution parity (rank × dtype) ------------------------------------------


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.float64])
@pytest.mark.parametrize("ndim", [1, 2, 3])
def test_auto_resolves_concrete_and_matches_reference(
    cache_dir, ndim, dtype
):
    """``strategy="auto"`` resolves to one of the concrete regimes and
    its output matches the sequential hwc reference at the chosen
    depth, at every rank and dtype."""
    p = DiffusionProblem(SHAPES[ndim], accuracy=6)
    f0 = jnp.asarray(p.init_field(seed=1), dtype)
    op = p.step_op("auto", fuse_steps="auto")
    assert op.block == "auto"  # coerced from None: auto owns the block
    rop = op.resolved(f0)
    assert rop.strategy in ("hwc", "swc", "swc_stream", "tc")
    if dtype == jnp.float64:
        assert rop.strategy != "tc"  # MXU regime is f32/bf16-only
    assert isinstance(rop.block, tuple) and len(rop.block) == ndim
    assert rop.fuse_steps >= 1
    if ndim == 1:
        assert rop.strategy != "swc_stream"  # no cross-stream axis
    out = op(f0)  # __call__ resolves then applies
    expect = integrate(p.step_op("hwc"), f0, rop.fuse_steps)
    tol = 2e-5 if dtype == jnp.float32 else 1e-10
    np.testing.assert_allclose(
        np.asarray(out), np.asarray(expect), rtol=tol, atol=tol
    )


def test_auto_parity_vs_each_concrete_strategy(cache_dir):
    """Whatever regime auto picks, forcing each concrete strategy at
    the resolved (block, depth) produces the same numerics — the
    resolved op is an ordinary member of the concrete family."""
    p = DiffusionProblem((32, 64), accuracy=6)
    f0 = p.init_field(seed=2)
    rop = p.step_op("auto", fuse_steps="auto").resolved(f0)
    auto_out = np.asarray(rop(f0))
    concrete = FusedStencilOp(
        rop.ops, rop.phi, 1, strategy=rop.strategy, block=rop.block,
        fuse_steps=rop.fuse_steps,
    )
    np.testing.assert_array_equal(auto_out, np.asarray(concrete(f0)))


# --- record round-trip (acceptance criterion) ----------------------------------


def test_auto_round_trips_bit_identically_through_cache(cache_dir):
    """Cold measure → cache write → warm hit: the warm resolution is
    the identical (strategy, block, depth) tuple, takes zero new
    measurements, and reproduces the output bit-for-bit. A second
    process replays the same record from disk."""
    p = DiffusionProblem((32, 64), accuracy=6)
    f0 = p.init_field(seed=3)
    op = p.step_op("auto", fuse_steps="auto")
    r1 = op.resolved(f0)  # cold: measures and persists
    out1 = np.asarray(r1(f0))
    rec = lookup_fused_nd(f0, op.ops, 1, "auto", fuse_steps="auto")
    assert rec is not None and rec.source == "measured"
    assert rec.schema == SCHEMA_VERSION
    assert rec.strategy_resolved == r1.strategy
    assert rec.stream == (r1.strategy == "swc_stream")

    before = sess_mod.MEASURE_COUNT
    r2 = p.step_op("auto", fuse_steps="auto").resolved(f0)
    assert sess_mod.MEASURE_COUNT == before  # warm hit: no re-measure
    assert (r2.strategy, r2.block, r2.fuse_steps) == (
        r1.strategy, r1.block, r1.fuse_steps,
    )
    np.testing.assert_array_equal(out1, np.asarray(r2(f0)))

    # The plan the record reconstructs is the plan the kernel runs.
    plan = plan_from_record(op.ops, f0.shape, 1, rec, dtype="float32")
    if r1.strategy == "hwc":
        assert plan is None
    else:
        assert plan.strategy == r1.strategy
        assert plan.fuse_steps == r1.fuse_steps

    # Fresh process: replay from disk with ZERO measurements.
    code = """
import numpy as np
import jax.numpy as jnp
from repro.physics.diffusion import DiffusionProblem
from repro.tuning import session as sess_mod

p = DiffusionProblem((32, 64), accuracy=6)
f0 = p.init_field(seed=3)
rop = p.step_op("auto", fuse_steps="auto").resolved(f0)
assert sess_mod.MEASURE_COUNT == 0, sess_mod.MEASURE_COUNT
print(f"REPLAYED {rop.strategy} {rop.block} {rop.fuse_steps}")
"""
    env = dict(os.environ)
    env["REPRO_TUNE_CACHE"] = str(cache_dir)
    env["PYTHONPATH"] = SRC + os.pathsep + env.get("PYTHONPATH", "")
    res = subprocess.run(
        [sys.executable, "-c", code], capture_output=True, text=True,
        env=env,
    )
    assert res.returncode == 0, res.stderr
    assert (
        f"REPLAYED {r1.strategy} {r1.block} {r1.fuse_steps}"
        in res.stdout
    )


def test_warm_hit_reproduces_stream_winner_without_remeasure(cache_dir):
    """THE regression this PR fixes: a persisted ``stream=True`` winner
    survives the cache round trip — the warm hit resolves back to
    ``swc_stream`` at the recorded block/depth without re-measuring
    (pre-v2 records had no ``stream``/``strategy_resolved`` fields, so
    the streaming decision was silently dropped)."""
    p = DiffusionProblem((64, 128), accuracy=6)
    f0 = p.init_field(seed=4)
    key = fused_nd_key(
        (64, 128), (3, 3), 1, 1, "float32", "auto", fuse_steps="auto"
    )
    assert ":sauto" in key.strategy and ":fauto" in key.strategy
    TuningCache().put(
        key,
        TuningRecord(
            block=(4, 128), timings_us={"4x128@f2:s": 5.0},
            source="measured", fuse_steps=2, stream=True,
            strategy_resolved="swc_stream",
        ),
    )
    before = sess_mod.MEASURE_COUNT
    rop = p.step_op("auto", fuse_steps="auto").resolved(f0)
    assert sess_mod.MEASURE_COUNT == before  # warm hit, no re-measure
    assert rop.strategy == "swc_stream"
    assert rop.block == (4, 128) and rop.fuse_steps == 2
    # ...and the reproduced op actually runs as a fused stream.
    base = integrate(p.step_op("hwc"), f0, 2)
    np.testing.assert_allclose(
        np.asarray(rop(f0)), np.asarray(base), rtol=2e-5, atol=1e-7
    )


def test_stream_flag_persisted_on_fauto_records(cache_dir):
    """The per-strategy ``swc_stream:…:fauto`` joint search also writes
    the stream flag through to disk (the raw JSON), so schema-v2
    records are self-describing about the regime they encode."""
    import json

    p = DiffusionProblem((64, 128), accuracy=6)
    f0 = p.init_field(seed=5)
    jax.jit(p.step_op("swc_stream", block="auto", fuse_steps="auto"))(f0)
    raw = json.loads((cache_dir / "cache.json").read_text())
    stream_recs = [
        r for k, r in raw["records"].items()
        if "swc_stream:sy:fauto" in k
    ]
    assert stream_recs, list(raw["records"])
    assert all(r["stream"] is True for r in stream_recs)
    assert all(
        r["strategy_resolved"] == "swc_stream" for r in stream_recs
    )


# --- jit-traced structural path ------------------------------------------------


def test_auto_under_jit_uses_structural_winner(cache_dir):
    """Under tracing nothing can be measured: the cross-strategy search
    records the cost-model winner (``source="model"``) and the traced
    computation still matches the reference at the chosen depth."""
    p = DiffusionProblem((64, 128), accuracy=6)
    f0 = p.init_field(seed=6)
    op = p.step_op("auto", fuse_steps="auto")
    out = jax.jit(op)(f0)
    rec = lookup_fused_nd(f0, op.ops, 1, "auto", fuse_steps="auto")
    assert rec is not None and rec.source == "model"
    assert rec.strategy_resolved in ("hwc", "swc", "swc_stream", "tc")
    expect = integrate(p.step_op("hwc"), f0, int(rec.fuse_steps))
    np.testing.assert_allclose(
        np.asarray(out), np.asarray(expect), rtol=2e-5, atol=1e-7
    )


# --- cost model ----------------------------------------------------------------


def test_cross_strategy_costmodel_shape_dependence():
    """The paper's Fig. 5 finding as a unit test: a cache-heavy 3-D
    shape (large domain, wide halo) structurally prefers fused explicit
    streaming, while a tiny depth-1 shape falls back to the hwc
    baseline (no Pallas config models below the compulsory-traffic
    floor)."""
    heavy = enumerate_cross_strategy_nd(
        (256, 256, 256), (3, 3, 3), 1, 1, 4,
        fuse_steps_options=(1, 2, 3, 4),
    )
    assert heavy[0].strategy == "swc_stream"
    assert heavy[0].fuse_steps > 1
    tiny = enumerate_cross_strategy_nd(
        (8, 16), (1, 1), 2, 1, 4, fuse_steps_options=(1,)
    )
    assert tiny[0].strategy == "hwc"
    assert tiny[0].score == 1.0  # the modeled-traffic floor
    # the hwc floor is always present, so the space is never empty
    assert any(c.strategy == "hwc" for c in heavy)


def test_hwc_floor_only_beaten_by_sub_compulsory_traffic():
    """At depth 1 every blocked candidate re-fetches halo (> floor), so
    hwc ranks first; opening the depth axis lets fused candidates model
    sub-compulsory per-step traffic and overtake it."""
    d1 = enumerate_cross_strategy_nd(
        (64, 128), (3, 3), 1, 1, 4, fuse_steps_options=(1,)
    )
    assert d1[0].strategy == "hwc"
    joint = enumerate_cross_strategy_nd(
        (64, 128), (3, 3), 1, 1, 4, fuse_steps_options=(1, 2, 3, 4)
    )
    assert joint[0].strategy != "hwc"
    assert joint[0].score < 1.0


# --- validation ----------------------------------------------------------------


def test_auto_validation_surface():
    """strategy='auto' owns the block: None is coerced, an explicit
    tile rejected; apply_padded demands a resolved op."""
    opset = derivative_operator_set(2, 4, spacing=0.5)

    def phi(d):
        return d["val"]

    op = FusedStencilOp(opset, phi, 1, strategy="auto")
    assert op.block == "auto"
    with pytest.raises(ValueError, match="block='auto'"):
        FusedStencilOp(
            opset, phi, 1, strategy="auto", block=(8, 16)
        )
    with pytest.raises(ValueError, match="resolve"):
        op.apply_padded(jnp.zeros((1, 20, 20)))
    # fuse_steps='auto' composes with strategy='auto'
    op2 = FusedStencilOp(
        opset, phi, 1, strategy="auto", fuse_steps="auto"
    )
    assert op2.needs_resolution


def test_hwc_baseline_always_measured_on_eager_resolution(cache_dir):
    """Even when fused candidates structurally out-rank hwc out of the
    top-k window, the eager cross-strategy search still TIMES the XLA
    baseline — the record's timing table must contain the ``hwc`` row
    (the contract: hwc is the measured baseline, not just a modeled
    floor)."""
    p = DiffusionProblem((64, 64), accuracy=6)
    f0 = p.init_field(seed=10)
    heavy = enumerate_cross_strategy_nd(
        (64, 64), (3, 3), 1, 1, 4, fuse_steps_options=(1, 2, 3, 4)
    )
    hwc_rank = next(
        i for i, c in enumerate(heavy) if c.strategy == "hwc"
    )
    assert hwc_rank >= 4  # structurally outside the default top-k
    p.step_op("auto", fuse_steps="auto").resolved(f0)
    rec = lookup_fused_nd(
        f0, p.step_op("hwc").ops, 1, "auto", fuse_steps="auto"
    )
    assert rec.source == "measured"
    assert "hwc" in rec.timings_us, rec.timings_us


def test_auto_pinned_depth_on_non_selfmap_raises(cache_dir):
    """An explicitly requested fuse_steps > 1 on a non-self-map op
    raises under strategy='auto' too (mirroring plan validation)
    instead of silently advancing fewer steps than asked."""
    opset = derivative_operator_set(2, 4, spacing=0.5)

    def phi(d):
        return d["val"][:1]  # n_out=1 != n_f=2: not a self-map

    f = jnp.zeros((2, 16, 32), jnp.float32)
    op = FusedStencilOp(opset, phi, 1, strategy="auto", fuse_steps=3)
    with pytest.raises(ValueError, match="self-map"):
        op.resolved(f)


def test_auto_with_fixed_depth_pins_search(cache_dir):
    """An int fuse_steps under strategy='auto' searches strategies at
    exactly that depth and keys without the ``:fauto`` suffix."""
    p = DiffusionProblem((32, 64), accuracy=6)
    f0 = p.init_field(seed=8)
    rop = p.step_op("auto", fuse_steps=2).resolved(f0)
    assert rop.fuse_steps == 2
    keys = list(TuningCache().items())
    assert any("auto:sauto:f2|" in k for k in keys), keys


def test_auto_mhd_rhs_depth_stays_one(cache_dir):
    """MHDSolver(strategy='auto'): the RHS op searches strategy/block
    but keeps depth 1 (the RHS is not a time step), and matches hwc."""
    from repro.physics.mhd import MHDSolver

    n = 8
    base = MHDSolver((n, n, n), strategy="hwc", accuracy=2)
    auto = MHDSolver((n, n, n), strategy="auto", accuracy=2)
    assert auto.op_block == "auto"
    f = base.init_smooth(seed=0, amplitude=1e-3, dtype=jnp.float64)
    rop = auto.rhs_op().resolved(f)
    assert rop.fuse_steps == 1
    r0 = base.rhs(f)
    r1 = auto.rhs(f)
    rel = float(jnp.abs(r1 - r0).max() / jnp.abs(r0).max())
    assert rel < 1e-10
