"""Distribution tests on 8 fake CPU devices: halo exchange vs
single-device, sharding rules, grad sync utilities, checkpoint
elasticity, and the fault-tolerance supervisor."""
import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
import numpy as np  # noqa: E402
import pytest  # noqa: E402
from jax.sharding import NamedSharding, PartitionSpec as P  # noqa: E402

from repro.core.fusion import FusedStencilOp  # noqa: E402
from repro.core.stencil import derivative_operator_set  # noqa: E402
from repro.distrib import sharding as shlib  # noqa: E402
from repro.distrib.grad_sync import (  # noqa: E402
    accumulate_grads,
    compressed_psum_tree,
    hierarchical_psum,
)
from repro.launch.mesh import make_mesh  # noqa: E402


def _shard_map(fn, mesh, in_specs, out_specs):
    if hasattr(jax, "shard_map"):
        return jax.shard_map(fn, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, check_vma=False)
    # jax <= 0.4.x spells it jax.experimental.shard_map with check_rep.
    from jax.experimental.shard_map import shard_map

    return shard_map(fn, mesh=mesh, in_specs=in_specs,
                     out_specs=out_specs, check_rep=False)


def test_sharded_stencil_matches_single_device():
    ops = derivative_operator_set(3, 6, spacing=0.3)

    def phi(d):
        return jnp.stack([
            d["val"][0] + 0.1 * (d["dxx"] + d["dyy"] + d["dzz"])[0],
            d["dx"][1] * d["dy"][0] + d["dxy"][1],
        ])

    op = FusedStencilOp(ops, phi, 2, strategy="hwc")
    rng = np.random.default_rng(0)
    f = jnp.asarray(rng.standard_normal((2, 8, 16, 32)), jnp.float32)
    expect = op(f)

    mesh = make_mesh((2, 4), ("data", "model"))
    fn = _shard_map(
        lambda fl: op.apply_sharded(fl, (None, "data", "model")),
        mesh,
        P(None, None, "data", "model"),
        P(None, None, "data", "model"),
    )
    out = jax.jit(fn)(f)
    np.testing.assert_allclose(
        np.asarray(out), np.asarray(expect), rtol=1e-5, atol=1e-5
    )


def test_sharded_overlap_matches_non_overlapped():
    """overlap=True (interior_first compute/communication overlap
    decomposition) must be a pure scheduling change: numerics match the
    plain exchange-then-apply path and the single-device reference."""
    ops = derivative_operator_set(3, 6, spacing=0.3)

    def phi(d):
        return jnp.stack([
            d["val"][0] + 0.1 * (d["dxx"] + d["dyy"] + d["dzz"])[0],
            d["dx"][1] * d["dy"][0] + d["dxy"][1],
        ])

    op = FusedStencilOp(ops, phi, 2, strategy="hwc")
    rng = np.random.default_rng(5)
    f = jnp.asarray(rng.standard_normal((2, 8, 16, 32)), jnp.float32)
    expect = op(f)

    mesh = make_mesh((2, 4), ("data", "model"))
    axes = (None, "data", "model")

    def run(overlap):
        fn = _shard_map(
            lambda fl: op.apply_sharded(fl, axes, overlap=overlap),
            mesh,
            P(None, None, "data", "model"),
            P(None, None, "data", "model"),
        )
        return jax.jit(fn)(f)

    plain, overlapped = run(False), run(True)
    np.testing.assert_allclose(
        np.asarray(overlapped), np.asarray(plain), rtol=1e-6, atol=1e-6
    )
    np.testing.assert_allclose(
        np.asarray(overlapped), np.asarray(expect), rtol=1e-5, atol=1e-5
    )


def test_sharded_temporal_fusion_matches_single_device():
    """fuse_steps=2 over the mesh: ONE widened halo exchange
    (radius·depth planes per axis) buys two time steps, and numerics
    match two single-device applications."""
    ops = derivative_operator_set(3, 6, spacing=0.3)

    def phi(d):
        return jnp.stack([
            d["val"][0] + 0.1 * (d["dxx"] + d["dyy"] + d["dzz"])[0],
            d["val"][1] + 0.05 * d["dx"][1] * d["dy"][0],
        ])

    rng = np.random.default_rng(9)
    f = jnp.asarray(rng.standard_normal((2, 8, 16, 32)), jnp.float32)
    single = FusedStencilOp(ops, phi, 2, strategy="hwc")
    expect = single(single(f))  # two sequential steps

    fused = FusedStencilOp(ops, phi, 2, strategy="hwc", fuse_steps=2)
    mesh = make_mesh((2, 4), ("data", "model"))
    fn = _shard_map(
        lambda fl: fused.apply_sharded(fl, (None, "data", "model")),
        mesh,
        P(None, None, "data", "model"),
        P(None, None, "data", "model"),
    )
    out = jax.jit(fn)(f)
    np.testing.assert_allclose(
        np.asarray(out), np.asarray(expect), rtol=1e-4, atol=1e-4
    )


def test_sharded_overlap_depth2_matches_non_overlapped(monkeypatch):
    """overlap=True no longer falls back at depth > 1: the interior-
    first decomposition widens to radius·fuse_steps (with the aux carry
    exchanged at radius·(fuse_steps-1)) and matches the plain
    exchange-then-apply path up to float reassociation."""
    ops = derivative_operator_set(3, 6, spacing=0.3)

    def mk_phi(c):
        def phi(d, a):
            f_new = d["val"] + c * d["dxx"] + 0.1 * a * d["dyy"][:1]
            w_new = 0.5 * a + c * d["val"][:1]
            return jnp.concatenate([f_new, w_new])

        return phi

    # Local sharded extents (32/2, 64/4) = (16, 16) must EXCEED
    # 2·radius·fuse_steps = 12, else the decomposition (correctly)
    # falls back to the plain path and the test is vacuous; the spy
    # below asserts the overlap path really engaged.
    rng = np.random.default_rng(13)
    f = jnp.asarray(rng.standard_normal((2, 8, 32, 64)), jnp.float32)
    aux = jnp.asarray(rng.standard_normal((1, 8, 32, 64)), jnp.float32)
    op = FusedStencilOp(
        ops, (mk_phi(0.3), mk_phi(0.7)), 3, strategy="hwc", fuse_steps=2
    )
    expect = op(f, aux)

    engaged = []
    orig = FusedStencilOp._apply_sharded_overlap

    def spy(self, *args, **kwargs):
        out = orig(self, *args, **kwargs)
        engaged.append(out is not None)
        return out

    monkeypatch.setattr(FusedStencilOp, "_apply_sharded_overlap", spy)

    mesh = make_mesh((2, 4), ("data", "model"))
    axes = (None, "data", "model")

    def run(overlap):
        fn = _shard_map(
            lambda fl, al: op.apply_sharded(fl, axes, al, overlap=overlap),
            mesh,
            (P(None, None, "data", "model"), P(None, None, "data", "model")),
            P(None, None, "data", "model"),
        )
        return jax.jit(fn)(f, aux)

    plain, overlapped = run(False), run(True)
    assert engaged and all(engaged), "overlap decomposition fell back"
    # scheduling change only: plain-path parity up to f32 reassociation
    np.testing.assert_allclose(
        np.asarray(overlapped), np.asarray(plain), rtol=1e-4, atol=1e-4
    )
    np.testing.assert_allclose(
        np.asarray(overlapped), np.asarray(expect), rtol=1e-4, atol=1e-3
    )


def test_apply_sharded_rejects_mismatched_mesh_axes():
    """A mesh_axes list that doesn't cover every spatial dim is a clear
    ValueError up front (not a confusing zip truncation downstream)."""
    ops = derivative_operator_set(3, 2, spacing=0.3)
    op = FusedStencilOp(ops, lambda d: d["val"], 2, strategy="hwc")
    f = jnp.zeros((2, 8, 8, 8), jnp.float32)
    with pytest.raises(ValueError, match="mesh_axes has 2 entries"):
        op.apply_sharded(f, ("data", "model"))
    with pytest.raises(ValueError, match="spatial dim"):
        op.apply_sharded(f, (None, None, "data", "model"))


def test_param_spec_rules():
    mesh = make_mesh((2, 4), ("data", "model"))
    # TP on attention projections
    spec = shlib.param_spec("blocks/wq", (4, 64, 128), mesh)
    assert spec == P(None, None, "model")
    # kv heads too small to shard → replicated (trailing Nones stripped)
    spec = shlib.param_spec("blocks/wk", (4, 64, 2), mesh)
    assert spec == P()
    # MoE: experts ≥ mesh → EP
    spec = shlib.param_spec("blocks/moe/w_gate", (2, 8, 16, 32), mesh)
    assert spec == P(None, "model")
    # MoE: experts < mesh → expert-TP fallback on d_ff
    spec = shlib.param_spec("blocks/moe/w_gate", (2, 2, 16, 32), mesh)
    assert spec == P(None, None, None, "model")
    # FSDP shards the biggest free dim over data
    spec = shlib.param_spec("blocks/wq", (4, 64, 128), mesh, fsdp=True)
    assert spec == P(None, "data", "model")


def test_compressed_psum_tree():
    mesh = make_mesh((8,), ("data",))
    g = {"w": jnp.asarray(np.random.default_rng(0).standard_normal((8, 64)),
                          jnp.float32)}

    def fn(gl):
        synced, resid = compressed_psum_tree(gl, "data")
        return synced, resid

    out, resid = jax.jit(
        _shard_map(fn, mesh, P("data", None), (P(None), P("data", None)))
    )(g["w"])
    expect = np.asarray(g["w"]).reshape(8, 1, 64).sum(0)
    # bf16 wire: ~1e-2 relative accuracy per element
    np.testing.assert_allclose(
        np.asarray(out)[0], expect[0], rtol=5e-2, atol=5e-2
    )
    # error feedback captures the residual
    assert float(jnp.abs(resid).max()) > 0.0


def test_hierarchical_psum_matches_flat():
    mesh = make_mesh((2, 4), ("pod", "data"))
    x = jnp.asarray(
        np.random.default_rng(1).standard_normal((8, 16)), jnp.float32
    )

    def hier(xl):
        return hierarchical_psum(xl, "data", "pod")

    def flat(xl):
        return jax.lax.psum(xl, ("pod", "data"))

    a = jax.jit(_shard_map(hier, mesh, P(("pod", "data"), None), P(None)))(x)
    b = jax.jit(_shard_map(flat, mesh, P(("pod", "data"), None), P(None)))(x)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-5)


def test_accumulate_grads_matches_full_batch():
    def loss_fn(p, batch):
        pred = batch["x"] @ p["w"]
        return jnp.mean((pred - batch["y"]) ** 2), {}

    rng = np.random.default_rng(2)
    p = {"w": jnp.asarray(rng.standard_normal((8, 4)), jnp.float32)}
    x = jnp.asarray(rng.standard_normal((4, 16, 8)), jnp.float32)
    y = jnp.asarray(rng.standard_normal((4, 16, 4)), jnp.float32)
    loss_acc, g_acc = accumulate_grads(loss_fn, p, {"x": x, "y": y})
    (loss_full, _), g_full = jax.value_and_grad(loss_fn, has_aux=True)(
        p, {"x": x.reshape(64, 8), "y": y.reshape(64, 4)}
    )
    np.testing.assert_allclose(float(loss_acc), float(loss_full), rtol=1e-6)
    np.testing.assert_allclose(
        np.asarray(g_acc["w"]), np.asarray(g_full["w"]), rtol=1e-5, atol=1e-6
    )


def test_checkpoint_roundtrip_and_elastic_reshard(tmp_path):
    from repro.checkpoint import CheckpointManager

    mgr = CheckpointManager(str(tmp_path), keep=2)
    tree = {
        "a": jnp.arange(32, dtype=jnp.float32).reshape(4, 8),
        "nested": {"b": jnp.ones((16,), jnp.int32)},
    }
    mgr.save(10, tree, blocking=True)
    mgr.save(20, tree, blocking=True)
    mgr.save(30, tree, blocking=True)
    assert mgr.all_steps() == [20, 30]  # keep=2 retention

    # restore onto a DIFFERENT sharding layout (elastic path)
    mesh = make_mesh((4, 2), ("data", "model"))
    shardings = {
        "a": NamedSharding(mesh, P("data", "model")),
        "nested": {"b": NamedSharding(mesh, P(None))},
    }
    restored, step = mgr.restore(tree, shardings=shardings)
    assert step == 30
    np.testing.assert_array_equal(np.asarray(restored["a"]),
                                  np.asarray(tree["a"]))
    assert restored["a"].sharding.spec == P("data", "model")


def test_supervisor_recovers_from_failure(tmp_path):
    from repro.checkpoint import CheckpointManager
    from repro.ft import Supervisor

    mgr = CheckpointManager(str(tmp_path), keep=3)
    sup = Supervisor(mgr, ckpt_every=5)
    trace = []

    def step_fn(state, step):
        trace.append(step)
        return {"x": state["x"] + 1}

    def restore(state, step):
        if step is None:
            return {"x": jnp.zeros(())}, 0
        restored, got = mgr.restore(state, step)
        return restored, got

    state, report = sup.run(
        {"x": jnp.zeros(())}, step_fn, 20,
        failure_at=12, restore_fn=restore,
    )
    assert report["restarts"] == 1
    assert float(state["x"]) == 20  # exact replay: 10 (ckpt) + 10 more
    # steps 10 and 11 replayed after restore from step 10
    assert trace.count(10) == 2 and trace.count(11) == 2


def test_straggler_monitor():
    from repro.ft import StragglerMonitor

    mon = StragglerMonitor(factor=1.5, window=10)
    flagged = []
    for i in range(10):
        flagged.append(mon.record(i, 0.1))
    assert not any(flagged)
    assert mon.record(10, 0.3)  # 3× median


def test_data_pipeline_determinism_and_sharding():
    from repro.data import BatchIterator, MarkovLMDataset

    ds = MarkovLMDataset(vocab=64, seq_len=16, branching=4, seed=7)
    # Host shards partition the global batch exactly.
    full = BatchIterator(ds, 8, host_index=0, host_count=1).next_local()
    h0 = BatchIterator(ds, 8, host_index=0, host_count=2).next_local()
    h1 = BatchIterator(ds, 8, host_index=1, host_count=2).next_local()
    np.testing.assert_array_equal(
        np.concatenate([h0["tokens"], h1["tokens"]]), full["tokens"]
    )
    # Replays are bit-identical (the ft recovery contract).
    again = BatchIterator(ds, 8, host_index=0, host_count=2).next_local()
    np.testing.assert_array_equal(h0["tokens"], again["tokens"])
    # Markov property: every transition comes from the chain's table.
    table = ds._table()
    tok = full["tokens"]
    for row in tok:
        for t in range(len(row) - 1):
            assert row[t + 1] in table[row[t]]
