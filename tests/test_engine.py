"""Rank-generic fused stencil engine tests: the StencilPlan lowering
layer (planner validation/clamping), swc-vs-hwc parity across rank ∈
{1, 2, 3} × dtype ∈ {float32, float64} × non-block-divisible shapes,
element-wise unrolling, and plan-keyed ``block="auto"`` resolution
through the persistent tuning cache at every rank."""
import jax

jax.config.update("jax_enable_x64", True)

import jax.numpy as jnp  # noqa: E402
import numpy as np  # noqa: E402
import pytest  # noqa: E402

from repro.core.fusion import FusedStencilOp  # noqa: E402
from repro.core.stencil import derivative_operator_set  # noqa: E402
from repro.kernels import ops as kops  # noqa: E402
from repro.kernels import ref  # noqa: E402
from repro.kernels.plan import plan_stencil  # noqa: E402
from repro.tuning import TuningCache  # noqa: E402

RNG = np.random.default_rng(11)

# Deliberately not divisible by the per-rank default blocks.
SHAPES = {1: (200,), 2: (12, 36), 3: (6, 10, 24)}


def _problem(ndim, dtype, accuracy=4, n_f=2):
    """An OperatorSet + nonlinear phi + padded operand at ``ndim``."""
    opset = derivative_operator_set(ndim, accuracy, spacing=0.3)
    names = opset.names

    def phi(d):
        acc = sum(d[n] for n in names)
        return jnp.stack(
            [jnp.tanh(acc[0]) + d["val"][-1] * d["dx"][0], acc[-1] * 0.5]
        )

    r = opset.radius
    shape = SHAPES[ndim]
    f = jnp.asarray(
        RNG.standard_normal((n_f,) + tuple(s + 2 * r for s in shape)),
        dtype,
    )
    return opset, phi, f


# --- planner -------------------------------------------------------------------


def test_plan_defaults_and_clamping():
    for ndim in (1, 2, 3):
        opset, _, f = _problem(ndim, jnp.float32)
        plan = plan_stencil(opset, f.shape, 2)
        assert plan.rank == ndim
        assert plan.interior == SHAPES[ndim]
        # clamped blocks always tile the interior exactly
        for n, t in zip(plan.interior, plan.block):
            assert n % t == 0


def test_plan_truncates_longer_blocks_x_last():
    opset, _, f = _problem(2, jnp.float32)
    plan = plan_stencil(opset, f.shape, 1, block=(8, 8, 128))
    assert plan.rank == 2
    # trailing (y, x) entries kept, then clamped to divisors of (12, 36)
    assert plan.block == (6, 36)


def test_plan_accepts_swc_stream_rank2_rejects_rank1():
    """swc_stream is a rank-2/3 plan attribute (y-/z-streaming); rank 1
    has no cross-stream tile axis and is rejected up front."""
    opset, _, f = _problem(2, jnp.float32)
    plan = plan_stencil(opset, f.shape, 1, strategy="swc_stream")
    assert plan.stream_axis == 0 and plan.stream_axis_letter == "y"
    assert plan.strategy_id.startswith("swc_stream:sy")
    opset1, _, f1 = _problem(1, jnp.float32)
    with pytest.raises(ValueError, match="rank 2"):
        plan_stencil(opset1, f1.shape, 1, strategy="swc_stream")
    with pytest.raises(ValueError, match="swc_stream"):
        FusedStencilOp(opset1, lambda d: d["val"], 1, strategy="swc_stream")


def test_plan_unroll_degrades_when_not_divisible():
    opset, _, f = _problem(1, jnp.float32)  # interior 200
    plan = plan_stencil(opset, f.shape, 1, block=(32,), unroll=7)
    assert plan.unroll == 1  # 200 % 7 != 0 → element-wise unroll dropped
    plan = plan_stencil(opset, f.shape, 1, block=(32,), unroll=2)
    assert plan.unroll == 2 and (plan.block[-1] * 2) <= 200
    assert 200 % (plan.block[-1] * 2) == 0


def test_plan_tuning_keys_distinct_and_stable():
    """Rank-1/2/3 plans key the SAME persistent cache with distinct,
    stable ids (satellite acceptance)."""
    ids = {}
    for ndim in (1, 2, 3):
        opset, _, f = _problem(ndim, jnp.float32)
        plan = plan_stencil(opset, f.shape, 2)
        key = plan.tuning_key(backend="cpu")
        assert key.kernel == f"fused_stencil{ndim}d"
        # stable: re-deriving the plan reproduces the id bit-for-bit
        again = plan_stencil(opset, f.shape, 2).tuning_key(backend="cpu")
        assert key.cache_id == again.cache_id
        ids[ndim] = key.cache_id
    assert len(set(ids.values())) == 3
    # the unroll factor is part of the codegen config → part of the key
    opset, _, f = _problem(1, jnp.float32)
    k1 = plan_stencil(opset, f.shape, 2, block=(25,), unroll=2)
    assert k1.tuning_key("cpu").cache_id != ids[1]


# --- swc vs hwc parity ---------------------------------------------------------


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.float64])
@pytest.mark.parametrize("ndim", [1, 2, 3])
def test_swc_matches_hwc_all_ranks(ndim, dtype):
    opset, phi, f = _problem(ndim, dtype)
    out = kops.fused_stencil_nd(
        f, opset, phi, 2, strategy="swc", interpret=True
    )
    expect = ref.fused_stencil(f, opset, phi)
    tol = 1e-4 if dtype == jnp.float32 else 1e-10
    assert out.dtype == expect.dtype
    np.testing.assert_allclose(
        np.asarray(out), np.asarray(expect), rtol=tol, atol=tol
    )


@pytest.mark.parametrize("ndim", [1, 2, 3])
def test_swc_unroll_matches_reference(ndim):
    opset, phi, f = _problem(ndim, jnp.float32)
    block = {1: (25,), 2: (6, 9), 3: (3, 5, 6)}[ndim]
    out = kops.fused_stencil_nd(
        f, opset, phi, 2, strategy="swc", block=block, unroll=2,
        interpret=True,
    )
    expect = ref.fused_stencil(f, opset, phi)
    np.testing.assert_allclose(
        np.asarray(out), np.asarray(expect), rtol=1e-4, atol=1e-4
    )


@pytest.mark.parametrize("ndim", [1, 2])
def test_fusion_op_routes_swc_below_rank3(ndim):
    """FusedStencilOp(strategy='swc') is Pallas-backed (not the XLA
    fallback) at rank 1/2 — the tentpole acceptance criterion."""
    opset, phi, f = _problem(ndim, jnp.float32)
    r = opset.radius
    interior = tuple(s - 2 * r for s in f.shape[1:])
    f_in = f[(slice(None),) + tuple(slice(r, r + n) for n in interior)]
    swc = FusedStencilOp(opset, phi, 2, strategy="swc")
    hwc = FusedStencilOp(opset, phi, 2, strategy="hwc")
    np.testing.assert_allclose(
        np.asarray(swc(f_in)), np.asarray(hwc(f_in)),
        rtol=1e-4, atol=1e-4,
    )


def test_aux_inputs_all_ranks():
    for ndim in (1, 2, 3):
        opset, _, f = _problem(ndim, jnp.float32)
        interior = SHAPES[ndim]
        aux = jnp.asarray(
            RNG.standard_normal((2,) + interior), jnp.float32
        )

        def phi(d, a):
            return d["val"] * 0.5 + a * d["dxx"]

        out = kops.fused_stencil_nd(
            f, opset, phi, 2, aux=aux, strategy="swc", interpret=True
        )
        expect = ref.fused_stencil(f, opset, phi, aux=aux)
        np.testing.assert_allclose(
            np.asarray(out), np.asarray(expect), rtol=1e-4, atol=1e-4
        )


# --- block="auto" through the persistent cache at every rank -------------------


@pytest.fixture
def cache_dir(tmp_path, monkeypatch):
    monkeypatch.setenv("REPRO_TUNE_CACHE", str(tmp_path))
    return tmp_path


def test_auto_resolves_per_rank_through_cache(cache_dir):
    """``block="auto"`` measures-and-persists one record per rank, and
    the swc result matches hwc (the PR acceptance criterion)."""
    for ndim in (1, 2, 3):
        opset, phi, f = _problem(ndim, jnp.float32)
        r = opset.radius
        interior = SHAPES[ndim]
        f_in = f[(slice(None),) + tuple(slice(r, r + n) for n in interior)]
        auto = FusedStencilOp(opset, phi, 2, strategy="swc", block="auto")
        hwc = FusedStencilOp(opset, phi, 2, strategy="hwc")
        np.testing.assert_allclose(
            np.asarray(auto(f_in)), np.asarray(hwc(f_in)),
            rtol=1e-4, atol=1e-4,
        )
    keys = list(TuningCache().items())
    for ndim in (1, 2, 3):
        # _problem builds accuracy-4 opsets: the non-default order joins
        # the strategy id as the final :o4 suffix.
        assert any(
            k.startswith(f"fused_stencil{ndim}d|swc:o4|") for k in keys
        ), keys
