"""``strategy="tc"`` — the MXU caching regime.

Covers the PR acceptance criteria: parity against the sequential hwc
reference across rank × dtype × temporal-fusion depth and through the
ensemble batch axis, the f32/bf16 dtype gate (float64 raises), tuning-
key uniqueness against the VPU regimes (``tc:f{S}:b{B}`` never replays
a ``swc`` winner), the cold→warm→fresh-process record round-trip with
``strategy_resolved="tc"`` surviving the persisted path, and the
cross-strategy ``"auto"`` search both enumerating tc candidates and
actually measuring them.
"""
import os
import subprocess
import sys
from pathlib import Path

import jax

jax.config.update("jax_enable_x64", True)

import jax.numpy as jnp  # noqa: E402
import numpy as np  # noqa: E402
import pytest  # noqa: E402

from repro.core.fusion import integrate  # noqa: E402
from repro.kernels.plan import tc_groups_per_axis  # noqa: E402
from repro.physics.diffusion import DiffusionProblem  # noqa: E402
from repro.tuning import (  # noqa: E402
    TuningCache,
    enumerate_cross_strategy_nd,
    fused_nd_key,
    lookup_fused_nd,
)
from repro.tuning import session as sess_mod  # noqa: E402
from repro.tuning.session import TuningSession, auto_strategy_nd  # noqa: E402

SRC = str(Path(__file__).resolve().parent.parent / "src")

SHAPES = {1: (1 << 10,), 2: (32, 64), 3: (16, 12, 16)}


@pytest.fixture
def cache_dir(tmp_path, monkeypatch):
    monkeypatch.setenv("REPRO_TUNE_CACHE", str(tmp_path))
    return tmp_path


# --- numerical parity (rank × dtype × depth × batch) ---------------------------


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("fuse", [1, 2])
@pytest.mark.parametrize("ndim", [1, 2, 3])
def test_tc_matches_reference(ndim, fuse, dtype):
    """The matmul lowering computes the same derivative sequence as the
    tap-by-tap reference at every rank, both dtypes and through
    temporal fusion. bf16 compares in f32 against an f32 reference at
    bf16 resolution (the band is cast to the input dtype, so tc rounds
    coefficients exactly like the VPU path)."""
    p = DiffusionProblem(SHAPES[ndim], accuracy=6)
    f32 = p.init_field(seed=1)
    f0 = jnp.asarray(f32, dtype)
    out = p.step_op("tc", fuse_steps=fuse)(f0)
    assert out.dtype == dtype  # f32 accumulation casts back on store
    expect = integrate(p.step_op("hwc"), f32, fuse)
    tol = 2e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(expect), rtol=tol,
        atol=tol,
    )


def test_tc_batched_matches_per_member():
    """A (batch, n_f, *spatial) ensemble stack through tc advances each
    member exactly as the unbatched reference does — the batch axis
    rides along as extra contraction rows."""
    p = DiffusionProblem((32, 64), accuracy=6)
    stack = jnp.stack([p.init_field(seed=s) for s in range(3)])
    out = p.step_op("tc", fuse_steps=2)(stack)
    assert out.shape == stack.shape
    for b in range(3):
        expect = integrate(p.step_op("hwc"), stack[b], 2)
        np.testing.assert_allclose(
            np.asarray(out[b]), np.asarray(expect), rtol=2e-5, atol=1e-5
        )


def test_tc_rejects_float64():
    """The MXU accumulates in f32; a float64 field must fail loudly at
    plan validation, not silently truncate."""
    p = DiffusionProblem((32, 64), accuracy=6)
    f0 = jnp.asarray(p.init_field(seed=1), jnp.float64)
    with pytest.raises(ValueError, match="float32.*bfloat16"):
        p.step_op("tc")(f0)


def test_tc_groups_star_stencil():
    """Fused diffusion is a star stencil: exactly one multi-tap
    contraction group per axis feeds the MXU compute model."""
    for ndim, shape in SHAPES.items():
        ops = DiffusionProblem(shape, accuracy=6).step_op("hwc").ops
        assert tc_groups_per_axis(ops) == (1,) * ndim


# --- tuning-key uniqueness ------------------------------------------------------


def test_tc_key_never_collides_with_vpu_keys():
    """``tc:f2:b4`` and friends are distinct cache identities from
    every swc/swc_stream key of the same shape — a tc winner can never
    be replayed by a VPU call site or vice versa."""
    k = fused_nd_key(
        (64, 128), (3, 3), 1, 1, "float32", "tc", fuse_steps=2, batch=4
    )
    assert k.strategy == "tc:f2:b4"
    ids = {
        fused_nd_key(
            (64, 128), (3, 3), 1, 1, "float32", strat,
            fuse_steps=fs, batch=b,
        ).cache_id
        for strat in ("swc", "swc_stream", "tc")
        for fs in (1, 2)
        for b in (1, 4)
    }
    assert len(ids) == 12  # all distinct across the full matrix


# --- record round-trip ----------------------------------------------------------


def test_tc_round_trips_through_cache_and_fresh_process(cache_dir):
    """Cold measure → warm hit with zero re-measurement and bit-equal
    output → fresh-process replay from disk. The record carries
    ``strategy_resolved="tc"`` and every timing row the tc search wrote
    is ``:tc``-marked."""
    p = DiffusionProblem((32, 64), accuracy=6)
    f0 = p.init_field(seed=3)
    op = p.step_op("tc", block="auto", fuse_steps=2)
    out1 = np.asarray(op(f0))  # cold: measures and persists
    rec = lookup_fused_nd(f0, op.ops, 1, "tc", fuse_steps=2)
    assert rec is not None and rec.source == "measured"
    assert rec.strategy_resolved == "tc"
    assert rec.winner_label.endswith(":tc")
    assert all(lbl.endswith(":tc") for lbl in rec.timings_us)

    before = sess_mod.MEASURE_COUNT
    out2 = np.asarray(p.step_op("tc", block="auto", fuse_steps=2)(f0))
    assert sess_mod.MEASURE_COUNT == before  # warm hit: no re-measure
    np.testing.assert_array_equal(out1, out2)

    code = """
from repro.physics.diffusion import DiffusionProblem
from repro.tuning import lookup_fused_nd
from repro.tuning import session as sess_mod

p = DiffusionProblem((32, 64), accuracy=6)
f0 = p.init_field(seed=3)
p.step_op("tc", block="auto", fuse_steps=2)(f0)
assert sess_mod.MEASURE_COUNT == 0, sess_mod.MEASURE_COUNT
op = p.step_op("tc", block="auto", fuse_steps=2)
rec = lookup_fused_nd(f0, op.ops, 1, "tc", fuse_steps=2)
print(f"REPLAYED {rec.strategy_resolved} {rec.block}")
"""
    env = dict(os.environ)
    env["REPRO_TUNE_CACHE"] = str(cache_dir)
    env["PYTHONPATH"] = SRC + os.pathsep + env.get("PYTHONPATH", "")
    res = subprocess.run(
        [sys.executable, "-c", code], capture_output=True, text=True,
        env=env,
    )
    assert res.returncode == 0, res.stderr
    assert f"REPLAYED tc {rec.block}" in res.stdout


# --- cross-strategy "auto" integration ------------------------------------------


def test_auto_enumerates_tc_and_gates_on_itemsize():
    """tc candidates appear in the cross-strategy space for 4-byte
    (and would for 2-byte) fields at every rank, and never for f64."""
    for ndim, shape in SHAPES.items():
        cands = enumerate_cross_strategy_nd(
            shape, (3,) * ndim, 1, 1, 4, fuse_steps_options=(1, 2)
        )
        assert any(c.strategy == "tc" for c in cands), ndim
    f64 = enumerate_cross_strategy_nd(
        (64, 128), (3, 3), 1, 1, 8, fuse_steps_options=(1, 2)
    )
    assert not any(c.strategy == "tc" for c in f64)


def test_auto_measures_tc_candidates(cache_dir):
    """With a measurement window wide enough to cover the space, the
    eager cross-strategy search actually TIMES tc candidates (``:tc``
    rows land in the record's timing table) — tc is a measured
    contender, not just an enumerated one."""
    p = DiffusionProblem((64, 128), accuracy=6)
    f0 = p.init_field(seed=7)
    sess = TuningSession(
        cache=TuningCache(), top_k=64, warmup=0, iters=1,
        record_source="smoke",
    )
    strat, block, depth = auto_strategy_nd(
        f0, p.step_op("hwc").ops, p.step_op("hwc").phi, 1,
        session=sess, depth_options=(1, 2),
    )
    assert strat in ("hwc", "swc", "swc_stream", "tc")
    rec = lookup_fused_nd(
        f0, p.step_op("hwc").ops, 1, "auto", session=sess,
        fuse_steps="auto",
    )
    assert rec is not None
    assert any(lbl.endswith(":tc") for lbl in rec.timings_us), (
        rec.timings_us
    )
