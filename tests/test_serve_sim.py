"""Serving-loop tests: shape-bucketed batching (a mixed-shape queue
drains into plan-compatible buckets, FIFO head-of-line), per-bucket
tuning-cache behavior (first batch of a bucket tunes, later batches and
later servers replay the persisted ``:b{B}`` record), and
``StragglerMonitor`` engagement on an injected slow batch."""
import time

import jax.numpy as jnp
import numpy as np
import pytest

from repro.ft.supervisor import StragglerMonitor
from repro.launch.serve_sim import (
    RequestQueue,
    SimRequest,
    SimServer,
    demo_queue,
)


@pytest.fixture
def cache_dir(tmp_path, monkeypatch):
    monkeypatch.setenv("REPRO_TUNE_CACHE", str(tmp_path))
    return tmp_path


def _req(rid, shape, n_steps=4, dtype=jnp.float32):
    f0 = jnp.zeros((1,) + shape, dtype) + 1e-5 * (rid + 1)
    return SimRequest(rid, f0, n_steps)


# --- queue bucketing ------------------------------------------------------------


def test_mixed_queue_drains_into_correct_buckets():
    """Interleaved shapes/steps separate into plan-compatible batches;
    the oldest waiting request always leads the next batch."""
    queue = RequestQueue()
    for rid in range(9):
        shape = (16, 32) if rid % 2 == 0 else (12, 24)
        queue.push(_req(rid, shape, n_steps=4 if rid < 6 else 8))
    batches = []
    while queue:
        key, reqs = queue.next_bucket(lambda r: r.bucket_key, max_batch=4)
        assert all(r.bucket_key == key for r in reqs)
        batches.append((key, [r.req_id for r in reqs]))
    # (16,32)+4steps: rids 0,2,4; (12,24)+4steps: 1,3,5;
    # (16,32)+8steps: 6,8; (12,24)+8steps: 7 — head-of-line order.
    assert [ids for _, ids in batches] == [
        [0, 2, 4], [1, 3, 5], [6, 8], [7]
    ]
    assert batches[0][0] == ((16, 32), "float32", 4)
    assert batches[2][0] == ((16, 32), "float32", 8)
    assert len({key for key, _ in batches}) == 4


def test_next_bucket_respects_max_batch_and_fifo():
    queue = RequestQueue([_req(i, (8, 16)) for i in range(5)])
    _, first = queue.next_bucket(lambda r: r.bucket_key, max_batch=4)
    assert [r.req_id for r in first] == [0, 1, 2, 3]
    _, rest = queue.next_bucket(lambda r: r.bucket_key, max_batch=4)
    assert [r.req_id for r in rest] == [4]
    assert not queue


def test_server_routes_every_request_to_its_bucket_result():
    """End to end on two interleaved shapes: every request id comes
    back with its own shape, and the server builds exactly one op per
    bucket."""
    queue = demo_queue([(16, 32), (12, 24)], n_steps=4, requests=10)
    expect_shape = {
        r.req_id: (1,) + r.bucket_key[0] for r in queue.snapshot()
    }
    server = SimServer(strategy="swc", max_batch=4)
    results = server.serve(queue)
    assert sorted(results) == list(range(10))
    for rid, out in results.items():
        assert out.shape == expect_shape[rid]
    assert server.op_builds == 2
    assert {rep.key[0] for rep in server.reports} == {(16, 32), (12, 24)}


# --- tuning-cache sharing -------------------------------------------------------


def test_per_bucket_tuning_cache_hits(cache_dir):
    """block="auto": the first full-size batch of each bucket measures
    and persists a ``:b{B}``-keyed record; every later batch of that
    bucket — including in a FRESH server (new process stand-in) —
    replays it with zero new measurements."""
    from repro.tuning import TuningCache
    from repro.tuning import session as sess_mod

    # 2 buckets x 2 full batches of B=2 each.
    queue = demo_queue([(16, 32), (12, 24)], n_steps=2, requests=8)
    server = SimServer(strategy="swc", block="auto", max_batch=2)
    server.serve(queue)
    measured = sess_mod.MEASURE_COUNT
    assert measured > 0  # the cold cache really was tuned
    keys = set(TuningCache().items())
    # The demo problems build accuracy-2 opsets, so the order
    # suffix follows the batch extent in the id.
    assert any(":b2:o2|16x32|" in k for k in keys), keys
    assert any(":b2:o2|12x24|" in k for k in keys), keys

    fresh = SimServer(strategy="swc", block="auto", max_batch=2)
    fresh.serve(demo_queue([(16, 32), (12, 24)], n_steps=2, requests=8))
    assert sess_mod.MEASURE_COUNT == measured  # pure cache replay
    assert set(TuningCache().items()) == keys


# --- straggler engagement -------------------------------------------------------


def test_straggler_monitor_flags_injected_slow_batch():
    """A deliberately slowed batch (contended-member stand-in) trips
    the trailing-median monitor once enough history exists, and the
    flag lands in the server's batch report."""
    slow_index = 6

    def inject(index, reqs):
        if index == slow_index:
            time.sleep(0.4)

    server = SimServer(
        strategy="swc",
        max_batch=2,
        straggler=StragglerMonitor(factor=1.5, window=20),
        batch_hook=inject,
    )
    queue = demo_queue([(16, 32)], n_steps=2, requests=14)  # 7 batches
    results = server.serve(queue)
    assert len(results) == 14
    flags = [rep.straggler for rep in server.reports]
    assert flags[slow_index], server.reports
    assert not any(flags[:slow_index])
    assert server.straggler.flagged[0][0] == slow_index


def test_fast_batches_do_not_flag():
    server = SimServer(strategy="swc", max_batch=2)
    server.serve(demo_queue([(16, 32)], n_steps=2, requests=12))
    assert not any(rep.straggler for rep in server.reports)
    assert server.straggler.flagged == []


# --- batched numerics through the server ----------------------------------------


def test_server_matches_per_member_serving():
    """Batched serving returns the same fields as serving each request
    alone (B=1 path) — bucketing is a throughput decision, not a
    numerics decision."""
    queue = demo_queue([(12, 24)], n_steps=4, requests=4, seed=7)
    singles = {r.req_id: r for r in queue.snapshot()}
    batched = SimServer(strategy="swc", max_batch=4).serve(queue)
    solo_server = SimServer(strategy="swc", max_batch=1)
    for rid, req in singles.items():
        solo = solo_server.serve(RequestQueue([req]))[rid]
        np.testing.assert_allclose(
            batched[rid], solo, rtol=0, atol=1e-6
        )
