"""Temporal fusion tests: multi-step in-kernel integration on
halo-widened blocks.

Covers the PR acceptance criteria — parity of ``fuse_steps ∈ {1, 2, 3}``
against the sequential reference across ranks 1/2/3 and
float32/float64, depth-keyed tuning-cache separation, the ≥ 1.3×
modeled HBM-traffic reduction of depth-2 diffusion at ranks 2/3, the
cost model's ability to pick a depth > 1 for ``block="auto"`` /
``fuse_steps="auto"``, and the tiny-block interior-volume guard in
``costmodel.halo_overhead``.
"""
import sys
from pathlib import Path

import jax

jax.config.update("jax_enable_x64", True)

import jax.numpy as jnp  # noqa: E402
import numpy as np  # noqa: E402
import pytest  # noqa: E402

from repro.core.fusion import FusedStencilOp, integrate  # noqa: E402
from repro.core.stencil import derivative_operator_set  # noqa: E402
from repro.core.trafficmodel import (  # noqa: E402
    stencil_hbm_bytes_per_step,
    stencil_redundant_compute_fraction,
    stencil_traffic_reduction,
)
from repro.kernels import ops as kops  # noqa: E402
from repro.kernels import ref  # noqa: E402
from repro.kernels.plan import plan_stencil  # noqa: E402
from repro.physics.diffusion import DiffusionProblem, simulate  # noqa: E402
from repro.physics.mhd import MHDSolver  # noqa: E402
from repro.tuning import lookup_fused_nd  # noqa: E402
from repro.tuning.costmodel import (  # noqa: E402
    enumerate_candidates_nd,
    halo_overhead,
)

RNG = np.random.default_rng(23)

# Small but not block-aligned interiors, one per rank.
SHAPES = {1: (60,), 2: (12, 24), 3: (6, 10, 24)}


def _problem(ndim, dtype, n_steps, accuracy=4, n_f=2):
    """A self-map problem (n_out == n_f) + operand padded for
    ``n_steps`` fused sweeps."""
    opset = derivative_operator_set(ndim, accuracy, spacing=0.3)
    names = opset.names

    def phi(d):
        acc = sum(d[n] for n in names)
        return jnp.stack(
            [
                jnp.tanh(acc[0]) + d["val"][-1] * 0.1,
                d["val"][0] + 0.05 * acc[-1],
            ]
        )

    r = opset.radius
    shape = SHAPES[ndim]
    f = jnp.asarray(
        RNG.standard_normal(
            (n_f,) + tuple(s + 2 * r * n_steps for s in shape)
        ),
        dtype,
    )
    return opset, phi, f


# --- kernel parity vs the sequential reference ---------------------------------


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.float64])
@pytest.mark.parametrize("ndim", [1, 2, 3])
@pytest.mark.parametrize("fuse_steps", [1, 2, 3])
def test_fused_steps_match_sequential_reference(ndim, fuse_steps, dtype):
    opset, phi, f = _problem(ndim, dtype, fuse_steps)
    out = kops.fused_stencil_nd(
        f, opset, phi, 2, strategy="swc", fuse_steps=fuse_steps,
        interpret=True,
    )
    expect = ref.fused_stencil_steps(f, opset, phi, fuse_steps)
    assert out.shape == (2,) + SHAPES[ndim]
    tol = 1e-4 if dtype == jnp.float32 else 1e-10
    np.testing.assert_allclose(
        np.asarray(out), np.asarray(expect), rtol=tol, atol=tol
    )


def test_fused_steps_with_aux_carry_and_per_step_phis():
    """Depth-2 fusion with an aux carry and DIFFERENT φ per sweep (the
    RK-substep shape: output rows feed the next sweep's fields and
    carry)."""
    opset = derivative_operator_set(2, 4, spacing=0.3)
    r = opset.radius
    shape = SHAPES[2]

    def mk_phi(c):
        def phi(d, a):
            f_new = d["val"] + c * d["dxx"] + 0.1 * a * d["dyy"][:1]
            w_new = 0.5 * a + c * d["val"][:1]
            return jnp.concatenate([f_new, w_new])

        return phi

    phis = (mk_phi(0.3), mk_phi(0.7))
    f = jnp.asarray(
        RNG.standard_normal((2,) + tuple(s + 4 * r for s in shape)),
        jnp.float64,
    )
    aux = jnp.asarray(
        RNG.standard_normal((1,) + tuple(s + 2 * r for s in shape)),
        jnp.float64,
    )
    out = kops.fused_stencil_nd(
        f, opset, phis, 3, aux=aux, strategy="swc", fuse_steps=2,
        interpret=True,
    )
    expect = ref.fused_stencil_steps(f, opset, phis, 2, aux=aux)
    np.testing.assert_allclose(
        np.asarray(out), np.asarray(expect), rtol=1e-10, atol=1e-10
    )


def test_plan_rejects_non_self_map_fusion():
    opset, phi, f = _problem(2, jnp.float32, 2)
    with pytest.raises(ValueError, match="self-map"):
        plan_stencil(opset, f.shape, 3, fuse_steps=2)  # n_out != n_f


def test_integrate_fused_matches_sequential_with_remainder():
    """integrate() over a fused op advances the EXACT step count: full
    depth-3 launches plus a depth-1 remainder."""
    opset = derivative_operator_set(2, 6, spacing=0.5)

    def phi(d):
        return d["val"] + 0.05 * (d["dxx"] + d["dyy"])

    f0 = jnp.asarray(
        RNG.standard_normal((1, 24, 48)), jnp.float64
    )
    seq = integrate(
        FusedStencilOp(opset, phi, 1, strategy="swc"), f0, 7
    )
    fused = integrate(
        FusedStencilOp(opset, phi, 1, strategy="swc", fuse_steps=3),
        f0, 7,
    )
    np.testing.assert_allclose(
        np.asarray(fused), np.asarray(seq), rtol=1e-12, atol=1e-12
    )


def test_diffusion_simulate_fused_parity():
    """Fused diffusion (the acceptance workload) matches the
    strategy-agnostic sequential run at ranks 2 and 3."""
    for shape in ((16, 32), (8, 12, 16)):
        p = DiffusionProblem(shape, accuracy=6)
        f0 = p.init_field(seed=3)
        base = simulate(p, f0, 4, strategy="hwc")
        fused = simulate(p, f0, 4, strategy="swc", fuse_steps=2)
        np.testing.assert_allclose(
            np.asarray(fused), np.asarray(base), rtol=1e-5, atol=1e-7
        )


def test_mhd_rk3_pairwise_fusion_parity():
    """fuse_rk_pairs (substeps 1+2 in one depth-2 kernel) reproduces
    the plain RK3 step."""
    shape = (8, 8, 16)
    base = MHDSolver(shape, strategy="hwc")
    f0 = base.init_smooth(seed=1, dtype=jnp.float64)
    expect = base.step(f0, 1e-4)
    for strat in ("hwc", "swc"):
        got = MHDSolver(
            shape, strategy=strat, fuse_rk_pairs=True
        ).step(f0, 1e-4)
        np.testing.assert_allclose(
            np.asarray(got), np.asarray(expect), rtol=1e-12, atol=1e-12
        )


# --- tuning keys ---------------------------------------------------------------


def test_tuning_key_depth_collision():
    """Depth-1 and depth-2 plans for the same problem cache under
    DISTINCT keys (same kernel/domain, different strategy id)."""
    opset, phi, f1 = _problem(2, jnp.float32, 1)
    _, _, f2 = _problem(2, jnp.float32, 2)
    k1 = plan_stencil(opset, f1.shape, 2, fuse_steps=1).tuning_key("cpu")
    k2 = plan_stencil(opset, f2.shape, 2, fuse_steps=2).tuning_key("cpu")
    assert k1.domain == k2.domain  # same interior problem...
    assert k1.cache_id != k2.cache_id  # ...distinct cache records
    assert ":f2" in k2.strategy and ":f2" not in k1.strategy
    # stable: re-deriving reproduces the id bit-for-bit
    again = plan_stencil(
        opset, f2.shape, 2, fuse_steps=2
    ).tuning_key("cpu")
    assert k2.cache_id == again.cache_id


# --- traffic model + cost model (acceptance criterion) -------------------------


@pytest.mark.parametrize(
    "domain,radii",
    [((256, 256), (3, 3)), ((64, 64, 64), (3, 3, 3))],
)
def test_depth2_traffic_reduction_meets_bar(domain, radii):
    """fuse_steps=2 diffusion at ranks 2/3 models ≥ 1.3× less HBM
    traffic than depth 1, each depth at its cost-model-chosen block."""
    cands = enumerate_candidates_nd(
        domain, radii, 1, 1, 4, fuse_steps_options=(1, 2)
    )
    best1 = next(c for c in cands if c.fuse_steps == 1)
    best2 = next(c for c in cands if c.fuse_steps == 2)
    ratio = stencil_traffic_reduction(
        domain, radii, 1, 1, 4,
        block_base=best1.block, block_fused=best2.block, fuse_steps=2,
    )
    assert ratio >= 1.3, (ratio, best1.block, best2.block)
    # cross-check: the candidate scores embed the same traffic model
    bytes2 = stencil_hbm_bytes_per_step(
        domain, best2.block, radii, 1, 1, 4, 2
    )
    bytes1 = stencil_hbm_bytes_per_step(
        domain, best1.block, radii, 1, 1, 4, 1
    )
    assert bytes1 / bytes2 == pytest.approx(ratio)


def test_cost_model_prefers_depth_over_one():
    """The joint (block, fuse_steps) enumeration ranks a fused config
    first for a bandwidth-bound diffusion problem — the structural
    winner ``block="auto"`` uses under tracing."""
    cands = enumerate_candidates_nd(
        (256, 256), (3, 3), 1, 1, 4, fuse_steps_options=(1, 2, 3, 4)
    )
    assert cands[0].fuse_steps > 1
    # redundancy is monotone in depth and zero at depth 1
    assert stencil_redundant_compute_fraction((64, 64), (3, 3), 1) == 0.0
    assert stencil_redundant_compute_fraction(
        (64, 64), (3, 3), 3
    ) > stencil_redundant_compute_fraction((64, 64), (3, 3), 2)


def test_auto_depth_resolves_and_matches_reference(tmp_path, monkeypatch):
    """``block="auto", fuse_steps="auto"`` under jit picks a depth > 1
    from the cost model, persists it under the ``:fauto`` key, and the
    fused result matches the sequential reference at that depth."""
    monkeypatch.setenv("REPRO_TUNE_CACHE", str(tmp_path))
    p = DiffusionProblem((64, 64), accuracy=6)
    op = p.step_op("swc", block="auto", fuse_steps="auto")
    f0 = p.init_field(seed=5)
    out = jax.jit(op)(f0)  # traced: structural (cost-model) winner
    rec = lookup_fused_nd(f0, op.ops, 1, "swc", fuse_steps="auto")
    assert rec is not None and rec.source == "model"
    assert rec.fuse_steps > 1
    expect = integrate(
        p.step_op("hwc"), f0, rec.fuse_steps
    )
    np.testing.assert_allclose(
        np.asarray(out), np.asarray(expect), rtol=2e-5, atol=1e-7
    )


def test_halo_overhead_tiny_block_guard():
    """Satellite fix: fused blocks swallowed by their (anisotropic)
    widened halo — zero/negative shrinking interior — score inf and are
    excluded, instead of ranking on misleading finite values. Depth 1
    has no shrinking region, so small tiles stay enumerable."""
    assert halo_overhead((8, 64), (3, 3), 2) == float("inf")  # 8 <= 12
    assert halo_overhead((16, 64), (3, 3), 2) < float("inf")
    # anisotropic radii: only the violating axis matters
    assert halo_overhead((8, 64), (1, 3), 2) < float("inf")
    assert halo_overhead((8, 64), (1, 32), 2) == float("inf")
    # depth 1 is untouched by the guard (high overhead, not excluded)
    assert halo_overhead((4, 64), (3, 3), 1) < float("inf")
    assert enumerate_candidates_nd((6, 6, 6), (3, 3, 3), 1, 1, 4)
    cands = enumerate_candidates_nd(
        (64, 64), (3, 3), 1, 1, 4, fuse_steps_options=(1, 2, 3)
    )
    for c in cands:
        assert np.isfinite(c.score)
        if c.fuse_steps > 1:
            assert all(
                t > 2 * r * c.fuse_steps for t, r in zip(c.block, (3, 3))
            ), c


def test_fusion_requires_periodic_boundary():
    """Intermediate in-kernel sweeps never re-impose the boundary, so
    only the periodic wrap composes exactly — other modes are rejected
    up front instead of silently diverging."""
    opset = derivative_operator_set(2, 4, spacing=0.3)
    phi = lambda d: d["val"]  # noqa: E731
    for depth in (2, "auto"):
        kwargs = (
            {"strategy": "swc", "block": "auto"}
            if depth == "auto" else {"strategy": "swc"}
        )
        with pytest.raises(ValueError, match="periodic"):
            FusedStencilOp(
                opset, phi, 2, boundary_mode="dirichlet",
                fuse_steps=depth, **kwargs,
            )
    # depth 1 keeps every boundary mode
    FusedStencilOp(opset, phi, 2, boundary_mode="dirichlet")


def test_phi_sequence_pins_auto_depth():
    opset = derivative_operator_set(2, 4, spacing=0.3)
    phis = (lambda d: d["val"], lambda d: d["val"])
    with pytest.raises(ValueError, match="pins the fusion depth"):
        FusedStencilOp(
            opset, phis, 2, strategy="swc", block="auto",
            fuse_steps="auto",
        )


# --- benchmark summary (satellite) ---------------------------------------------


def test_bench_summary_rows():
    # benchmarks/ is not a package; scoped path push is the sanctioned
    # way to import its row summarizer here.
    sys.path.insert(0, str(Path(__file__).resolve().parent.parent))  # repolint: allow[sys-path-hack]
    try:
        from benchmarks.run import summarize_rows
    finally:
        sys.path.pop(0)  # repolint: allow[sys-path-hack]
    rows = [
        {
            "name": "fig11/x", "us_per_call": 100.0,
            "derived": "Mupdates_per_s=1.0;tpu_bw_bound_s=2.00e-05",
        },
        {"name": "fig13/y", "us_per_call": 50.0, "derived": "foo=1"},
    ]
    out = summarize_rows(rows)
    assert set(out) == {"fig11/x"}
    assert out["fig11/x"]["roofline_fraction"] == pytest.approx(0.2)
    assert out["fig11/x"]["gbps"] == pytest.approx(
        0.2 * 819, rel=1e-3
    )
