"""Physics validation: diffusion against exact discrete eigenvalues,
MHD against the spectral oracle (6th-order convergence), strategy
equivalence, and integration stability."""
import jax

jax.config.update("jax_enable_x64", True)

import jax.numpy as jnp  # noqa: E402
import numpy as np  # noqa: E402
import pytest  # noqa: E402

from repro.physics.diffusion import (  # noqa: E402
    DiffusionProblem,
    simulate,
    step_1d_xcorr,
)
from repro.physics.mhd import (  # noqa: E402
    MHDSolver,
    N_FIELDS,
    mhd_rhs_phi,
)
from repro.physics.spectral import spectral_rhs  # noqa: E402


def _mode_eigenvalue(problem: DiffusionProblem, k) -> float:
    spec = problem.merged_stencil()
    return float(
        sum(
            c * np.cos(sum(ki * oi * hi for ki, oi, hi in
                           zip(k, o, problem.spacing)))
            for o, c in zip(spec.offsets, spec.coeffs)
        )
    )


@pytest.mark.parametrize(
    "shape,k",
    [((64,), (3,)), ((32, 32), (2, 1)), ((16, 16, 32), (1, 2, 1))],
)
def test_diffusion_exact_discrete_decay(shape, k):
    """A Fourier mode is an exact eigenvector of the merged stencil —
    the simulated decay must match λ^n to fp precision."""
    p = DiffusionProblem(shape, accuracy=6)
    f0 = p.fourier_mode(k)
    n_steps = 40
    out = simulate(p, f0, n_steps)
    lam = _mode_eigenvalue(p, k)
    decay = float(jnp.linalg.norm(out) / jnp.linalg.norm(f0))
    assert abs(decay - lam**n_steps) < 1e-10


def test_diffusion_1d_xcorr_path_equivalent():
    p = DiffusionProblem((64,), accuracy=6)
    f = p.fourier_mode((3,))
    a = step_1d_xcorr(f[0], p, strategy="hwc")
    b = p.step_op("hwc")(f)[0]
    assert float(jnp.abs(a - b).max()) < 1e-14


def test_diffusion_analytic_limit():
    """Against exp(-α|k|²t) within forward-Euler + FD truncation."""
    p = DiffusionProblem((32, 32, 32), accuracy=6, safety=0.05)
    k = (1, 1, 2)
    out = simulate(p, p.fourier_mode(k), 60)
    decay = float(jnp.linalg.norm(out) / jnp.linalg.norm(p.fourier_mode(k)))
    ana = p.analytic_decay(k, 60 * p.dt)
    assert abs(decay - ana) / ana < 2e-3


# --- MHD -----------------------------------------------------------------------


def _smooth_fields(n, seed=0, amp=1e-2):
    rng = np.random.default_rng(seed)
    grids = np.meshgrid(
        *(np.linspace(0, 2 * np.pi, n, endpoint=False),) * 3, indexing="ij"
    )
    f = np.zeros((N_FIELDS, n, n, n))
    for fi in range(N_FIELDS):
        for _ in range(3):
            k = rng.integers(-2, 3, size=3)
            ph = rng.uniform(0, 2 * np.pi)
            f[fi] += rng.uniform(0.3, 1.0) * amp * np.cos(
                k[0] * grids[0] + k[1] * grids[1] + k[2] * grids[2] + ph
            )
    return f


def test_mhd_rhs_matches_spectral_oracle_6th_order():
    errs = {}
    for n in (16, 32):
        solver = MHDSolver((n, n, n), strategy="hwc")
        f = _smooth_fields(n)
        rhs_fd = np.asarray(solver.rhs(jnp.asarray(f)))
        rhs_sp = spectral_rhs(f, solver.spacing, mhd_rhs_phi(solver.params))
        errs[n] = np.abs(rhs_fd - rhs_sp).max() / np.abs(rhs_sp).max()
    order = np.log2(errs[16] / errs[32])
    assert errs[32] < 5e-4
    assert order > 5.0, f"expected ~6th order, got {order:.2f}"


def test_mhd_equilibrium():
    solver = MHDSolver((16, 16, 16), strategy="hwc")
    f0 = jnp.zeros((N_FIELDS, 16, 16, 16), jnp.float64)
    assert float(jnp.abs(solver.rhs(f0)).max()) < 1e-12


@pytest.mark.parametrize("strategy", ["swc", "swc_stream"])
def test_mhd_strategies_match_hwc(strategy):
    n = 16
    f = jnp.asarray(_smooth_fields(n), jnp.float32)
    base = MHDSolver((n, n, n), strategy="hwc")
    other = MHDSolver((n, n, n), strategy=strategy, block=(8, 8, 16))
    r0 = base.rhs(f)
    r1 = other.rhs(f)
    rel = float(jnp.abs(r1 - r0).max() / jnp.abs(r0).max())
    # f32: XLA-fused vs interpret-Pallas differ only in summation order
    assert rel < 1e-5


def test_mhd_fused_rk_axpy_bitexact():
    n = 16
    f = jnp.asarray(_smooth_fields(n), jnp.float64)
    a = MHDSolver((n, n, n), strategy="hwc", fuse_rk_axpy=False)
    b = MHDSolver((n, n, n), strategy="hwc", fuse_rk_axpy=True)
    dt = float(a.cfl_dt(f))
    fa = a.step(f, dt)
    fb = b.step(f, dt)
    assert float(jnp.abs(fa - fb).max()) == 0.0


def test_mhd_integration_stable():
    n = 16
    solver = MHDSolver((n, n, n), strategy="hwc")
    f = jnp.asarray(_smooth_fields(n, amp=1e-3), jnp.float64)
    dt = float(solver.cfl_dt(f))
    out = solver.simulate(f, 30, dt)
    assert bool(jnp.isfinite(out).all())
    # dissipative system at low amplitude: no runaway growth
    assert float(jnp.abs(out).max()) < 10 * float(jnp.abs(f).max()) + 1.0
