"""Hypothesis property tests on the stencil-engine invariants
(assignment requirement c): linearity, shift equivariance, fusion
equivalence, causality.

These RUN everywhere: with ``hypothesis`` installed (the ``test``/
``dev`` extras — what CI installs) they get the real coverage-guided
search; on a bare interpreter they fall back to the deterministic
seeded sampler in ``tests/_minihypothesis.py`` instead of being
skipped, so the invariants are always exercised.
"""
import jax

jax.config.update("jax_enable_x64", True)

import jax.numpy as jnp
import numpy as np

try:
    import hypothesis.strategies as st
    from hypothesis import given, settings
except ImportError:  # bare interpreter: seeded fallback, not a skip
    from _minihypothesis import given, settings
    from _minihypothesis import strategies as st

from repro.core.stencil import derivative_operator_set
from repro.kernels import ref


def _phi_test(d):
    lap = d["dxx"] + d["dyy"] + d["dzz"]
    o0 = d["val"][0] + 0.1 * lap[0] + d["dx"][1] * d["dy"][0]
    o1 = jnp.tanh(d["val"][1]) + d["dxy"][0] + d["dz"][1] * d["dxz"][0]
    return jnp.stack([o0, o1])


@settings(max_examples=25, deadline=None)
@given(
    r=st.integers(0, 8),
    n=st.integers(16, 128),
    seed=st.integers(0, 2**31 - 1),
)
def test_xcorr_linearity(r, n, seed):
    """ζ is linear: ζ(αf + βh) = αζ(f) + βζ(h) (paper Sec. 2.4)."""
    rng = np.random.default_rng(seed)
    f = rng.standard_normal(n + 2 * r)
    h = rng.standard_normal(n + 2 * r)
    g = rng.standard_normal(2 * r + 1)
    a, b = rng.standard_normal(2)
    lhs = ref.xcorr1d_numpy(a * f + b * h, g)
    rhs = a * ref.xcorr1d_numpy(f, g) + b * ref.xcorr1d_numpy(h, g)
    np.testing.assert_allclose(lhs, rhs, rtol=1e-9, atol=1e-9)


@settings(max_examples=25, deadline=None)
@given(
    r=st.integers(1, 6),
    shift=st.integers(1, 5),
    seed=st.integers(0, 2**31 - 1),
)
def test_xcorr_shift_equivariance(r, shift, seed):
    """Stencils commute with translation on a periodic domain."""
    rng = np.random.default_rng(seed)
    n = 64
    f = rng.standard_normal(n)
    g = rng.standard_normal(2 * r + 1)

    def apply(fv):
        fp = np.concatenate([fv[-r:], fv, fv[:r]])
        return ref.xcorr1d_numpy(fp, g)

    np.testing.assert_allclose(
        apply(np.roll(f, shift)), np.roll(apply(f), shift),
        rtol=1e-9, atol=1e-9,
    )


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 2**31 - 1), accuracy=st.sampled_from([2, 4, 6]))
def test_fusion_equals_unfused(seed, accuracy):
    """φ(A·B) fused == evaluating each operator separately then φ."""
    rng = np.random.default_rng(seed)
    opset = derivative_operator_set(3, accuracy, spacing=0.5)
    r = opset.radius
    f = jnp.asarray(
        rng.standard_normal((2, 6 + 2 * r, 6 + 2 * r, 8 + 2 * r)),
        jnp.float64,
    )
    fused = ref.fused_stencil(f, opset, _phi_test)
    # unfused: evaluate each operator separately on a singleton-radius
    # view of the padded array (same interior geometry)
    R = opset.radius_per_axis()
    derivs = {}
    for spec in opset.ops:
        rr = spec.radius_per_axis() or (0, 0, 0)
        view = f[
            :,
            R[0] - rr[0] : f.shape[1] - (R[0] - rr[0]),
            R[1] - rr[1] : f.shape[2] - (R[1] - rr[1]),
            R[2] - rr[2] : f.shape[3] - (R[2] - rr[2]),
        ]
        derivs[spec.name] = ref.apply_operator_set(
            view, type(opset)((spec,))
        )[spec.name]
    np.testing.assert_allclose(
        np.asarray(fused), np.asarray(_phi_test(derivs)),
        rtol=1e-12, atol=1e-12,
    )


@settings(max_examples=15, deadline=None)
@given(
    seed=st.integers(0, 2**31 - 1),
    k=st.integers(1, 6),
    s=st.integers(8, 64),
)
def test_conv1d_causality(seed, k, s):
    """Output at t must not depend on inputs after t."""
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.standard_normal((1, s, 4)), jnp.float32)
    w = jnp.asarray(rng.standard_normal((k, 4)), jnp.float32)
    base = np.asarray(ref.conv1d_depthwise_causal(x, w))
    t = s // 2
    x2 = x.at[:, t + 1 :].set(999.0)
    pert = np.asarray(ref.conv1d_depthwise_causal(x2, w))
    np.testing.assert_array_equal(base[:, : t + 1], pert[:, : t + 1])
