"""Ensemble serving demo: a mixed-shape stream of simulation requests
drained through the batched fused-stencil engine — shape-bucketed
batching, one batched kernel per bucket (B members per block, shared
halo), a warm tuning cache across batches, and StragglerMonitor
flagging of slow batches (here injected, the CPU stand-in for a
contended device).

Run:  PYTHONPATH=src python examples/serve_ensemble.py
"""
import argparse
import time

import numpy as np

from repro.ft.supervisor import StragglerMonitor
from repro.launch.serve_sim import SimServer, demo_queue


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--requests", type=int, default=24)
    ap.add_argument("--steps", type=int, default=8)
    ap.add_argument("--max-batch", type=int, default=4)
    ap.add_argument("--slow-batch", type=int, default=None,
                    help="inject a sleep into this batch index so the "
                         "straggler monitor fires (default: last batch)")
    args = ap.parse_args()

    # Two interleaved request shapes -> two buckets; FIFO head-of-line
    # picks whichever bucket the oldest waiting request belongs to.
    queue = demo_queue(
        [(16, 32), (12, 24)], args.steps, args.requests, seed=1
    )
    n_batches_est = -(-args.requests // args.max_batch)
    slow = (
        args.slow_batch if args.slow_batch is not None
        else n_batches_est - 1
    )

    def inject(index, reqs):
        if index == slow:
            time.sleep(0.5)  # contended-member stand-in

    server = SimServer(
        strategy="swc",
        max_batch=args.max_batch,
        straggler=StragglerMonitor(factor=1.5, window=20),
        batch_hook=inject,
    )
    t0 = time.time()
    results = server.serve(queue)
    wall = time.time() - t0
    assert len(results) == args.requests

    print(f"{'batch':>5} {'bucket':>14} {'B':>3} {'seconds':>9} flag")
    for rep in server.reports:
        shape = "x".join(map(str, rep.key[0]))
        print(f"{rep.index:5d} {shape:>14} {rep.batch:3d} "
              f"{rep.seconds:9.4f} {'STRAGGLER' if rep.straggler else ''}")
    members = sum(r.batch for r in server.reports)
    print(
        f"\nserved {args.requests} members in {len(server.reports)} "
        f"batches / {server.op_builds} op builds, {wall:.2f}s "
        f"({members * args.steps / wall:.0f} member-steps/s)"
    )
    flagged = [r.index for r in server.reports if r.straggler]
    print(f"straggler batches: {flagged or 'none'} "
          f"(monitor history {len(server.straggler._times)} batches)")
    for rid, out in sorted(results.items())[:3]:
        print(f"  req {rid}: final field mean {float(np.mean(out)):+.3e}")
    print("serve_ensemble OK")


if __name__ == "__main__":
    main()
