"""Decaying MHD turbulence on a 32³ periodic box — the paper's production
use case (Sec. 3.3) end to end: CFL-stepped RK3 integration with the
fused stencil engine, kinetic/magnetic energy diagnostics, and a
cross-check between caching strategies mid-run.

Run:  PYTHONPATH=src python examples/mhd_simulation.py          (~2 min)
"""
import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.physics.mhd import (
    AX, AZ, LNRHO, MHDParams, MHDSolver, SS, UX, UZ,
)


def energies(f):
    rho = jnp.exp(f[LNRHO])
    u2 = jnp.sum(f[UX : UZ + 1] ** 2, axis=0)
    e_kin = float(jnp.mean(0.5 * rho * u2))
    # B = ∇×A via spectral curl would be overkill for a diagnostic; use
    # simple central differences at 2nd order on the periodic box.
    a = f[AX : AZ + 1]
    def d(q, ax):
        return (jnp.roll(q, -1, ax) - jnp.roll(q, 1, ax)) * (16 / (4 * np.pi))
    bx = d(a[2], 1) - d(a[1], 0)
    by = d(a[0], 0) - d(a[2], 2)
    bz = d(a[1], 2) - d(a[0], 1)
    e_mag = float(jnp.mean(0.5 * (bx**2 + by**2 + bz**2)))
    return e_kin, e_mag


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=32)
    ap.add_argument("--steps", type=int, default=40)
    ap.add_argument("--amplitude", type=float, default=0.05)
    args = ap.parse_args()

    solver = MHDSolver(
        (args.n,) * 3,
        params=MHDParams(nu=2e-2, eta=2e-2, kappa=2e-3),
        strategy="hwc",
    )
    f = solver.init_smooth(seed=3, amplitude=args.amplitude,
                           dtype=jnp.float32)
    step = jax.jit(lambda f, dt: solver.step(f, dt))

    print(f"MHD {args.n}^3, nu=eta=2e-2, RK3 + 6th-order FD")
    print(f"{'step':>5} {'t':>8} {'dt':>9} {'E_kin':>12} {'E_mag':>12} "
          f"{'max|u|':>9}")
    t_sim, t0 = 0.0, time.time()
    for i in range(args.steps):
        dt = float(solver.cfl_dt(f))
        f = step(f, dt)
        t_sim += dt
        if i % 8 == 0 or i == args.steps - 1:
            ek, em = energies(f)
            umax = float(jnp.abs(f[UX : UZ + 1]).max())
            print(f"{i:5d} {t_sim:8.3f} {dt:9.5f} {ek:12.4e} {em:12.4e} "
                  f"{umax:9.4f}", flush=True)
        assert np.isfinite(float(f.max())), "simulation blew up"
    wall = time.time() - t0
    ups = args.steps * args.n**3 / wall
    print(f"\n{args.steps} steps in {wall:.1f}s "
          f"({ups/1e6:.2f} Mupdates/s on CPU)")

    # Strategy cross-check mid-state (the paper's verification protocol),
    # with the SWC block resolved by the persistent autotuner: a cache
    # hit replays the recorded winner, a miss runs the paper's
    # rank-then-measure search once and persists it.
    from repro.tuning import format_block, lookup_fused_nd

    swc = MHDSolver((args.n,) * 3, params=solver.params, strategy="swc",
                    block="auto")
    err = float(jnp.abs(solver.rhs(f) - swc.rhs(f)).max())
    scale = float(jnp.abs(solver.rhs(f)).max())
    rec = lookup_fused_nd(f, swc.operator_set, f.shape[0], "swc")
    if rec is not None:
        print(f"auto-tuned SWC block: {format_block(rec.block)} "
              f"[{rec.source}]")
    print(f"HWC vs SWC on evolved state: max abs diff {err:.2e} "
          f"(field scale {scale:.2e})")
    assert err <= 1e-4 * max(scale, 1.0)
    print("mhd_simulation OK")


if __name__ == "__main__":
    main()
