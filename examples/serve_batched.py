"""Batched serving with continuous-batching-lite: a fixed device batch of
decode slots; finished sequences are immediately replaced from a request
queue (the slot's cache region is reset), so device utilization stays
flat as requests of different lengths complete — the core scheduling idea
behind production LLM serving, on a reduced model on CPU.

Run:  PYTHONPATH=src python examples/serve_batched.py
"""
import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.registry import get_config, get_model, reduced_config
from repro.distrib import sharding as shlib
from repro.launch.mesh import make_mesh
from repro.launch.serve_sim import RequestQueue


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2.5-3b")
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--requests", type=int, default=12)
    ap.add_argument("--max-new", type=int, default=24)
    args = ap.parse_args()

    cfg = reduced_config(get_config(args.arch))
    api = get_model(cfg)
    mesh = make_mesh((1, 1), ("data", "model"))
    shlib.set_rules(mesh)
    key = jax.random.PRNGKey(0)
    params = api.init_params(cfg, key)

    rng = np.random.default_rng(0)
    # Request queue (shared scaffolding with the ensemble serving loop):
    # (id, prompt token, target length) — lengths differ so slots free
    # at different times.
    queue = RequestQueue(
        (i, int(rng.integers(0, cfg.vocab)),
         int(rng.integers(args.max_new // 3, args.max_new)))
        for i in range(args.requests)
    )
    cache = api.init_decode_cache(cfg, args.slots, 64)

    @jax.jit
    def step(params, cache, tokens, key):
        logits, cache = api.decode_step(params, cfg, tokens, cache)
        key, sub = jax.random.split(key)
        nxt = jax.random.categorical(sub, logits, axis=-1)[:, None]
        return cache, nxt.astype(jnp.int32), key

    slot_req = [-1] * args.slots  # request id per slot
    slot_left = [0] * args.slots  # tokens remaining
    outputs: dict[int, list[int]] = {}
    tokens = jnp.zeros((args.slots, 1), jnp.int32)
    completed, t0, steps = 0, time.time(), 0

    def fill_slots():
        nonlocal tokens
        tok_host = np.array(tokens)  # writable host copy
        for s in range(args.slots):
            if slot_left[s] == 0 and queue:
                rid, prompt, length = queue.pop()
                slot_req[s], slot_left[s] = rid, length
                outputs[rid] = []
                tok_host[s, 0] = prompt
        tokens = jnp.asarray(tok_host)

    fill_slots()
    while completed < args.requests:
        cache, tokens, key = step(params, cache, tokens, key)
        steps += 1
        tok_host = np.asarray(tokens)
        for s in range(args.slots):
            if slot_left[s] > 0:
                outputs[slot_req[s]].append(int(tok_host[s, 0]))
                slot_left[s] -= 1
                if slot_left[s] == 0:
                    completed += 1
        fill_slots()

    dt = time.time() - t0
    total_tokens = sum(len(v) for v in outputs.values())
    print(
        f"served {args.requests} requests / {total_tokens} tokens in "
        f"{steps} batch-steps, {dt:.2f}s "
        f"({total_tokens/dt:.1f} tok/s, slot util "
        f"{total_tokens/(steps*args.slots)*100:.0f}%)"
    )
    for rid in sorted(outputs)[:4]:
        print(f"  req {rid}: {len(outputs[rid])} tokens: "
              f"{outputs[rid][:10]}...")
    assert completed == args.requests
    print("serve_batched OK")


if __name__ == "__main__":
    main()
