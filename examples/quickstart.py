"""Quickstart: the three layers of the framework in ~a minute on CPU.

1. The paper's fused stencil engine (φ(A·B)) on a 3-D multiphysics RHS,
   HWC vs SWC strategies agreeing bitwise-ish.
2. The diffusion equation solved with ONE merged cross-correlation kernel
   (paper Eq. 5-7), validated against the exact discrete eigenvalue.
3. A reduced LM architecture from the zoo taking real train steps.

Demos 1-2 are the paper reproduction: everything they touch lives in
``repro.{core,kernels,physics,tuning}`` (the StencilPlan pipeline —
see docs/architecture.md). Demo 3 is NOT part of the stencil pipeline:
``repro.models`` / ``repro.configs`` are the beyond-paper architecture
zoo that reuses the same kernel techniques (e.g. mamba2's depthwise
conv); skip it if you are here for the stencils.

Run:  PYTHONPATH=src python examples/quickstart.py
"""

import jax
import jax.numpy as jnp
import numpy as np


def stencil_demo():
    print("=== 1. fused multiphysics stencil (paper Sec. 4.4) ===")
    from repro.physics.mhd import MHDSolver

    solver_hwc = MHDSolver((16, 16, 16), strategy="hwc")
    # strategy="auto": the persistent autotuner (repro.tuning) picks the
    # whole caching regime — hwc (XLA-managed) vs swc (Pallas VMEM
    # blocks) vs swc_stream — jointly with the block, measured on first
    # use and cached under ~/.cache/repro-tune (or $REPRO_TUNE_CACHE).
    # Run `python -m repro.tuning show` to see the recorded tables.
    solver_auto = MHDSolver((16, 16, 16), strategy="auto")
    f = solver_hwc.init_smooth(seed=0, amplitude=1e-2, dtype=jnp.float32)
    r1 = solver_hwc.rhs(f)
    r2 = solver_auto.rhs(f)
    err = float(jnp.abs(r1 - r2).max())
    rop = solver_auto.rhs_op().resolved(f)  # warm cache hit
    print(f"  8-field MHD RHS, 10 operators, 127 taps fused in one kernel")
    print(f"  strategy='auto' resolved to {rop.strategy!r} "
          f"(block={rop.block}, depth={rop.fuse_steps})")
    print(f"  XLA-managed (HWC) vs auto-tuned strategy max diff: {err:.2e}")
    dt = float(solver_hwc.cfl_dt(f))
    f1 = solver_hwc.step(f, dt)
    print(f"  one RK3 step (dt={dt:.3f}): max|Δf| = "
          f"{float(jnp.abs(f1 - f).max()):.3e}\n")


def diffusion_demo():
    print("=== 2. diffusion as one merged kernel (paper Eq. 5-7) ===")
    from repro.physics.diffusion import DiffusionProblem, simulate

    p = DiffusionProblem((16, 16, 32), accuracy=6)
    k = (1, 2, 1)
    f0 = p.fourier_mode(k)
    out = simulate(p, f0, 50)
    decay = float(jnp.linalg.norm(out) / jnp.linalg.norm(f0))
    spec = p.merged_stencil()
    lam = sum(
        c * np.cos(sum(ki * oi * hi for ki, oi, hi in zip(k, o, p.spacing)))
        for o, c in zip(spec.offsets, spec.coeffs)
    )
    print(f"  mode {k}: measured decay {decay:.6f}, "
          f"exact eigenvalue^50 {lam**50:.6f}\n")


def lm_demo():
    print("=== 3. architecture zoo (beyond-paper; not the stencil "
          "pipeline): one real train step ===")
    from repro.configs.registry import get_config, get_model, reduced_config
    from repro.optim import AdamWConfig, adamw_init, adamw_update

    for arch in ("qwen2.5-3b", "mamba2-780m", "mixtral-8x7b"):
        cfg = reduced_config(get_config(arch))
        api = get_model(cfg)
        key = jax.random.PRNGKey(0)
        params = api.init_params(cfg, key)
        tokens = jax.random.randint(key, (2, 32), 0, cfg.vocab)
        batch = {"tokens": tokens, "labels": jnp.roll(tokens, -1, 1)}
        (loss, _), grads = jax.value_and_grad(api.lm_loss, has_aux=True)(
            params, cfg, batch
        )
        params, _, m = adamw_update(
            AdamWConfig(), grads, adamw_init(params), params
        )
        print(f"  {arch:16s} [{cfg.family}] loss={float(loss):.3f} "
              f"gnorm={float(m['grad_norm']):.2f}")
    print()


if __name__ == "__main__":
    stencil_demo()
    diffusion_demo()
    lm_demo()
    print("quickstart OK")
