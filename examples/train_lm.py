"""End-to-end training driver (assignment deliverable b): a ~15M-param
transformer trained a few hundred steps on learnable Markov-chain data,
with checkpointing, an injected node failure, automatic restore, and
bit-identical data replay — the full fault-tolerance path on CPU.

Run:  PYTHONPATH=src python examples/train_lm.py          (~5-10 min CPU)
      PYTHONPATH=src python examples/train_lm.py --steps 100   (faster)
"""
import argparse
import dataclasses
import tempfile
import time

import jax
import jax.numpy as jnp

from repro.checkpoint import CheckpointManager
from repro.configs.registry import get_config
from repro.data import BatchIterator, MarkovLMDataset
from repro.distrib import sharding as shlib
from repro.ft import Supervisor
from repro.launch.mesh import make_mesh
from repro.launch.steps import jit_train_step
from repro.models.config import ModelConfig
from repro.optim import AdamWConfig, adamw_init


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--global-batch", type=int, default=16)
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--fail-at", type=int, default=120)
    args = ap.parse_args()

    # ~15M params: a shrunken qwen2.5 (same family/topology).
    cfg = dataclasses.replace(
        get_config("qwen2.5-3b"),
        n_layers=4, d_model=256, n_heads=8, n_kv_heads=2, head_dim=32,
        d_ff=1024, vocab=512, dtype="float32", remat="none",
    )
    print(f"model: {cfg.n_params()/1e6:.1f}M params")

    mesh = make_mesh((1, 1), ("data", "model"))
    shlib.set_rules(mesh)
    dataset = MarkovLMDataset(vocab=cfg.vocab, seq_len=args.seq_len,
                              branching=4)
    print(f"data: order-1 Markov chain, entropy rate "
          f"{dataset.entropy_rate:.3f} nats/token")

    opt_cfg = AdamWConfig(lr_peak=8e-3, warmup_steps=20,
                          total_steps=args.steps)
    batch_abs = {
        k: jax.ShapeDtypeStruct((args.global_batch, args.seq_len), jnp.int32)
        for k in ("tokens", "labels")
    }
    with shlib.rules_context(mesh):
        step_fn, (p_sh, o_sh, b_sh) = jit_train_step(
            cfg, mesh, batch_abs, opt_cfg=opt_cfg
        )
        from repro.configs.registry import get_model

        api = get_model(cfg)
        params = api.init_params(cfg, jax.random.PRNGKey(0))
        opt = adamw_init(params)

        ckpt_dir = tempfile.mkdtemp(prefix="repro_train_lm_")
        ckpt = CheckpointManager(ckpt_dir, keep=2)
        sup = Supervisor(ckpt, ckpt_every=50)
        losses = []

        def one_step(state, step):
            it = BatchIterator(dataset, args.global_batch, host_index=0,
                               host_count=1, start_step=step)
            params, opt, metrics = step_fn(
                state["params"], state["opt"], it.next_local()
            )
            loss = float(metrics["loss"])
            losses.append((step, loss))
            if step % 25 == 0:
                print(f"  step {step:4d}  loss {loss:.4f}", flush=True)
            return {"params": params, "opt": opt}

        def restore(state, step):
            if step is None:
                return state, 0
            restored, got = ckpt.restore(state, step)
            print(f"  >> restored checkpoint at step {got}")
            return restored, got

        t0 = time.time()
        state, report = sup.run(
            {"params": params, "opt": opt}, one_step, args.steps,
            failure_at=args.fail_at, restore_fn=restore,
        )
        dt = time.time() - t0

    first = losses[0][1]
    final = losses[-1][1]
    print(
        f"\ntrained {args.steps} steps in {dt:.0f}s "
        f"({args.steps*args.global_batch*args.seq_len/dt:.0f} tok/s): "
        f"loss {first:.3f} → {final:.3f} "
        f"(entropy rate {dataset.entropy_rate:.3f}); "
        f"injected failures recovered: {report['restarts']}"
    )
    assert final < first - 0.5, "loss should drop by >0.5 nats"
    assert report["restarts"] == 1, "expected exactly one injected failure"
    print("train_lm OK")


if __name__ == "__main__":
    main()
