#!/usr/bin/env python
"""Repo-specific lint rules ruff has no knowledge of.

Three rules, enforced by AST walk (not regex), each waivable per line
with ``# repolint: allow[rule-name]`` on the offending line or the
line above (a waiver states the exception is sanctioned — use
sparingly and say why in a neighboring comment):

* ``sys-path-hack`` — no ``sys.path`` mutation anywhere: the package
  is importable via ``pip install -e .`` or ``PYTHONPATH=src``, and
  path hacks silently shadow the installed package with stale trees.
* ``legacy-kernel-import`` — no direct imports of the historical
  ``repro.kernels.stencil1d``/``stencil3d`` modules outside
  ``repro/kernels/compat.py``: call sites go through
  ``repro.kernels.ops`` (the facade), so the legacy modules can keep
  shrinking without breaking users.
* ``broad-except`` — no bare ``except:`` / ``except Exception:`` that
  DISCARDS the exception (no ``as e`` binding) outside ``src/repro/ft/``
  (the fault-tolerance layer intentionally fences arbitrary failures).
  Binding the exception is allowed — it signals the handler logs or
  re-raises deliberately.

Usage: ``python tools/lint_repo.py [paths...]`` (default: the repo's
source trees). Exit 1 iff any violation. Wired into the CI lint job
next to ruff.
"""
from __future__ import annotations

import ast
import re
import sys
from pathlib import Path

DEFAULT_PATHS = ("src", "tests", "benchmarks", "examples", "tools")
WAIVER_RE = re.compile(r"#\s*repolint:\s*allow\[([a-z-]+(?:,\s*[a-z-]+)*)\]")
LEGACY_MODULES = ("stencil1d", "stencil3d")


def _waivers(lines: list[str]) -> dict[int, set[str]]:
    """Map 1-based line number -> rule names waived on that line (a
    waiver comment also covers the line directly below it)."""
    out: dict[int, set[str]] = {}
    for i, line in enumerate(lines, start=1):
        m = WAIVER_RE.search(line)
        if m:
            rules = {r.strip() for r in m.group(1).split(",")}
            out.setdefault(i, set()).update(rules)
            out.setdefault(i + 1, set()).update(rules)
    return out


def _is_sys_path(node: ast.AST) -> bool:
    return (
        isinstance(node, ast.Attribute)
        and node.attr == "path"
        and isinstance(node.value, ast.Name)
        and node.value.id == "sys"
    )


def _legacy_import(node: ast.AST) -> str | None:
    if isinstance(node, ast.ImportFrom) and node.module:
        parts = node.module.split(".")
        if parts[-1] in LEGACY_MODULES and "kernels" in parts:
            return node.module
        if node.module.endswith("kernels"):
            for alias in node.names:
                if alias.name in LEGACY_MODULES:
                    return f"{node.module}.{alias.name}"
    if isinstance(node, ast.Import):
        for alias in node.names:
            parts = alias.name.split(".")
            if parts[-1] in LEGACY_MODULES and "kernels" in parts:
                return alias.name
    return None


def lint_file(path: Path) -> list[tuple[int, str, str]]:
    """Return (line, rule, message) violations for one file."""
    rel = path.as_posix()
    text = path.read_text()
    try:
        tree = ast.parse(text, filename=rel)
    except SyntaxError as e:
        return [(e.lineno or 0, "syntax", f"unparsable: {e.msg}")]
    waived = _waivers(text.splitlines())
    out: list[tuple[int, str, str]] = []

    def emit(line: int, rule: str, msg: str) -> None:
        if rule not in waived.get(line, set()):
            out.append((line, rule, msg))

    in_ft = "/ft/" in f"/{rel}"
    in_compat = rel.endswith("kernels/compat.py")
    is_legacy_self = any(
        rel.endswith(f"kernels/{m}.py") for m in LEGACY_MODULES
    )
    for node in ast.walk(tree):
        if _is_sys_path(node):
            emit(
                node.lineno, "sys-path-hack",
                "sys.path mutation — install the package "
                "(pip install -e .) or set PYTHONPATH instead",
            )
        if not in_ft and isinstance(node, ast.ExceptHandler):
            broad = node.type is None or (
                isinstance(node.type, ast.Name)
                and node.type.id in ("Exception", "BaseException")
            )
            if broad and node.name is None:
                emit(
                    node.lineno, "broad-except",
                    "bare `except Exception:` discards the error — "
                    "bind it (`as e`) and log, or narrow the type",
                )
        if not (in_compat or is_legacy_self):
            mod = _legacy_import(node)
            if mod is not None:
                emit(
                    node.lineno, "legacy-kernel-import",
                    f"direct import of legacy module {mod} — go "
                    "through repro.kernels.ops (or kernels/compat.py)",
                )
    return out


def main(argv: list[str]) -> int:
    roots = [Path(p) for p in (argv or DEFAULT_PATHS)]
    files: list[Path] = []
    for root in roots:
        if root.is_file():
            files.append(root)
        elif root.is_dir():
            files.extend(sorted(root.rglob("*.py")))
    n = 0
    for f in files:
        for line, rule, msg in lint_file(f):
            print(f"{f.as_posix()}:{line}: [{rule}] {msg}")
            n += 1
    if n:
        print(f"{n} repolint violation(s)")
        return 1
    print(f"repolint: {len(files)} files clean")
    return 0


if __name__ == "__main__":
    sys.exit(main(sys.argv[1:]))
