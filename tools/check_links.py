#!/usr/bin/env python3
"""Intra-repo markdown link checker (stdlib only — the CI docs job).

Scans the given markdown files/directories for ``[text](target)``
links and reference-style ``[text]: target`` definitions, and fails
(exit 1) when a relative target does not exist on disk. External
schemes (http/https/mailto) and pure in-page anchors (``#…``) are
skipped; a ``path#anchor`` target is checked for the path part only.

Usage:
    python tools/check_links.py README.md docs
"""
from __future__ import annotations

import re
import sys
from pathlib import Path

# Inline [text](target) — target up to the first unescaped ')' — plus
# reference definitions "[label]: target" at line start.
INLINE = re.compile(r"\[[^\]]*\]\(([^)\s]+)(?:\s+\"[^\"]*\")?\)")
REFDEF = re.compile(r"^\s*\[[^\]]+\]:\s+(\S+)", re.MULTILINE)
SKIP_SCHEMES = ("http://", "https://", "mailto:", "ftp://")


def md_files(paths: list[str]) -> list[Path]:
    """Expand files/directories into the markdown files to scan."""
    out: list[Path] = []
    for p in paths:
        path = Path(p)
        if path.is_dir():
            out.extend(sorted(path.rglob("*.md")))
        elif path.exists():
            out.append(path)
        else:
            print(f"check_links: no such file or directory: {p}")
            sys.exit(2)
    return out


def check_file(md: Path) -> list[str]:
    """Broken-link messages for one markdown file."""
    text = md.read_text(encoding="utf-8")
    errors = []
    targets = INLINE.findall(text) + REFDEF.findall(text)
    for target in targets:
        if target.startswith(SKIP_SCHEMES) or target.startswith("#"):
            continue
        path_part = target.split("#", 1)[0]
        if not path_part:
            continue
        resolved = (md.parent / path_part).resolve()
        if not resolved.exists():
            errors.append(f"{md}: broken link -> {target}")
    return errors


def main(argv: list[str]) -> int:
    """Check every argument (file or directory); 0 iff no broken links."""
    files = md_files(argv or ["README.md", "docs"])
    errors = [e for md in files for e in check_file(md)]
    for e in errors:
        print(e)
    print(
        f"check_links: {len(files)} file(s), "
        f"{len(errors)} broken link(s)"
    )
    return 1 if errors else 0


if __name__ == "__main__":
    sys.exit(main(sys.argv[1:]))
