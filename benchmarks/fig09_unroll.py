"""Paper Fig. 9: tuning-strategy comparison for the hand-written kernel —
baseline (1 output/thread, rolled MAC), element-wise unrolling (4
adjacent outputs reuse each coefficient), stencil-point-wise unrolling
(MAC loop unrolled ×4). Same three strategies, TPU block terms."""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from benchmarks.util import emit, time_fn
from repro.kernels import ops


def run(full: bool = False) -> None:
    n = (16 if full else 1) * 1024 * 1024 // 4
    rng = np.random.default_rng(0)
    radii = (4, 64, 512) if full else (4, 64)
    for r in radii:
        f = jnp.asarray(rng.standard_normal(n + 2 * r), jnp.float32)
        g = jnp.asarray(rng.standard_normal(2 * r + 1), jnp.float32)
        for strat in ("baseline", "elementwise", "pointwise"):
            t = time_fn(
                lambda f=f, g=g, s=strat: ops.xcorr1d(
                    f, g, strategy=s, block_size=4096, unroll=4
                ),
                iters=3,
            )
            emit(f"fig09/{strat}/r{r}", t, "unroll=4")
