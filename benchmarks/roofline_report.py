"""Render EXPERIMENTS.md §Dry-run / §Roofline tables from the sweep JSON.

    PYTHONPATH=src python -m benchmarks.roofline_report results/dryrun.json

When a ``BENCH_summary.json`` is present (repo root, or a second
positional path) an extra section renders the stencil kernels' compute
roof next to their bandwidth roof: ``tc`` rows carry an
``mxu_roofline_fraction`` (time at peak MXU rate / measured time), so
the table shows both fractions side by side and names the binding roof
per kernel — the two-roof view the tc regime is tuned against.
"""
from __future__ import annotations

import json
import os
import sys


def fmt_e(x):
    return f"{x:.2e}" if x is not None else "—"


def dryrun_table(rows) -> str:
    out = [
        "| arch | shape | mesh | status | compile s | args GiB/chip | temp GiB/chip |",
        "|---|---|---|---|---|---|---|",
    ]
    for r in rows:
        if r.get("status") == "ok":
            mem = r.get("memory", {})
            gib = 1024**3
            args_g = mem.get("argument_size_in_bytes", 0) / gib
            temp_g = mem.get("temp_size_in_bytes", 0) / gib
            out.append(
                f"| {r['arch']} | {r['shape']} | {r['mesh']} | ok | "
                f"{r['compile_s']} | {args_g:.2f} | {temp_g:.2f} |"
            )
        else:
            status = r.get("status", "?")
            short = status if len(status) < 48 else status[:45] + "..."
            out.append(
                f"| {r['arch']} | {r['shape']} | {r['mesh']} | {short} "
                f"| — | — | — |"
            )
    return "\n".join(out)


def roofline_table(rows) -> str:
    out = [
        "| arch | shape | compute s | memory s (model) | memory s (HLO-UB) "
        "| collective s | dominant | useful-FLOP ratio | roofline frac |",
        "|---|---|---|---|---|---|---|---|---|",
    ]
    for r in rows:
        if r.get("status") != "ok" or r.get("mesh") != "single":
            continue
        out.append(
            f"| {r['arch']} | {r['shape']} | {fmt_e(r['compute_s'])} "
            f"| {fmt_e(r['memory_s'])} | {fmt_e(r.get('memory_s_hlo_upper'))} "
            f"| {fmt_e(r['collective_s'])} | {r['dominant']} "
            f"| {r['useful_flops_ratio']:.2f} "
            f"| {r['roofline_fraction']:.3f} |"
        )
    return "\n".join(out)


def collective_summary(rows) -> str:
    out = [
        "| arch | shape | AG | AR | RS | A2A | CP | wire GiB/chip |",
        "|---|---|---|---|---|---|---|---|",
    ]
    for r in rows:
        if r.get("status") != "ok" or r.get("mesh") != "single":
            continue
        c = r.get("coll_counts", {})
        out.append(
            f"| {r['arch']} | {r['shape']} | {c.get('all-gather', 0)} "
            f"| {c.get('all-reduce', 0)} | {c.get('reduce-scatter', 0)} "
            f"| {c.get('all-to-all', 0)} | {c.get('collective-permute', 0)} "
            f"| {r['coll_wire_bytes'] / 1024**3:.2f} |"
        )
    return "\n".join(out)


def stencil_roof_table(kernels: dict) -> str:
    """Two-roof table for BENCH_summary.json kernel entries: bandwidth
    fraction for every roofline-comparable kernel, MXU fraction for the
    ``tc`` rows that report one, and which roof binds (the larger
    fraction is the nearer ceiling)."""
    out = [
        "| kernel | us/call | bw frac | mxu frac | binding roof |",
        "|---|---|---|---|---|",
    ]
    for name in sorted(kernels):
        k = kernels[name]
        bw = k.get("roofline_fraction")
        mxu = k.get("mxu_roofline_fraction")
        if bw is None and mxu is None:
            continue
        binding = "—"
        if mxu is not None:
            binding = "compute (MXU)" if mxu > (bw or 0.0) else "memory (HBM)"
        elif bw is not None:
            binding = "memory (HBM)"
        out.append(
            f"| {name} | {k.get('us_per_call', 0):.1f} "
            f"| {bw if bw is not None else '—'} "
            f"| {mxu if mxu is not None else '—'} | {binding} |"
        )
    return "\n".join(out)


def main() -> None:
    path = sys.argv[1] if len(sys.argv) > 1 else "results/dryrun.json"
    rows = json.load(open(path))
    ok = sum(1 for r in rows if r.get("status") == "ok")
    fail = [r for r in rows if r.get("status") == "FAIL"]
    skip = sum(
        1 for r in rows
        if r.get("status") not in ("ok", "FAIL")
    )
    print(f"## Dry-run matrix ({ok} ok / {skip} documented skips / "
          f"{len(fail)} failed)\n")
    print(dryrun_table(rows))
    print("\n## Roofline terms (single-pod, 256 chips)\n")
    print(roofline_table(rows))
    print("\n## Collective inventory (single-pod)\n")
    print(collective_summary(rows))
    if fail:
        print("\n## Failures\n")
        for r in fail:
            print(f"- {r['arch']} × {r['shape']} × {r['mesh']}: {r['error']}")
    summary = sys.argv[2] if len(sys.argv) > 2 else "BENCH_summary.json"
    if os.path.exists(summary):
        try:
            kernels = json.load(open(summary)).get("kernels", {})
        except ValueError:
            kernels = {}
        if kernels:
            print(f"\n## Stencil rooflines ({summary})\n")
            print(stencil_roof_table(kernels))


if __name__ == "__main__":
    main()
