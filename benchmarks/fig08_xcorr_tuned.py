"""Paper Fig. 8: hand-tuned 1-D cross-correlation, HWC vs SWC, vs radius.

HWC = pure-jnp shifted multiply-accumulate (XLA owns residency);
SWC = the Pallas kernel (explicit VMEM blocks; interpret mode on CPU).
The derived column reports the bandwidth-bound roofline time on TPU
constants — the paper's observation (bandwidth-bound at small r,
cache-bound at large r) is reproduced structurally in EXPERIMENTS.md.
"""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from benchmarks.util import emit, time_fn
from repro.core.rooflinelib import TPU_V5E
from repro.kernels import ops


def run(full: bool = False) -> None:
    n = (16 if full else 1) * 1024 * 1024 // 4
    rng = np.random.default_rng(0)
    radii = (1, 4, 16, 64, 256, 1024) if full else (1, 16, 128)
    for r in radii:
        f = jnp.asarray(rng.standard_normal(n + 2 * r), jnp.float32)
        g = jnp.asarray(rng.standard_normal(2 * r + 1), jnp.float32)
        roof_t = (2 * n * 4) / TPU_V5E.hbm_bw  # read+write once
        for strat in ("hwc", "baseline"):
            label = {"hwc": "hwc", "baseline": "swc"}[strat]
            t = time_fn(
                lambda f=f, g=g, s=strat: ops.xcorr1d(
                    f, g, strategy=s, block_size=4096
                ),
                iters=3,
            )
            emit(
                f"fig08/xcorr_{label}/r{r}", t,
                f"tpu_bw_bound_s={roof_t:.2e};"
                f"flops_per_byte={(2 * (2 * r + 1)) / 8:.1f}",
            )
