"""Paper Fig. 14 / C1: tuning-parameter exploration — block-shape sweep
for the fused 3-D kernel (the __launch_bounds__/thread-block analogue on
TPU), driven by the persistent tuning subsystem: structural cost-model
ranking, measured timing of the top candidates (``force=True`` so the
benchmark always re-measures), and the winner recorded in the on-disk
cache that ``block="auto"`` call sites replay."""
from __future__ import annotations

import jax

from benchmarks.util import emit, smoke
from repro.physics.mhd import MHDSolver, N_FIELDS
from repro.tuning import (
    TuningSession,
    default_session,
    format_block,
    fused_nd_candidates,
    fused_nd_key,
    time_candidate,
)


def run(full: bool = False) -> None:
    n = 32 if full else 16
    shape = (n, n, n)
    solver0 = MHDSolver(shape, strategy="swc")
    f0 = solver0.init_fields()
    radii = solver0.rhs_op().radius_per_axis
    key = fused_nd_key(
        shape, radii, N_FIELDS, N_FIELDS, str(f0.dtype), "swc"
    )
    cands = fused_nd_candidates(
        shape, radii, N_FIELDS, N_FIELDS, f0.dtype.itemsize
    )
    by_block = {c.block: c for c in cands}

    iters = 1 if smoke() else 3
    session = TuningSession(
        cache=default_session().cache,
        top_k=2 if smoke() else (8 if full else 4),
        warmup=1,
        iters=iters,
        # Smoke timings are single-iteration noise: stamp them "smoke" so
        # full-protocol callers (repro.tuning warm, eager auto sites)
        # re-measure instead of replaying them forever.
        record_source="smoke" if smoke() else "measured",
    )

    def measure(cand):
        solver = MHDSolver(shape, strategy="swc", block=cand.block)
        rhs = jax.jit(solver.rhs)
        return time_candidate(lambda: rhs(f0), warmup=1, iters=iters)

    # Full runs re-measure unconditionally (that IS the benchmark); a
    # --smoke run must not overwrite a properly measured record with a
    # single-iteration winner, so it only fills a cold cache.
    record = session.tune(key, cands, measure, force=not smoke())
    winner = format_block(record.block)
    for blk_s, us in sorted(
        record.timings_us.items(), key=lambda kv: kv[1]
    ):
        cand = by_block.get(tuple(int(x) for x in blk_s.split("x")))
        derived = (
            f"vmem_KiB={cand.vmem_bytes // 1024};"
            f"halo_overhead={cand.halo_overhead:.2f};"
            f"model_score={cand.score:.3f};"
        ) if cand is not None else ""
        emit(
            f"fig14/blocktune/{blk_s}", us / 1e6,
            derived + f"winner={int(blk_s == winner)}",
        )
