"""Paper Fig. 14 / C1: tuning-parameter exploration — block-shape sweep
for the fused 3-D kernel (the __launch_bounds__/thread-block analogue on
TPU), via the autotune harness: structural cost-model ranking + measured
timing of the top candidates."""
from __future__ import annotations

import numpy as np

from benchmarks.util import emit
from repro.core.autotune import enumerate_candidates, time_candidate
from repro.physics.mhd import MHDSolver, N_FIELDS


def run(full: bool = False) -> None:
    n = 32 if full else 16
    shape = (n, n, n)
    cands = enumerate_candidates(
        shape, (3, 3, 3), N_FIELDS, N_FIELDS, 4,
        tx_options=(16, 32, 64) if not full else (32, 64, 128),
        ty_options=(4, 8, 16),
        tz_options=(4, 8, 16),
    )
    solver0 = MHDSolver(shape, strategy="swc")
    f0 = solver0.init_fields()
    import jax

    for cand in cands[: (8 if full else 4)]:
        solver = MHDSolver(shape, strategy="swc", block=cand.block)
        rhs = jax.jit(solver.rhs)
        try:
            t = time_candidate(lambda: rhs(f0), warmup=1, iters=3)
        except Exception:
            continue  # discarded launch (paper protocol)
        emit(
            f"fig14/blocktune/{'x'.join(map(str, cand.block))}", t,
            f"vmem_KiB={cand.vmem_bytes // 1024};"
            f"halo_overhead={cand.halo_overhead:.2f};"
            f"model_score={cand.score:.3f}",
        )
