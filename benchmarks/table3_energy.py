"""Paper Table 3: energy efficiency (million element updates per second
per watt), derived from TDP — the paper's own method (no power rails on
either setup; they divide throughput by the published TDP).

Throughput here is the TPU-roofline bound for each case (the deployable
upper bound from §Roofline terms), TDP = v5e-class 200 W. Flagged as
DERIVED in the name — on hardware the same harness divides measured
throughput instead.

The ``fused_energy`` rows close the temporal-fusion loop on Table 3: a
``fuse_steps`` sweep over the depth-S diffusion kernel emitting
measured-vs-modeled J/update per depth. The modeled term converts the
traffic model's HBM bytes/step to time at the roofline bandwidth and
multiplies by TDP; the measured term multiplies the timed per-step wall
clock by TDP (on this CPU container the "measured" number exercises the
harness — on TPU hardware the same rows report real silicon energy).
See docs/benchmarks.md for the row schema.
"""
from __future__ import annotations

import jax
import numpy as np

from benchmarks.util import emit, smoke, time_fn
from repro.core.rooflinelib import TPU_V5E, stencil_ideal_bytes
from repro.core.stencil import derivative_operator_set
from repro.core.trafficmodel import stencil_hbm_bytes_per_step
from repro.physics.mhd import N_FIELDS


def _fused_energy_sweep(full: bool) -> None:
    """Measured-vs-modeled J/update per temporal-fusion depth (the
    ROADMAP fused-depth energy-table item)."""
    from repro.physics.diffusion import DiffusionProblem
    from repro.tuning import lookup_fused_nd

    hw = TPU_V5E
    shape = (
        (2048, 2048) if full else (64, 64) if smoke() else (256, 256)
    )
    p = DiffusionProblem(shape, accuracy=6)
    f0 = p.init_field()
    n = int(np.prod(shape))
    for depth in (1, 2, 4):
        op = p.step_op("swc", block="auto", fuse_steps=depth)
        op(f0)  # eager warm: tune-and-persist on a cache miss
        rec = lookup_fused_nd(f0, op.ops, 1, "swc", fuse_steps=depth)
        block = tuple(rec.block) if rec is not None else (16, 128)
        t = time_fn(jax.jit(op), f0, iters=3) / depth
        bytes_step = stencil_hbm_bytes_per_step(
            shape, block, (p.radius,) * p.ndim, 1, 1, 4, depth
        )
        t_model = bytes_step / hw.hbm_bw
        measured_uj = t * hw.tdp_watts / n * 1e6
        modeled_uj = t_model * hw.tdp_watts / n * 1e6
        emit(
            f"table3/fused_energy/2d_r{p.radius}_f{depth}", t,
            f"uJ_per_update_measured={measured_uj:.4f};"
            f"uJ_per_update_modeled={modeled_uj:.6f};"
            f"model_bytes_per_step={bytes_step:.0f};"
            f"tdp_W={hw.tdp_watts:.0f}",
        )


def run(full: bool = False) -> None:
    hw = TPU_V5E
    rows = []

    # Cross-correlation, n = 16Mi elements, FP32 r=1 (paper row 1).
    n = 16 * 1024 * 1024
    t_bw = 2 * n * 4 / hw.hbm_bw
    rows.append(("xcorr/fp32_r1", n, t_bw))
    # FP64 r=1024: compute-heavier; TPU FP64 is emulated ≈ 1/8 fp32 rate.
    flops = 2 * n * 2049
    t = max(flops / (hw.peak_flops_f32 / 8), 2 * n * 8 / hw.hbm_bw)
    rows.append(("xcorr/fp64_r1024", n, t))

    # Diffusion 256³ (fp32 r=1, fp64 r=4).
    n3 = 256**3
    rows.append(("diffusion/fp32_r1", n3, 2 * n3 * 4 / hw.hbm_bw))
    ops_d = derivative_operator_set(3, 8)
    flops = ops_d.flops_per_point(1) * n3
    t = max(2 * n3 * 8 / hw.hbm_bw, flops / (hw.peak_flops_f32 / 8))
    rows.append(("diffusion/fp64_r4", n3, t))

    # MHD 128³ (r=3, 8 fields, RK3 = 3 passes).
    nm = 128**3
    ops_m = derivative_operator_set(3, 6)
    bytes_pass = stencil_ideal_bytes(nm, N_FIELDS, N_FIELDS, 4)
    flops_pass = ops_m.flops_per_point(N_FIELDS) * nm * 3  # + phi ≈ 3x
    t32 = 3 * max(bytes_pass / hw.hbm_bw, flops_pass / hw.peak_flops_f32)
    rows.append(("mhd/fp32_r3", nm, t32))
    t64 = 3 * max(
        2 * bytes_pass / hw.hbm_bw, flops_pass / (hw.peak_flops_f32 / 8)
    )
    rows.append(("mhd/fp64_r3", nm, t64))

    for name, n_updates, t in rows:
        mups_w = n_updates / t / 1e6 / hw.tdp_watts
        emit(
            f"table3/derived_energy/{name}", t,
            f"Mupdates_per_s_per_W={mups_w:.1f};tdp_W={hw.tdp_watts:.0f}",
        )

    _fused_energy_sweep(full)
