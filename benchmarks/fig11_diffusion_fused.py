"""Paper Figs. 11-12: diffusion equation with the fused stencil engine,
1/2/3-D, radius (accuracy) sweep, HWC vs SWC strategies — the SWC path
now runs at every rank through the StencilPlan lowering layer. The SWC
block comes from the tuning subsystem (``block="auto"``): the eager warm
call measures-and-records on a cache miss, the jitted timing loop
replays the persisted winner.

``fuse_steps > 1`` (the ``--fuse-steps`` driver flag) additionally
benchmarks temporal fusion: one kernel advances that many Euler steps
on halo-widened VMEM blocks, timings are reported PER STEP, and the
derived column carries the traffic model's predicted HBM reduction so
measured and modeled wins land in the same artifact row.

The ``--strategies`` driver flag widens the strategy sweep — e.g.
``--strategies swc_stream`` benchmarks the explicit-streaming kernel
(y-streaming at rank 2, z-streaming at rank 3; skipped at rank 1,
which has no cross-stream axis), composing with ``--fuse-steps``.

``--strategies auto`` benchmarks ``strategy="auto"``: the
cross-strategy tuning search picks the caching regime (hwc vs swc vs
swc_stream vs tc) jointly with block/depth/stream, and the row's
derived column reports which regime won (``auto_strategy=...``,
``auto_depth=...``) so the decision lands in ``BENCH_summary.json``
per shape.

``--strategies tc`` benchmarks the MXU regime: derivative taps lower
to banded coefficient-matrix contractions over the VMEM-resident
block. Its rows carry two extra derived fields: ``tpu_mxu_bound_s``
(the compute roof next to the bandwidth roof, so the summary can form
``mxu_roofline_fraction``) and ``mxu_crossover_depth`` (the smallest
temporal-fusion depth at which the cost model ranks a tc candidate
above every VPU candidate — 0 when the VPU wins at every enumerated
depth).
"""
from __future__ import annotations

import jax
import numpy as np

from benchmarks.util import emit, smoke, time_fn
from repro.core.rooflinelib import TPU_V5E, stencil_mxu_roof_s
from repro.core.trafficmodel import (
    stencil_mxu_flops_per_step,
    stencil_traffic_reduction,
)
from repro.kernels.plan import tc_groups_per_axis
from repro.physics.diffusion import DiffusionProblem
from repro.tuning import format_block, lookup_fused_nd
from repro.tuning.costmodel import enumerate_cross_strategy_nd


def _mxu_crossover_depth(
    shape: tuple[int, ...], radius: int, depths: tuple[int, ...] = (1, 2, 4, 8)
) -> int:
    """Smallest enumerated fusion depth where the cost model ranks some
    tc candidate above every swc/swc_stream candidate of the same depth
    (0 = the VPU wins everywhere): deeper fusion amortizes halo traffic
    but multiplies VPU tap work, while the tc matmul rides the MXU —
    the crossover the fig11 tc series exists to locate."""
    ndim = len(shape)
    cands = enumerate_cross_strategy_nd(
        shape, (radius,) * ndim, 1, 1, 4, fuse_steps_options=depths
    )
    for depth in depths:
        by_strat: dict[str, float] = {}
        for c in cands:
            if c.fuse_steps != depth or c.strategy == "hwc":
                continue
            prev = by_strat.get(c.strategy)
            if prev is None or c.score < prev:
                by_strat[c.strategy] = c.score
        tc = by_strat.get("tc")
        vpu = min(
            (v for k, v in by_strat.items() if k != "tc"), default=None
        )
        if tc is not None and vpu is not None and tc < vpu:
            return depth
    return 0


def run(
    full: bool = False,
    dims: tuple[int, ...] = (1, 2, 3),
    fuse_steps: int = 1,
    strategies: tuple[str, ...] = ("hwc", "swc"),
) -> None:
    shapes = {
        1: (1 << (22 if full else 14 if smoke() else 18),),
        2: ((2048, 2048) if full else (64, 64) if smoke() else (256, 256)),
        3: ((256,) * 3 if full else (16,) * 3 if smoke() else (32, 32, 64)),
    }
    suffix = f"_f{fuse_steps}" if fuse_steps != 1 else ""
    for ndim, shape in shapes.items():
        if ndim not in dims:
            continue
        for acc in ((2, 4, 6, 8) if full else (2, 6)):
            p = DiffusionProblem(shape, accuracy=acc)
            f0 = p.init_field()
            n = int(np.prod(shape))
            roof = 2 * n * 4 / TPU_V5E.hbm_bw
            for strat in strategies:
                if strat == "swc_stream" and ndim < 2:
                    continue  # streaming needs a cross-stream axis
                tuned = ""
                steps_run = fuse_steps
                if strat == "auto":
                    # Cross-strategy resolution: --fuse-steps 1 opens
                    # the full joint (strategy, block, depth, stream)
                    # search; an explicit depth pins the depth axis.
                    fs = "auto" if fuse_steps == 1 else fuse_steps
                    op = p.step_op("auto", fuse_steps=fs)
                    rop = op.resolved(f0)  # eager: tune-and-persist
                    rec = lookup_fused_nd(
                        f0, op.ops, 1, "auto", fuse_steps=fs
                    )
                    if rec is not None:
                        chosen = rec.resolved_strategy
                        tuned = (
                            f";auto_strategy={chosen}"
                            f";auto_depth={rec.fuse_steps}"
                            f";tuned_block={format_block(rec.block)}"
                            f";tuned_src={rec.source}"
                        )
                    op = rop
                    steps_run = int(rop.fuse_steps)
                elif strat in ("swc", "swc_stream", "tc"):
                    op = p.step_op(strat, block="auto", fuse_steps=fuse_steps)
                    op(f0)  # eager: tune-and-persist on a cache miss
                    rec = lookup_fused_nd(
                        f0, op.ops, 1, strat, fuse_steps=fuse_steps
                    )
                    if rec is not None:
                        tuned = (f";tuned_block={format_block(rec.block)}"
                                 f";tuned_src={rec.source}")
                        if strat == "tc":
                            flops = stencil_mxu_flops_per_step(
                                shape, rec.block, (p.radius,) * ndim, 1,
                                fuse_steps,
                                groups_per_axis=tc_groups_per_axis(op.ops),
                            )
                            tuned += (
                                f";tpu_mxu_bound_s="
                                f"{stencil_mxu_roof_s(flops):.2e}"
                                f";mxu_crossover_depth="
                                f"{_mxu_crossover_depth(shape, p.radius)}"
                            )
                        if fuse_steps != 1 and strat != "tc":
                            ratio = stencil_traffic_reduction(
                                shape, (p.radius,) * ndim, 1, 1, 4,
                                block_base=rec.block,
                                block_fused=rec.block,
                                fuse_steps=fuse_steps,
                                stream=strat == "swc_stream",
                            )
                            tuned += f";traffic_model_x={ratio:.2f}"
                else:
                    op = p.step_op(strat, fuse_steps=fuse_steps)
                jitted = jax.jit(op)
                t = time_fn(jitted, f0, iters=3) / steps_run
                emit(
                    f"fig11/diffusion_fused/{ndim}d_r{p.radius}"
                    f"_{strat}{suffix}", t,
                    f"Mupdates_per_s={n / t / 1e6:.1f};"
                    f"tpu_bw_bound_s={roof:.2e}" + tuned,
                )
