"""Paper Figs. 11-12: diffusion equation with the fused stencil engine,
1/2/3-D, radius (accuracy) sweep, HWC vs SWC strategies — the SWC path
now runs at every rank through the StencilPlan lowering layer. The SWC
block comes from the tuning subsystem (``block="auto"``): the eager warm
call measures-and-records on a cache miss, the jitted timing loop
replays the persisted winner."""
from __future__ import annotations

import jax
import numpy as np

from benchmarks.util import emit, smoke, time_fn
from repro.core.rooflinelib import TPU_V5E
from repro.physics.diffusion import DiffusionProblem
from repro.tuning import format_block, lookup_fused_nd


def run(full: bool = False, dims: tuple[int, ...] = (1, 2, 3)) -> None:
    shapes = {
        1: (1 << (22 if full else 14 if smoke() else 18),),
        2: ((2048, 2048) if full else (64, 64) if smoke() else (256, 256)),
        3: ((256,) * 3 if full else (16,) * 3 if smoke() else (32, 32, 64)),
    }
    for ndim, shape in shapes.items():
        if ndim not in dims:
            continue
        for acc in ((2, 4, 6, 8) if full else (2, 6)):
            p = DiffusionProblem(shape, accuracy=acc)
            f0 = p.init_field()
            n = int(np.prod(shape))
            roof = 2 * n * 4 / TPU_V5E.hbm_bw
            for strat in ("hwc", "swc"):
                tuned = ""
                if strat == "swc":
                    op = p.step_op(strat, block="auto")
                    op(f0)  # eager: tune-and-persist on a cache miss
                    rec = lookup_fused_nd(f0, op.ops, 1, "swc")
                    if rec is not None:
                        tuned = (f";tuned_block={format_block(rec.block)}"
                                 f";tuned_src={rec.source}")
                else:
                    op = p.step_op(strat)
                jitted = jax.jit(op)
                t = time_fn(jitted, f0, iters=3)
                emit(
                    f"fig11/diffusion_fused/{ndim}d_r{p.radius}_{strat}", t,
                    f"Mupdates_per_s={n / t / 1e6:.1f};"
                    f"tpu_bw_bound_s={roof:.2e}" + tuned,
                )
