"""MMS convergence benchmark — the generalized-operator acceptance gate
as a BENCH artifact.

Sweeps the method-of-manufactured-solutions harness
(``repro.verify.mms``) over accuracy orders × ranks × boundary
families, fits the observed error slope for each, and writes the
results to ``BENCH_convergence.json`` so CI can assert the fitted
orders (and the perf-trajectory archive records them next to the
timing artifacts).

Unlike the fig* timing benchmarks this one measures CORRECTNESS
trajectories: a row's ``slope`` is the observed convergence order of
the full pad → plan → emit pipeline at that configuration, and the
``nominal`` column is what the weight generator claims. ``--smoke``
shrinks the matrix for CI (orders {2, 8}, ranks {1, 2}, plus the
neumann/neumann2 ghost-fill gap pair); the full run adds order 6,
rank 3, and a cross-strategy sweep at order 6 proving the slope is
strategy-invariant.

Usage::

    python -m benchmarks.convergence [--smoke] [--json PATH]
"""
from __future__ import annotations

import argparse
import json

import jax

jax.config.update("jax_enable_x64", True)

from repro.verify.mms import run_convergence  # noqa: E402


def _row(result) -> dict:
    d = result.as_dict()
    print(
        f"convergence rank={d['rank']} acc={d['accuracy']} "
        f"{d['boundary']:10s} {d['dtype']:8s} {d['strategy']:4s} "
        f"slope={d['slope']:6.2f} (nominal {d['nominal']})"
    )
    return d


def main(argv=None) -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true", help="CI matrix")
    ap.add_argument("--json", default="BENCH_convergence.json")
    args = ap.parse_args(argv)

    orders = (2, 8) if args.smoke else (2, 4, 6, 8)
    ranks = (1, 2) if args.smoke else (1, 2, 3)
    rows = []
    for rank in ranks:
        for acc in orders:
            for bc in ("periodic", "dirichlet"):
                rows.append(_row(run_convergence(rank, acc, bc)))
    # The ghost-fill order gap (satellite regression): edge-replicate
    # "neumann" caps the slope near 0.5, the mirror-about-node
    # "neumann2" fill releases the interior order.
    for mode in ("neumann", "neumann2"):
        rows.append(_row(run_convergence(1, 6, mode)))
    if not args.smoke:
        # Strategy invariance: the slope is a property of the weights,
        # not the lowering — every caching regime must reproduce it.
        for strategy in ("hwc", "swc", "swc_stream", "tc"):
            rows.append(
                _row(
                    run_convergence(
                        2, 6, "periodic", strategy=strategy,
                        # tc is f32-only; coarse grids keep its
                        # truncation error above the f32 floor.
                        dtype="float32" if strategy == "tc" else "float64",
                        ns=(8, 12, 16) if strategy == "tc" else None,
                    )
                )
            )
    with open(args.json, "w") as fh:
        json.dump({"rows": rows, "smoke": bool(args.smoke)}, fh, indent=1)
    print(f"wrote {args.json} ({len(rows)} rows)")


if __name__ == "__main__":
    main()
