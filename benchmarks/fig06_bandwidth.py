"""Paper Fig. 6: effective memory bandwidth vs problem size (r = 0 copy).

Finds the minimum problem size that saturates effective bandwidth — the
paper's protocol for choosing its 64/128 MiB benchmark sizes. On this CPU
container the measured GB/s is host bandwidth; the derived column also
reports the TPU-roofline time for the same transfer (2·bytes / 819 GB/s)
so the table is portable.
"""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from benchmarks.util import emit, smoke, time_fn
from repro.core.rooflinelib import TPU_V5E
from repro.kernels import ops


def run(full: bool = False) -> None:
    sizes_mib = (1, 4, 16, 64) if not full else (1, 2, 4, 8, 16, 32, 64, 128)
    if smoke():
        sizes_mib = (1, 4)
    g = jnp.ones((1,), jnp.float32)  # r = 0: f'_i = f_i
    for mib in sizes_mib:
        n = mib * 1024 * 1024 // 4
        f = jnp.asarray(np.random.default_rng(0).standard_normal(n), jnp.float32)
        t = time_fn(
            lambda f=f: ops.xcorr1d(f, g, strategy="hwc"), warmup=2, iters=5
        )
        nbytes = 2 * n * 4  # read + write once
        gbps = nbytes / t / 1e9
        tpu_t = nbytes / TPU_V5E.hbm_bw
        emit(
            f"fig06/bandwidth/{mib}MiB",
            t,
            f"measured_GBps={gbps:.1f};tpu_roofline_s={tpu_t:.2e}",
        )
