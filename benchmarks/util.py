"""Benchmark utilities: timing protocol (paper Sec. 5.1 — warm-up, then
median of timed iterations, explicit synchronization), CSV output, and
machine-readable row collection for ``run.py --json``."""
from __future__ import annotations

import time

import jax
import numpy as np

# Rows emitted so far: {"name", "us_per_call", "derived"} dicts, consumed
# by run.py --json for the CI perf-trajectory artifacts.
ROWS: list[dict] = []

_SMOKE = False


def set_smoke(on: bool = True) -> None:
    """Smoke mode (CI): single timed iteration, minimal warm-up, and
    modules may shrink problem sizes — correctness-of-plumbing runs, not
    trustworthy timings."""
    global _SMOKE
    _SMOKE = on


def smoke() -> bool:
    return _SMOKE


def time_fn(fn, *args, warmup: int = 2, iters: int = 5) -> float:
    """Median seconds per call, block_until_ready-synchronized."""
    if _SMOKE:
        warmup, iters = min(warmup, 1), 1
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    ts = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        ts.append(time.perf_counter() - t0)
    return float(np.median(ts))


def emit(name: str, seconds: float, derived: str = "") -> None:
    """``name,us_per_call,derived`` CSV row (assignment contract)."""
    us = seconds * 1e6
    ROWS.append({"name": name, "us_per_call": us, "derived": derived})
    print(f"{name},{us:.1f},{derived}")


def header() -> None:
    print("name,us_per_call,derived")
