"""Benchmark utilities: timing protocol (paper Sec. 5.1 — warm-up, then
median of timed iterations, explicit synchronization) and CSV output."""
from __future__ import annotations

import time

import jax
import numpy as np


def time_fn(fn, *args, warmup: int = 2, iters: int = 5) -> float:
    """Median seconds per call, block_until_ready-synchronized."""
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    ts = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        ts.append(time.perf_counter() - t0)
    return float(np.median(ts))


def emit(name: str, seconds: float, derived: str = "") -> None:
    """``name,us_per_call,derived`` CSV row (assignment contract)."""
    print(f"{name},{seconds * 1e6:.1f},{derived}")


def header() -> None:
    print("name,us_per_call,derived")
