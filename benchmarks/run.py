"""Benchmark driver — one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows.

Usage:
    PYTHONPATH=src python -m benchmarks.run            # quick (CPU-sized)
    PYTHONPATH=src python -m benchmarks.run --full     # paper-sized
    PYTHONPATH=src python -m benchmarks.run --only fig13
"""
from __future__ import annotations

import argparse
import importlib

from benchmarks.util import header

MODULES = (
    "fig06_bandwidth",
    "fig07_xcorr_library",
    "fig08_xcorr_tuned",
    "fig09_unroll",
    "fig10_diffusion_xla",
    "fig11_diffusion_fused",
    "fig13_mhd",
    "fig14_blocktune",
    "table3_energy",
)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true",
                    help="paper-sized problems (hours on CPU)")
    ap.add_argument("--only", default=None,
                    help="substring filter on module names")
    args = ap.parse_args()
    header()
    for name in MODULES:
        if args.only and args.only not in name:
            continue
        mod = importlib.import_module(f"benchmarks.{name}")
        mod.run(full=args.full)


if __name__ == "__main__":
    main()
