"""Benchmark driver — one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows; ``--json PATH`` also
archives the rows (plus device + git sha) for the CI perf trajectory.

Usage:
    PYTHONPATH=src python -m benchmarks.run            # quick (CPU-sized)
    PYTHONPATH=src python -m benchmarks.run --full     # paper-sized
    PYTHONPATH=src python -m benchmarks.run --only fig13
    PYTHONPATH=src python -m benchmarks.run --only fig06 --smoke \
        --json BENCH_fig06.json                        # CI artifact
"""
from __future__ import annotations

import argparse
import importlib
import inspect
import json
import subprocess

from benchmarks import util
from benchmarks.util import header

MODULES = (
    "fig06_bandwidth",
    "fig07_xcorr_library",
    "fig08_xcorr_tuned",
    "fig09_unroll",
    "fig10_diffusion_xla",
    "fig11_diffusion_fused",
    "fig13_mhd",
    "fig14_blocktune",
    "table3_energy",
)

JSON_SCHEMA = 1


def _git_sha() -> str:
    try:
        return subprocess.run(
            ["git", "rev-parse", "HEAD"], capture_output=True, text=True,
            check=True, timeout=10,
        ).stdout.strip()
    except Exception:
        return "unknown"


def _device() -> str:
    from repro.tuning.cache import current_backend

    return current_backend()


def write_json(path: str) -> None:
    """Archive the emitted rows. Row schema: name, us_per_call, derived,
    device, git_sha (the CI workflow uploads these as BENCH_*.json)."""
    device, sha = _device(), _git_sha()
    rows = [
        {**row, "device": device, "git_sha": sha} for row in util.ROWS
    ]
    payload = {
        "schema": JSON_SCHEMA,
        "device": device,
        "git_sha": sha,
        "smoke": util.smoke(),
        "rows": rows,
    }
    with open(path, "w") as fh:
        json.dump(payload, fh, indent=1, sort_keys=True)
        fh.write("\n")
    print(f"wrote {len(rows)} row(s) to {path}")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true",
                    help="paper-sized problems (hours on CPU)")
    ap.add_argument("--only", default=None,
                    help="substring filter on module names")
    ap.add_argument("--smoke", action="store_true",
                    help="single-iteration shrunk-size run (CI plumbing "
                         "check; timings not trustworthy)")
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="also write rows as JSON (device + git sha "
                         "stamped) for artifact archiving")
    ap.add_argument("--dims", default=None, metavar="D[,D...]",
                    help="restrict dimensionality-sweep modules (fig10/"
                         "fig11) to these ranks, e.g. --dims 1,2 or "
                         "--dims 3 (default: all of 1,2,3)")
    args = ap.parse_args()
    if args.smoke:
        util.set_smoke(True)
    dims = None
    if args.dims is not None:
        try:
            dims = tuple(sorted({int(d) for d in args.dims.split(",")}))
        except ValueError:
            dims = ()
        if not dims or any(d not in (1, 2, 3) for d in dims):
            ap.error("--dims entries must be in {1, 2, 3}")
    header()
    for name in MODULES:
        if args.only and args.only not in name:
            continue
        mod = importlib.import_module(f"benchmarks.{name}")
        kwargs = {}
        if (dims is not None
                and "dims" in inspect.signature(mod.run).parameters):
            kwargs["dims"] = dims  # others run normally (no rank sweep)
        mod.run(full=args.full, **kwargs)
    if args.json:
        write_json(args.json)


if __name__ == "__main__":
    main()
