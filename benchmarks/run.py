"""Benchmark driver — one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows; ``--json PATH`` also
archives the rows (plus device + git sha) for the CI perf trajectory.

Usage:
    PYTHONPATH=src python -m benchmarks.run            # quick (CPU-sized)
    PYTHONPATH=src python -m benchmarks.run --full     # paper-sized
    PYTHONPATH=src python -m benchmarks.run --only fig13
    PYTHONPATH=src python -m benchmarks.run --only fig06 --smoke \
        --json BENCH_fig06.json                        # CI artifact
"""
from __future__ import annotations

import argparse
import importlib
import inspect
import json
import subprocess

from benchmarks import util
from benchmarks.util import header

MODULES = (
    "fig06_bandwidth",
    "fig07_xcorr_library",
    "fig08_xcorr_tuned",
    "fig09_unroll",
    "fig10_diffusion_xla",
    "fig11_diffusion_fused",
    "fig13_mhd",
    "fig14_blocktune",
    "table3_energy",
)

JSON_SCHEMA = 1


def _git_sha() -> str:
    try:
        return subprocess.run(
            ["git", "rev-parse", "HEAD"], capture_output=True, text=True,
            check=True, timeout=10,
        ).stdout.strip()
    except (OSError, subprocess.SubprocessError):
        return "unknown"


def _device() -> str:
    from repro.tuning.cache import current_backend

    return current_backend()


def write_json(path: str) -> None:
    """Archive the emitted rows. Row schema: name, us_per_call, derived,
    device, git_sha (the CI workflow uploads these as BENCH_*.json)."""
    device, sha = _device(), _git_sha()
    rows = [
        {**row, "device": device, "git_sha": sha} for row in util.ROWS
    ]
    payload = {
        "schema": JSON_SCHEMA,
        "device": device,
        "git_sha": sha,
        "smoke": util.smoke(),
        "rows": rows,
    }
    with open(path, "w") as fh:
        json.dump(payload, fh, indent=1, sort_keys=True)
        fh.write("\n")
    print(f"wrote {len(rows)} row(s) to {path}")


def _parse_derived(derived: str) -> dict[str, str]:
    return dict(
        kv.split("=", 1) for kv in derived.split(";") if "=" in kv
    )


def summarize_rows(rows) -> dict:
    """Consolidate emitted rows into per-kernel GB/s + achieved-vs-
    roofline fraction. A row qualifies when its derived column carries a
    roofline bound (``tpu_bw_bound_s``/``tpu_roofline_s``): the fraction
    is bound/measured, and the achieved bandwidth is that fraction of
    the HBM roofline (``measured_GBps`` is used directly when a module
    already reports it)."""
    from repro.core.rooflinelib import TPU_V5E

    kernels = {}
    for row in rows:
        derived = _parse_derived(row.get("derived", ""))
        bound = derived.get("tpu_bw_bound_s") or derived.get(
            "tpu_roofline_s"
        )
        if bound is None:
            continue
        seconds = row["us_per_call"] / 1e6
        if seconds <= 0:
            continue
        fraction = float(bound) / seconds
        if "measured_GBps" in derived:
            gbps = float(derived["measured_GBps"])
        else:
            gbps = fraction * TPU_V5E.hbm_bw / 1e9
        entry = {
            "us_per_call": row["us_per_call"],
            "gbps": round(gbps, 3),
            "roofline_fraction": round(fraction, 6),
        }
        # tc rows additionally carry the MXU compute roof: report how
        # close the measured time sits to it, the compute-side analogue
        # of roofline_fraction (a tc kernel is compute-bound when its
        # mxu fraction exceeds its bandwidth fraction).
        if "tpu_mxu_bound_s" in derived:
            entry["mxu_roofline_fraction"] = round(
                float(derived["tpu_mxu_bound_s"]) / seconds, 6
            )
        # Cross-strategy "auto" rows report which caching regime the
        # tuning search picked for this shape — forward the decision so
        # the consolidated summary records it per kernel.
        for k in (
            "auto_strategy", "auto_depth", "tuned_block",
            "mxu_crossover_depth",
        ):
            if k in derived:
                entry[k] = derived[k]
        kernels[row["name"]] = entry
    return kernels


def write_summary(path: str = "BENCH_summary.json") -> None:
    """The consolidated perf-trajectory seed: ONE file at the repo root
    with every roofline-comparable kernel. Kernels from an existing
    summary of the same sha are merged in, so the CI job's sequential
    driver invocations (fig06, fig10 …, fig11 --fuse-steps 2)
    consolidate instead of overwriting each other."""
    sha = _git_sha()
    kernels = summarize_rows(util.ROWS)
    try:
        with open(path) as fh:
            prior = json.load(fh)
        if prior.get("git_sha") == sha and isinstance(
            prior.get("kernels"), dict
        ):
            kernels = {**prior["kernels"], **kernels}
    except (OSError, ValueError):
        pass
    payload = {
        "schema": JSON_SCHEMA,
        "device": _device(),
        "git_sha": sha,
        "smoke": util.smoke(),
        "kernels": kernels,
    }
    with open(path, "w") as fh:
        json.dump(payload, fh, indent=1, sort_keys=True)
        fh.write("\n")
    print(f"wrote {len(kernels)} kernel summar(ies) to {path}")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true",
                    help="paper-sized problems (hours on CPU)")
    ap.add_argument("--only", default=None,
                    help="substring filter on module names")
    ap.add_argument("--smoke", action="store_true",
                    help="single-iteration shrunk-size run (CI plumbing "
                         "check; timings not trustworthy)")
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="also write rows as JSON (device + git sha "
                         "stamped) for artifact archiving")
    ap.add_argument("--dims", default=None, metavar="D[,D...]",
                    help="restrict dimensionality-sweep modules (fig10/"
                         "fig11) to these ranks, e.g. --dims 1,2 or "
                         "--dims 3 (default: all of 1,2,3)")
    ap.add_argument("--fuse-steps", type=int, default=1, metavar="S",
                    help="temporal-fusion depth for modules that sweep "
                         "it (fig11): S in-kernel time steps per launch "
                         "on halo-widened blocks, timings reported per "
                         "step (default 1)")
    ap.add_argument("--strategies", default=None, metavar="S[,S...]",
                    help="restrict/widen the caching-strategy sweep for "
                         "modules that take one (fig11), e.g. "
                         "--strategies swc_stream, --strategies tc "
                         "(MXU matmul lowering; rows gain "
                         "tpu_mxu_bound_s/mxu_crossover_depth), "
                         "--strategies auto (cross-strategy tuning "
                         "search; the chosen regime is reported per "
                         "shape), or --strategies hwc,swc,tc "
                         "(default: hwc,swc)")
    args = ap.parse_args()
    if args.fuse_steps < 1:
        ap.error("--fuse-steps must be >= 1")
    if args.smoke:
        util.set_smoke(True)
    dims = None
    if args.dims is not None:
        try:
            dims = tuple(sorted({int(d) for d in args.dims.split(",")}))
        except ValueError:
            dims = ()
        if not dims or any(d not in (1, 2, 3) for d in dims):
            ap.error("--dims entries must be in {1, 2, 3}")
    strategies = None
    if args.strategies is not None:
        strategies = tuple(
            s.strip() for s in args.strategies.split(",") if s.strip()
        )
        bad = [
            s for s in strategies
            if s not in ("hwc", "swc", "swc_stream", "tc", "auto")
        ]
        if not strategies or bad:
            ap.error(
                "--strategies entries must be in "
                "{hwc, swc, swc_stream, tc, auto}"
            )
    header()
    for name in MODULES:
        if args.only and args.only not in name:
            continue
        mod = importlib.import_module(f"benchmarks.{name}")
        params = inspect.signature(mod.run).parameters
        kwargs = {}
        if dims is not None and "dims" in params:
            kwargs["dims"] = dims  # others run normally (no rank sweep)
        if args.fuse_steps != 1 and "fuse_steps" in params:
            kwargs["fuse_steps"] = args.fuse_steps
        if strategies is not None and "strategies" in params:
            kwargs["strategies"] = strategies
        mod.run(full=args.full, **kwargs)
    if args.json:
        write_json(args.json)
        if args.smoke:
            # Seed the perf trajectory: consolidated per-kernel GB/s +
            # roofline fractions at the repo root, uploaded by CI.
            write_summary()


if __name__ == "__main__":
    main()
