"""Paper Fig. 7: 1-D cross-correlation via the vendor library.

The cuDNN/MIOpen analogue on our stack is XLA's native convolution
primitive (lax.conv_general_dilated) — the "let the library choose the
algorithm" path, against which the hand-tuned kernels of Fig. 8 are
compared.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.util import emit, time_fn


def conv_library(f, g):
    # NCW layout, single batch/channel — the paper's 1-D setup.
    return jax.lax.conv_general_dilated(
        f[None, None, :], g[None, None, :],
        window_strides=(1,), padding="VALID",
    )[0, 0]


def run(full: bool = False) -> None:
    n = (64 if full else 4) * 1024 * 1024 // 4
    rng = np.random.default_rng(0)
    radii = (1, 4, 16, 64, 256, 1024) if full else (1, 16, 256)
    jitted = jax.jit(conv_library)
    for r in radii:
        f = jnp.asarray(rng.standard_normal(n + 2 * r), jnp.float32)
        g = jnp.asarray(rng.standard_normal(2 * r + 1), jnp.float32)
        t = time_fn(jitted, f, g)
        updates_per_s = n / t
        emit(
            f"fig07/xcorr_library/r{r}", t,
            f"Mupdates_per_s={updates_per_s/1e6:.1f}",
        )
