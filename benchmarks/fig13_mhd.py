"""Paper Fig. 13: MHD integration substep, tuning-strategy comparison.

Strategies: HWC (XLA-managed), SWC (Pallas pipelined blocks), SWC-stream
(paper Fig. 5b explicit z-streaming), and the beyond-paper fused-RK-axpy
variant. Derived column: fraction of the paper's 'ideal performance'
(domain read+written exactly once at peak BW — Sec. 5.4) achieved on TPU
roofline terms.
"""
from __future__ import annotations

import jax
import numpy as np

from benchmarks.util import emit, time_fn
from repro.core.rooflinelib import TPU_V5E, stencil_ideal_bytes
from repro.physics.mhd import MHDSolver, N_FIELDS
from repro.tuning import format_block, lookup_fused_nd


def run(full: bool = False) -> None:
    n = 64 if full else 24
    shape = (n, n, n)
    # SWC-family strategies take their block from the tuning subsystem;
    # HWC ignores the block (XLA owns residency).
    cases = [
        ("hwc", dict(strategy="hwc", fuse_rk_axpy=False)),
        ("swc", dict(strategy="swc", block="auto", fuse_rk_axpy=False)),
        ("swc_stream",
         dict(strategy="swc_stream", block="auto", fuse_rk_axpy=False)),
        ("hwc_fused_axpy", dict(strategy="hwc", fuse_rk_axpy=True)),
    ]
    npoints = float(np.prod(shape))
    ideal = stencil_ideal_bytes(npoints, N_FIELDS, N_FIELDS, 4) / TPU_V5E.hbm_bw
    for label, kw in cases:
        solver = MHDSolver(shape, **kw)
        f0 = solver.init_fields()
        tuned = ""
        if kw.get("block") == "auto":
            solver.rhs(f0)  # eager: tune-and-persist on a cache miss
            rec = lookup_fused_nd(
                f0, solver.operator_set, N_FIELDS, kw["strategy"]
            )
            if rec is not None:
                tuned = (f";tuned_block={format_block(rec.block)}"
                         f";tuned_src={rec.source}")
        dt = 1e-6  # paper Table B2: benchmark dt ≈ machine epsilon
        substep = jax.jit(lambda f, s=solver: s.step(f, dt))
        t = time_fn(substep, f0, iters=3, warmup=1)
        t_sub = t / 3.0  # paper reports per RK substep
        emit(
            f"fig13/mhd_{label}/{n}cubed", t_sub,
            f"Mupdates_per_s={npoints / t_sub / 1e6:.2f};"
            f"ideal_tpu_s_per_substep={ideal:.2e}" + tuned,
        )
