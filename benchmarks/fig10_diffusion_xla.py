"""Paper Fig. 10: diffusion equation via tensor-library primitives (the
PyTorch-path analogue): XLA's conv_general_dilated in 1/2/3-D, radius
sweep — the "transfer the tuning burden to the library" strategy.

Rows carry the HBM roofline bound (``tpu_bw_bound_s``), so the library
baseline lands in the consolidated ``BENCH_summary.json`` next to the
fused-engine strategies — the measured analogue of the hwc
modeled-traffic floor the cross-strategy ``"auto"`` search competes
against."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.util import emit, time_fn
from repro.core.rooflinelib import TPU_V5E
from repro.core.stencil import central_difference_coeffs


def _conv_nd(f, g, ndim):
    dn = jax.lax.conv_dimension_numbers(
        f.shape, g.shape,
        ("NCDHW"[: ndim + 2], "OIDHW"[: ndim + 2], "NCDHW"[: ndim + 2]),
    )
    return jax.lax.conv_general_dilated(
        f, g, window_strides=(1,) * ndim, padding="VALID",
        dimension_numbers=dn,
    )


def run(full: bool = False, dims: tuple[int, ...] = (1, 2, 3)) -> None:
    shapes = {
        1: (1 << (22 if full else 18),),
        2: ((2048, 2048) if full else (256, 256)),
        3: ((256, 256, 256) if full else (48, 48, 48)),
    }
    rng = np.random.default_rng(0)
    for ndim, shape in shapes.items():
        if ndim not in dims:
            continue
        for acc in ((2, 4, 8) if full else (2, 6)):
            r = acc // 2
            c2 = central_difference_coeffs(2, acc)
            # separable laplacian as a dense nd kernel (library path)
            k = np.zeros((2 * r + 1,) * ndim)
            for ax in range(ndim):
                idx = [r] * ndim
                for j, cj in enumerate(c2):
                    idx[ax] = j
                    k[tuple(idx)] += cj
            idx = (r,) * ndim
            k[idx] += 1.0  # merged identity (paper Eq. 5)
            fp = jnp.asarray(
                rng.standard_normal([s + 2 * r for s in shape]), jnp.float32
            )[None, None]
            g = jnp.asarray(k, jnp.float32)[None, None]
            jitted = jax.jit(lambda f, g, nd=ndim: _conv_nd(f, g, nd))
            t = time_fn(jitted, fp, g, iters=3)
            n = int(np.prod(shape))
            roof = 2 * n * 4 / TPU_V5E.hbm_bw  # compulsory f32 r+w
            emit(
                f"fig10/diffusion_library/{ndim}d_r{r}", t,
                f"Mupdates_per_s={n / t / 1e6:.1f};"
                f"tpu_bw_bound_s={roof:.2e}",
            )
