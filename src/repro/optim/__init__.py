"""Optimizer substrate: AdamW, LR schedules, global-norm clipping."""
from repro.optim.adamw import (  # noqa: F401
    AdamWConfig,
    OptState,
    adamw_init,
    adamw_update,
    clip_by_global_norm,
    cosine_schedule,
)
