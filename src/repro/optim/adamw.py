"""AdamW with decoupled weight decay, global-norm clipping, and warmup +
cosine decay — the production default for every arch in the zoo.

Kept dependency-free (no optax in this container) and pytree-shaped so
optimizer states inherit parameter shardings under pjit: each moment
tensor has the SAME shape as its parameter, so `param_shardings` applies
verbatim — with FSDP enabled the Adam moments are sharded too (ZeRO).
"""
from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr_peak: float = 3e-4
    warmup_steps: int = 100
    total_steps: int = 10_000
    lr_min_ratio: float = 0.1
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0


class OptState(NamedTuple):
    step: jnp.ndarray  # ()
    mu: Any  # pytree like params
    nu: Any


def cosine_schedule(cfg: AdamWConfig, step: jnp.ndarray) -> jnp.ndarray:
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    progress = jnp.clip(
        (step - cfg.warmup_steps)
        / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1),
        0.0,
        1.0,
    )
    cos = 0.5 * (1.0 + jnp.cos(jnp.pi * progress))
    floor = cfg.lr_min_ratio
    return cfg.lr_peak * warm * (floor + (1.0 - floor) * cos)


def adamw_init(params: Any) -> OptState:
    zeros = jax.tree.map(lambda p: jnp.zeros_like(p, dtype=jnp.float32), params)
    return OptState(
        step=jnp.zeros((), jnp.int32),
        mu=zeros,
        nu=jax.tree.map(jnp.copy, zeros),
    )


def clip_by_global_norm(grads: Any, max_norm: float):
    sq = sum(
        jnp.sum(jnp.square(g.astype(jnp.float32)))
        for g in jax.tree_util.tree_leaves(grads)
    )
    norm = jnp.sqrt(sq)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-12))
    return jax.tree.map(lambda g: (g * scale).astype(g.dtype), grads), norm


_NO_DECAY = ("ln", "norm", "bias", "b_", "bq", "bk", "bv", "bo", "A_log",
             "dt_bias", "D", "a_param", "pos")


def _decays(path) -> bool:
    name = "/".join(
        str(getattr(p, "key", getattr(p, "name", p))) for p in path
    )
    leaf = name.rsplit("/", 1)[-1]
    return not any(k in leaf for k in _NO_DECAY)


def adamw_update(
    cfg: AdamWConfig, grads: Any, state: OptState, params: Any
) -> tuple[Any, OptState, dict[str, jnp.ndarray]]:
    """One AdamW step → (new_params, new_state, metrics)."""
    grads, gnorm = clip_by_global_norm(grads, cfg.clip_norm)
    step = state.step + 1
    lr = cosine_schedule(cfg, step)
    b1, b2 = cfg.b1, cfg.b2
    bc1 = 1.0 - b1 ** step.astype(jnp.float32)
    bc2 = 1.0 - b2 ** step.astype(jnp.float32)

    def upd(path, p, g, m, v):
        gf = g.astype(jnp.float32)
        m_new = b1 * m + (1.0 - b1) * gf
        v_new = b2 * v + (1.0 - b2) * jnp.square(gf)
        m_hat = m_new / bc1
        v_hat = v_new / bc2
        delta = m_hat / (jnp.sqrt(v_hat) + cfg.eps)
        if _decays(path):
            delta = delta + cfg.weight_decay * p.astype(jnp.float32)
        p_new = p.astype(jnp.float32) - lr * delta
        return p_new.astype(p.dtype), m_new, v_new

    p_flat, treedef = jax.tree_util.tree_flatten_with_path(params)
    g_flat = jax.tree_util.tree_leaves(grads)
    m_flat = jax.tree_util.tree_leaves(state.mu)
    v_flat = jax.tree_util.tree_leaves(state.nu)
    new_p, new_m, new_v = [], [], []
    for (path, p), g, m, v in zip(p_flat, g_flat, m_flat, v_flat):
        pn, mn, vn = upd(path, p, g, m, v)
        new_p.append(pn)
        new_m.append(mn)
        new_v.append(vn)
    unflat = jax.tree_util.tree_unflatten
    metrics = {"grad_norm": gnorm, "lr": lr}
    return (
        unflat(treedef, new_p),
        OptState(step, unflat(treedef, new_m), unflat(treedef, new_v)),
        metrics,
    )
