"""Verification harnesses — correctness layers that gate the engine.

``repro.verify.mms`` is the method-of-manufactured-solutions
convergence harness: it drives analytically-known fields through
:class:`~repro.core.fusion.FusedStencilOp` at every generated accuracy
order, rank and boundary family, and fits the observed error slope
against the nominal order — the acceptance gate for the generalized
(Fornberg-weight) operator pipeline.
"""
from repro.verify.mms import (  # noqa: F401
    MMSResult,
    fit_slope,
    manufactured_solution,
    run_convergence,
)
