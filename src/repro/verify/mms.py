"""Method-of-manufactured-solutions (MMS) convergence harness.

Pick a smooth field f with a known Laplacian, evaluate the generated
∇² operator through the SAME :class:`~repro.core.fusion.FusedStencilOp`
pipeline production code uses (pad → lower → φ(A·B), any caching
regime), and fit the slope of log(error) against log(h) over a grid
refinement sweep. A correct order-A weight pipeline shows slope ≈ A;
every systematic defect this PR's machinery could have — wrong Fornberg
weights, a mis-scaled spacing, ghost cells contaminating wall cells,
boundary-modified rows applied at the wrong offset — bends the slope
away from nominal, which is why the fitted order (not a point-wise
tolerance) is the acceptance gate.

Manufactured fields:

* ``periodic`` — f = ∏ₐ sin(xₐ + φₐ) on [0, 2π)ʳ (cell-indexed,
  x_j = j·2π/n), so ∇²f = −rank·f exactly and the wrap IS the
  continuation. Incommensurate phases keep every axis's error term
  alive.
* ``dirichlet`` — f = ∏ₐ sin(ωₐ xₐ + φₐ) on the vertex-centered unit
  cube (h = 1/(n−1)); generic wall values, exercised with
  ``boundary_weights=True`` so the wall cells run the offset
  (one-sided) weight rows at full interior order.
* ``neumann`` / ``neumann2`` — f = ∏ₐ cos(π xₐ) (zero normal gradient
  at every wall), exercised through the ghost FILL itself
  (``boundary_weights=False``): the edge-replicate ``neumann`` fill is
  1st-order and caps the observed slope near the wall, the
  mirror-about-node ``neumann2`` fill reproduces the even extension and
  releases the interior order — the documented one-order gap the
  regression suite asserts.
"""
from __future__ import annotations

import dataclasses
from typing import Sequence

import jax.numpy as jnp
import numpy as np

from repro.core.fusion import FusedStencilOp
from repro.core.stencil import OperatorSet, laplacian_stencil

# Incommensurate per-axis phases/frequencies: no axis's error term
# cancels by symmetry, no two axes alias.
_PHASES = (0.3, 1.1, 2.2)
_FREQS = (1.7, 2.3, 1.3)

# Default refinement sweeps per rank (coarse → fine). Chosen so f64
# errors stay far above roundoff yet rank-3 sweeps stay cheap on CPU.
# Order 8 converges so fast it hits the f64 floor (~1e-13 relative) by
# n ≈ 48 — its sweep stays coarse on purpose; n = 12 still holds the
# 10-point offset rows (deriv + accuracy samples).
DEFAULT_NS = {
    1: (32, 48, 64, 96),
    2: (24, 32, 48, 64),
    3: (16, 24, 32),
}
DEFAULT_NS_ORDER8 = {
    1: (12, 16, 20, 24),
    2: (12, 16, 20, 24),
    3: (12, 16, 20),
}


@dataclasses.dataclass(frozen=True)
class MMSResult:
    """One convergence sweep: the fitted slope and its evidence."""

    rank: int
    accuracy: int
    boundary: str
    dtype: str
    strategy: str
    boundary_weights: bool
    ns: tuple[int, ...]
    hs: tuple[float, ...]
    errors: tuple[float, ...]  # normalized RMS per grid
    slope: float  # fitted observed order
    nominal: int  # the order the pipeline claims

    def as_dict(self) -> dict:
        return dataclasses.asdict(self)


def fit_slope(hs: Sequence[float], errors: Sequence[float]) -> float:
    """Least-squares slope of log(error) vs log(h) — the observed
    convergence order. Grids whose error underflows to exact zero are
    dropped (an exactly-reproduced solution carries no slope
    information); returns ``inf`` when fewer than two informative
    grids remain."""
    pts = [
        (np.log(h), np.log(e))
        for h, e in zip(hs, errors)
        if e > 0.0
    ]
    if len(pts) < 2:
        return float("inf")
    x, y = zip(*pts)
    return float(np.polyfit(x, y, 1)[0])


def manufactured_solution(
    rank: int, boundary: str, n: int, dtype: str = "float64"
) -> tuple[jnp.ndarray, jnp.ndarray, float]:
    """The manufactured field and its exact Laplacian on an n-per-axis
    grid: ``(f, lap_exact, h)`` with f of shape (1, *spatial).

    See the module docstring for the per-boundary field families; all
    axes share one extent ``n`` and one spacing ``h``.
    """
    if boundary == "periodic":
        h = 2.0 * np.pi / n
        x = np.arange(n) * h
        axes = [np.sin(x + _PHASES[a]) for a in range(rank)]
        f = _outer(axes)
        lap = -float(rank) * f
    elif boundary == "dirichlet":
        h = 1.0 / (n - 1)
        x = np.linspace(0.0, 1.0, n)
        axes = [np.sin(_FREQS[a] * x + _PHASES[a]) for a in range(rank)]
        f = _outer(axes)
        lap = np.zeros_like(f)
        for a in range(rank):
            parts = list(axes)
            parts[a] = -(_FREQS[a] ** 2) * parts[a]
            lap += _outer(parts)
    elif boundary in ("neumann", "neumann2"):
        h = 1.0 / (n - 1)
        x = np.linspace(0.0, 1.0, n)
        axes = [np.cos(np.pi * x) for _ in range(rank)]
        f = _outer(axes)
        lap = -rank * np.pi**2 * f
    else:
        raise ValueError(
            f"no manufactured solution for boundary {boundary!r}"
        )
    f = jnp.asarray(f[None], dtype=dtype)
    lap = jnp.asarray(lap[None], dtype=dtype)
    return f, lap, float(h)


def _outer(axes_1d: Sequence[np.ndarray]) -> np.ndarray:
    out = axes_1d[0]
    for g in axes_1d[1:]:
        out = np.multiply.outer(out, g)
    return out


def run_convergence(
    rank: int,
    accuracy: int,
    boundary: str = "periodic",
    *,
    dtype: str = "float64",
    strategy: str = "hwc",
    ns: Sequence[int] | None = None,
    boundary_weights: bool | None = None,
) -> MMSResult:
    """One refinement sweep of the generated ∇² at the given order.

    ``boundary_weights`` defaults to True on ``dirichlet`` (the wall
    cells must run the offset rows to see the interior order at all)
    and False on the Neumann family (whose POINT is to measure the
    ghost fill) and periodic (no walls). ``strategy`` selects the
    caching regime the sweep lowers through — the harness goes through
    ``FusedStencilOp`` precisely so a regression anywhere in the
    pipeline (not just in the weights) bends the slope.
    """
    if ns is None:
        ns = (DEFAULT_NS_ORDER8 if accuracy >= 8 else DEFAULT_NS)[rank]
    if boundary_weights is None:
        boundary_weights = boundary == "dirichlet"
    hs, errors = [], []
    for n in ns:
        f, lap_exact, h = manufactured_solution(rank, boundary, n, dtype)
        ops = OperatorSet(
            (laplacian_stencil(rank, accuracy, spacing=h),)
        )
        op = FusedStencilOp(
            ops, lambda d: d["lap"], n_out=1,
            boundary_mode=boundary, strategy=strategy,
            boundary_weights=boundary_weights,
        )
        out = op(f)
        err = np.asarray(out - lap_exact, dtype=np.float64)
        ref = np.sqrt(np.mean(np.asarray(lap_exact, np.float64) ** 2))
        errors.append(float(np.sqrt(np.mean(err**2)) / ref))
        hs.append(h)
    return MMSResult(
        rank=rank, accuracy=accuracy, boundary=boundary, dtype=dtype,
        strategy=strategy, boundary_weights=bool(boundary_weights),
        ns=tuple(int(n) for n in ns), hs=tuple(hs),
        errors=tuple(errors),
        slope=fit_slope(hs, errors), nominal=int(accuracy),
    )
