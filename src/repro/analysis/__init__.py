"""Static plan auditor: machine-checkable proofs about every lowerable
:class:`~repro.kernels.plan.StencilPlan` — no kernels executed.

Three finding families (see docs/analysis.md for the full story):

* **bounds** (:mod:`repro.analysis.bounds`) — the plan's actual kernel
  body is shadow-executed over an interval abstract domain
  (:mod:`repro.analysis.shadow`): every load must stay inside the
  staged window, every store inside (and exactly covering) the output
  tile, scratch reads must be initialized, and the streaming kernel's
  carried halo planes must hold exactly the global planes each chunk's
  input window calls for.
* **vmem** (:mod:`repro.analysis.vmem`) — the working set the shadow
  run measures must match ``costmodel.vmem_working_set`` (the number
  that steers candidate enumeration and the VMEM budget filter).
* **key** (:mod:`repro.analysis.keys`) — ``strategy_sid`` is injective
  over the exhaustive axis product modulo the one documented accuracy
  alias, and ``plan_from_record`` is a left inverse of the persisted
  tuning decision.

``python -m repro.analysis`` audits the registered shape set plus the
full cross-strategy candidate space and writes ``BENCH_audit.json``;
``--mutants`` runs the seeded-defect harness
(:mod:`repro.analysis.mutants`) proving the auditor detects each
defect class.
"""
from repro.analysis.bounds import PlanAudit, audit_plan
from repro.analysis.driver import run_audit, run_mutants
from repro.analysis.findings import CLASSES, AuditError, Finding
from repro.analysis.keys import (
    audit_key_uniqueness,
    audit_record_roundtrip,
    audit_sid_injectivity,
    parse_sid,
)
from repro.analysis.vmem import check_vmem, model_vmem

__all__ = [
    "AuditError",
    "CLASSES",
    "Finding",
    "PlanAudit",
    "audit_key_uniqueness",
    "audit_plan",
    "audit_record_roundtrip",
    "audit_sid_injectivity",
    "check_vmem",
    "model_vmem",
    "parse_sid",
    "run_audit",
    "run_mutants",
]
