"""VMEM-model fidelity: measured shadow working set vs the cost model.

The bounds audit measures the working set a plan's shadow run actually
staged — the shadow ref shapes (which are the emitter's own BlockSpec/
scratch shapes via ``lowering_windows``/``stream_extents``) plus the
carried intermediate extents OBSERVED at the synthetic-φ boundaries.
:func:`check_vmem` compares that against
``repro.tuning.costmodel.vmem_working_set``, which derives the same
quantity by independent arithmetic (and whose answers steer candidate
enumeration and the 12 MiB budget filter). Divergence means the tuner
is budgeting for a different kernel than the one being emitted —
historically how the unroll and aux terms went missing.

Tolerance: the two derivations are exact mirrors, so the default
relative tolerance is 0 (byte equality). ``tol`` exists for callers
that deliberately loosen the contract (e.g. exploratory model edits);
``python -m repro.analysis`` exposes it as ``--vmem-tol``.
"""
from __future__ import annotations

import numpy as np

from repro.analysis.findings import Finding
from repro.kernels.plan import StencilPlan
from repro.tuning import costmodel


def model_vmem(plan: StencilPlan) -> int:
    """The cost model's working-set prediction for ``plan``, called
    with the plan's base (un-flattened) counts plus its batch extent —
    exercising the model's own batch scaling path. The model is
    resolved through the module at call time so the mutation harness's
    seeded model defects are what actually runs."""
    return costmodel.vmem_working_set(
        plan.block,
        plan.radii,
        plan.n_f,
        plan.n_out,
        np.dtype(plan.dtype).itemsize,
        plan.fuse_steps,
        plan.strategy == "swc_stream",
        batch=plan.batch,
        unroll=plan.unroll,
        n_aux=plan.n_aux,
    )


def check_vmem(
    plan: StencilPlan, measured: int | None, *, tol: float = 0.0
) -> list[Finding]:
    """One finding (class ``vmem``) if ``measured`` and the model
    disagree beyond ``tol`` (relative); empty list otherwise."""
    if measured is None:
        return []  # bounds audit aborted; its findings already report
    model = model_vmem(plan)
    limit = tol * max(measured, model)
    if abs(measured - model) > limit:
        return [Finding(
            "vmem", plan.strategy_id,
            f"shadow run staged {measured} B, cost model predicts "
            f"{model} B (tol {tol:g})",
        )]
    return []
