"""CLI for the static plan auditor.

Usage::

    python -m repro.analysis [--smoke|--full] [--json PATH]
                             [--vmem-tol F] [--no-enumerate]
    python -m repro.analysis --mutants [--json PATH]

Exit status 0 iff the audit is finding-free (or, with ``--mutants``,
every seeded defect class was detected). The JSON report schema is
documented in docs/analysis.md.
"""
from __future__ import annotations

import argparse
import sys

from repro.analysis.driver import (
    run_audit,
    run_mutants,
    write_report,
)


def main(argv: list[str] | None = None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="Static plan auditor (bounds / VMEM / keys).",
    )
    mode = ap.add_mutually_exclusive_group()
    mode.add_argument(
        "--smoke", action="store_true",
        help="audit the smoke extents (default)",
    )
    mode.add_argument(
        "--full", action="store_true",
        help="audit the benchmark (full) extents",
    )
    mode.add_argument(
        "--mutants", action="store_true",
        help="run the seeded-defect mutation harness instead",
    )
    ap.add_argument(
        "--json", default="BENCH_audit.json", metavar="PATH",
        help="report path (default: %(default)s)",
    )
    ap.add_argument(
        "--vmem-tol", type=float, default=0.0, metavar="F",
        help="relative tolerance for the VMEM fidelity check "
        "(default: exact)",
    )
    ap.add_argument(
        "--no-enumerate", action="store_true",
        help="skip the cross-strategy candidate-space audit",
    )
    args = ap.parse_args(argv)

    if args.mutants:
        report = run_mutants()
        write_report(report, args.json)
        for name, r in report["mutants"].items():
            mark = "ok" if r["detected"] else "MISSED"
            print(
                f"  {mark:6s} {name}: {r['description']} -> "
                f"{r['classes'] or ['no findings']}"
            )
        if report["undetected"]:
            print(
                f"UNDETECTED mutants: {', '.join(report['undetected'])}"
            )
            return 1
        print(f"all {len(report['mutants'])} mutants detected")
        return 0

    report = run_audit(
        full=args.full,
        vmem_tol=args.vmem_tol,
        enumerate_candidates=not args.no_enumerate,
    )
    write_report(report, args.json)
    c = report["counts"]
    print(
        f"audited {c['registry_plans']} registry plans + "
        f"{c['candidate_plans']} enumerated candidates; "
        f"{c['sid_combos']} sid combos, "
        f"{c['record_roundtrips']} record round-trips"
    )
    for f in report["findings"]:
        print(f"  [{f['cls']}] {f['plan']}: {f['detail']}")
    if report["findings"]:
        print(f"{len(report['findings'])} findings -> {args.json}")
        return 1
    print(f"0 findings -> {args.json}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
