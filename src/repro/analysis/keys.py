"""Tuning-key injectivity and ``plan_from_record`` round-trip proofs.

Three theorems, each checked by exhaustive enumeration (the axis
product is small — a few thousand combinations):

1. **sid injectivity** — :func:`repro.kernels.plan.strategy_sid` is
   injective over the full valid axis product (strategy × rank ×
   unroll × fuse × batch × accuracy × n_aux) *modulo the one
   documented alias*: accuracy 0 ("unknown") and
   :data:`~repro.kernels.plan.DEFAULT_ACCURACY` both key unmarked.
   Two combos mapping to the same sid must be identical in every other
   axis. (Rank is a free axis here: it joins the TuningKey through
   ``kernel_name``, and the stream-axis letter already encodes it for
   streaming sids.)

2. **sid parsability** — the suffix grammar round-trips: a parser
   built from the documented grammar recovers every axis from the sid
   string. A suffix that failed to parse (or parsed to different
   values) would mean the grammar is ambiguous.

3. **record left-inverse** — for every audited plan,
   ``plan_from_record`` applied to a record carrying the plan's
   persisted decision (block, depth, stream flag, strategy, unroll)
   reconstructs the plan EXACTLY (dataclass equality). This is the
   warm-cache contract: a tuned decision replayed from disk must lower
   the same kernel that was measured.
"""
from __future__ import annotations

import itertools
import re
from typing import Any, Iterator

from repro.analysis.findings import Finding

# The audited functions (strategy_sid, plan_from_record) are resolved
# through the module at call time so the mutation harness's seeded
# key defects are what actually runs.
from repro.kernels import plan as plan_mod
from repro.kernels.plan import DEFAULT_ACCURACY, StencilPlan

# Enumerated axis values. These deliberately over-approximate what any
# single registry uses (batch 2 AND 4, accuracy up to 8, aux up to 2)
# so the proof covers values no current caller exercises yet.
_STRATEGIES = ("swc", "swc_stream", "tc", "auto")
_RANKS = (1, 2, 3)
_UNROLLS = (1, 2, 4)
_FUSES: tuple[Any, ...] = (1, 2, 3, "auto")
_BATCHES = (1, 2, 4)
_ACCURACIES = (0, 2, 4, 6, 8)
_AUXES = (0, 1, 2)

Combo = tuple[str, int, int, Any, int, int, int]
# (strategy, rank, unroll, fuse, batch, accuracy, n_aux)


def _valid(c: Combo) -> bool:
    """Mirror of the plan/search-layer constraints on the axis product
    (kept independent of ``StencilPlan.__post_init__`` on purpose: the
    auditor restates the rules it is checking against)."""
    strategy, rank, unroll, fuse, batch, _acc, n_aux = c
    if strategy == "swc_stream" and (rank == 1 or n_aux or unroll != 1):
        return False
    if strategy == "tc" and unroll != 1:
        return False
    if strategy == "auto" and unroll != 1:
        return False  # the cross-strategy search never keys unroll
    if unroll != 1 and fuse != 1:
        return False  # temporal fusion requires unroll=1
    if batch != 1 and n_aux and fuse not in (1,):
        return False  # batched temporal aux carries are rejected
    return True


def enumerate_combos() -> Iterator[Combo]:
    for c in itertools.product(
        _STRATEGIES, _RANKS, _UNROLLS, _FUSES, _BATCHES, _ACCURACIES,
        _AUXES,
    ):
        if _valid(c):
            yield c


_SID_RE = re.compile(
    r"^(?P<strategy>swc_stream|swc|tc|auto)"
    r"(?::s(?P<stream>auto|[zyx]))?"
    r"(?::u(?P<unroll>\d+))?"
    r"(?::f(?P<fuse>auto|\d+))?"
    r"(?::b(?P<batch>\d+))?"
    r"(?::a(?P<aux>\d+))?"
    r"(?::o(?P<acc>\d+))?$"
)


def parse_sid(sid: str) -> dict[str, Any] | None:
    """Parse a strategy id back into its axes per the documented
    grammar; ``None`` if the string does not match (a grammar break)."""
    m = _SID_RE.match(sid)
    if m is None:
        return None
    fuse = m["fuse"]
    return {
        "strategy": m["strategy"],
        "stream": m["stream"],
        "unroll": int(m["unroll"] or 1),
        "fuse": fuse if fuse == "auto" else int(fuse or 1),
        "batch": int(m["batch"] or 1),
        "n_aux": int(m["aux"] or 0),
        "accuracy": int(m["acc"]) if m["acc"] is not None else None,
    }


def _alias_ok(a: Combo, b: Combo) -> bool:
    """True iff two combos sharing a sid differ only through the
    documented accuracy alias ({0, DEFAULT_ACCURACY} key unmarked) —
    or only in rank for non-streaming strategies (rank joins the
    TuningKey via ``kernel_name``, not the sid)."""
    sa, ra, ua, fa, ba, aa, xa = a
    sb, rb, ub, fb, bb, ab, xb = b
    if (sa, ua, fa, ba, xa) != (sb, ub, fb, bb, xb):
        return False
    if sa == "swc_stream" and ra != rb:
        return False  # the stream letter must disambiguate ranks
    if aa != ab and {aa, ab} != {0, DEFAULT_ACCURACY}:
        return False
    return True


def audit_sid_injectivity() -> tuple[list[Finding], int]:
    """Prove theorems 1 and 2 over the full axis product. Returns
    (findings, number of combos checked)."""
    findings: list[Finding] = []
    by_sid: dict[str, list[Combo]] = {}
    n = 0
    for c in enumerate_combos():
        strategy, rank, unroll, fuse, batch, acc, n_aux = c
        sid = plan_mod.strategy_sid(
            strategy, rank, unroll, fuse, batch, acc, n_aux
        )
        n += 1
        by_sid.setdefault(sid, []).append(c)
        parsed = parse_sid(sid)
        if parsed is None:
            findings.append(Finding(
                "key", sid, f"sid does not match the suffix grammar "
                f"(combo {c})",
            ))
            continue
        expect_stream = (
            {2: "y", 3: "z"}[rank] if strategy == "swc_stream"
            else ("auto" if strategy == "auto" else None)
        )
        ok = (
            parsed["strategy"] == strategy
            and parsed["stream"] == expect_stream
            and parsed["unroll"] == unroll
            and parsed["fuse"] == fuse
            and parsed["batch"] == batch
            and parsed["n_aux"] == n_aux
            and (
                parsed["accuracy"] == acc
                if acc not in (0, DEFAULT_ACCURACY)
                else parsed["accuracy"] is None
            )
        )
        if not ok:
            findings.append(Finding(
                "key", sid,
                f"sid parse {parsed} does not round-trip combo {c}",
            ))
    for sid, combos in by_sid.items():
        for a, b in itertools.combinations(combos, 2):
            if not _alias_ok(a, b):
                findings.append(Finding(
                    "key", sid,
                    f"sid collision: combos {a} and {b} share the id "
                    "but differ beyond the documented accuracy alias",
                ))
    return findings, n


def _normalized_identity(plan: StencilPlan) -> tuple:
    """Everything a TuningKey must separate: all plan identity except
    the block (the tuned value) — accuracy collapsed through the
    documented alias."""
    acc = (
        DEFAULT_ACCURACY
        if plan.accuracy in (0, DEFAULT_ACCURACY)
        else plan.accuracy
    )
    return (
        plan.rank, plan.strategy, plan.radii, plan.interior, plan.n_f,
        plan.n_out, plan.dtype, plan.n_aux, plan.unroll,
        plan.fuse_steps, plan.batch, acc,
    )


def audit_key_uniqueness(
    plans: list[StencilPlan],
) -> list[Finding]:
    """No two distinct audited plans may share a TuningKey identity
    (block aside — the block IS the tuned value)."""
    findings: list[Finding] = []
    seen: dict[tuple, tuple] = {}
    for p in plans:
        k = (
            p.kernel_name, p.strategy_id, p.interior, p.radii, p.n_f,
            p.n_out, p.dtype,
        )
        ident = _normalized_identity(p)
        prev = seen.setdefault(k, ident)
        if prev != ident:
            findings.append(Finding(
                "key", p.strategy_id,
                f"TuningKey collision: identities {prev} and {ident} "
                "share one cache key",
            ))
    return findings


def audit_record_roundtrip(
    plan: StencilPlan, ops: Any
) -> list[Finding]:
    """Theorem 3 for one plan: synthesize the record the tuner would
    persist for this plan's decision and prove ``plan_from_record`` is
    a left inverse."""
    from repro.tuning.cache import TuningRecord

    rec = TuningRecord(
        block=plan.block,
        timings_us={},
        source="model",
        fuse_steps=plan.fuse_steps,
        stream=plan.strategy == "swc_stream",
        strategy_resolved=plan.strategy,
        unroll=plan.unroll,
    )
    lead = (plan.batch,) if plan.batch > 1 else ()
    shape = lead + (plan.n_f,) + plan.interior
    back = plan_mod.plan_from_record(
        ops, shape, plan.n_out, rec, dtype=plan.dtype,
        n_aux=plan.n_aux,
    )
    if back != plan:
        return [Finding(
            "key", plan.strategy_id,
            f"plan_from_record is not a left inverse: rebuilt "
            f"{back and back.strategy_id}/block="
            f"{back and back.block}/unroll={back and back.unroll} "
            f"from the persisted decision of block={plan.block}/"
            f"unroll={plan.unroll}",
        )]
    return []
