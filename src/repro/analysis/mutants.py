"""Mutation harness: prove the auditor catches each defect class.

A static auditor that has never seen a bug is indistinguishable from
one that cannot see bugs. Each entry in :data:`MUTANTS` monkeypatches
one deliberately broken variant of real kernel/key arithmetic into the
audited modules (halo slice one element wide, streaming prologue one
halo short, carry skewed by a plane, the pre-fix VMEM model that
ignored unroll/aux, a strategy id that drops the batch suffix, a
record rebuild that drops unroll, a temporal sweep with skewed margin,
an unroll loop that skips the last sub-tile), runs the relevant audit
on a small fixed plan set, and asserts at least one finding of the
expected class appears. Every patch is applied through the owning
module's attribute (the auditor resolves them at call time) and always
restored.

Run via ``python -m repro.analysis --mutants`` (CI job) or
:func:`run_harness` directly.
"""
from __future__ import annotations

import contextlib
import dataclasses
from typing import Any, Callable, Iterator

from repro.analysis.bounds import audit_plan
from repro.analysis.findings import Finding
from repro.analysis.keys import (
    audit_record_roundtrip,
    audit_sid_injectivity,
)
from repro.analysis.vmem import check_vmem
from repro.core.stencil import derivative_operator_set
from repro.kernels.plan import plan_stencil


# ---------------------------------------------------------------------------
# Broken variants (each mirrors the real code with ONE seeded defect)
# ---------------------------------------------------------------------------


def _block_derivs_wide(fblk, ops, radii, tile):
    """_block_derivs with the halo slice one element too wide — the
    classic off-by-one numpy would silently clamp."""
    import jax.numpy as jnp

    rank = len(tile)
    out = {}
    for spec in ops.ops:
        acc = None
        for off, c in zip(spec.offsets, spec.coeffs):
            sl = (slice(None),) + tuple(
                slice(
                    radii[a] + off[a],
                    radii[a] + off[a] + tile[a] + (1 if a == 0 else 0),
                )
                for a in range(rank)
            )
            term = jnp.asarray(c, dtype=fblk.dtype) * fblk[sl]
            acc = term if acc is None else acc + term
        out[spec.name] = acc
    return out


def _temporal_sweeps_skewed(cur, ops, radii, tile, phis, derivs_fn=None):
    """_temporal_sweeps evaluating every non-final sweep one margin
    too small — intermediate extents no longer match the schedule."""
    from repro.kernels import emit

    derivs_fn = derivs_fn or emit._block_derivs
    n_f = cur.shape[0]
    n_steps = len(phis)
    for s, phi in enumerate(phis):
        margin = n_steps - 1 - s
        bad = max(margin - 1, 0)  # seeded defect: margin skew
        sub_tile = tuple(t + 2 * r * bad for t, r in zip(tile, radii))
        derivs = derivs_fn(cur, ops, radii, sub_tile)
        val = phi(derivs)
        if margin:
            cur = val[:n_f]
    return val


def _kernel_pipelined_gap(
    f_ref, *rest, ops, radii, tile, phi, unroll, has_aux,
    derivs_fn=None,
):
    """_kernel_pipelined that never computes the LAST unroll sub-tile
    — stores stay in bounds but the output tile has a hole."""
    from repro.kernels import emit

    derivs_fn = derivs_fn or emit._block_derivs
    aux_ref, o_ref = rest if has_aux else (None, rest[0])
    fblk = f_ref[...]
    tx = tile[-1]
    rx = radii[-1]
    for e in range(max(unroll - 1, 1) if unroll > 1 else unroll):
        sub = fblk if unroll == 1 else fblk[..., e * tx : e * tx + tx + 2 * rx]
        derivs = derivs_fn(sub, ops, radii, tile)
        if has_aux:
            ablk = aux_ref[...]
            a_sub = ablk if unroll == 1 else ablk[..., e * tx : (e + 1) * tx]
            val = phi(derivs, a_sub)
        else:
            val = phi(derivs)
        if unroll == 1:
            o_ref[...] = val
        else:
            o_ref[..., e * tx : (e + 1) * tx] = val


def _make_kernel_stream_mutant(
    *, prologue_planes: int | None = None, carry_src_skew: int = 0
):
    """A copy of ``emit._kernel_stream`` with seeded streaming defects:
    ``prologue_planes`` overrides the 2·h₀ leading-halo copy width
    (short prologue → uninitialized planes), ``carry_src_skew`` offsets
    the carried-halo source (skew → plane provenance mismatch)."""

    def kernel(
        f_hbm, o_hbm, work, pf0, pf1, outbuf, sem_pf, sem_out, *,
        ops, radii, tile, phis, n_chunks,
    ):
        from repro.kernels import emit

        pl, pltpu, jax_mod = emit.pl, emit.pltpu, emit.jax
        rank = len(tile)
        halo = tuple(r * len(phis) for r in radii)
        ts, hs = tile[0], halo[0]
        cross_off = tuple(
            pl.program_id(i) * tile[1 + i] for i in range(rank - 1)
        )
        cross_halo = tuple(
            pl.ds(o, t + 2 * h)
            for o, t, h in zip(cross_off, tile[1:], halo[1:])
        )
        cross_tile = tuple(
            pl.ds(o, t) for o, t in zip(cross_off, tile[1:])
        )
        pro = 2 * hs if prologue_planes is None else prologue_planes

        def fresh_copy(chunk, pf_ref, slot):
            return pltpu.make_async_copy(
                f_hbm.at[
                    (slice(None), pl.ds(chunk * ts + 2 * hs, ts))
                    + cross_halo
                ],
                pf_ref,
                None,
            )

        halo_cp = pltpu.make_async_copy(
            f_hbm.at[(slice(None), pl.ds(0, pro)) + cross_halo],
            work.at[:, pl.ds(0, pro)],
            None,
        )
        halo_cp.start()
        fresh_copy(0, pf0, 0).start()
        halo_cp.wait()

        def body(chunk, _):
            slot = jax_mod.lax.rem(chunk, 2)

            @pl.when(chunk + 1 < n_chunks)
            def _():
                @pl.when(slot == 0)
                def _():
                    fresh_copy(chunk + 1, pf1, 1).start()

                @pl.when(slot == 1)
                def _():
                    fresh_copy(chunk + 1, pf0, 0).start()

            @pl.when(slot == 0)
            def _():
                fresh_copy(chunk, pf0, 0).wait()
                work[:, pl.ds(2 * hs, ts)] = pf0[...]

            @pl.when(slot == 1)
            def _():
                fresh_copy(chunk, pf1, 1).wait()
                work[:, pl.ds(2 * hs, ts)] = pf1[...]

            outbuf[...] = emit._temporal_sweeps(
                work[...], ops, radii, tile, phis
            )
            out_cp = pltpu.make_async_copy(
                outbuf,
                o_hbm.at[(slice(None), pl.ds(chunk * ts, ts)) + cross_tile],
                None,
            )
            out_cp.start()
            work[:, pl.ds(0, 2 * hs)] = work[
                :, pl.ds(ts + carry_src_skew, 2 * hs)
            ]
            out_cp.wait()
            return 0

        jax_mod.lax.fori_loop(0, n_chunks, body, 0)

    return kernel


def _vmem_working_set_legacy(
    block, radii, n_f, n_out, itemsize, fuse_steps=1, stream=False,
    *, batch=1, unroll=1, n_aux=0,
):
    """The pre-fix cost model: unroll and aux residency ignored."""
    n_f = n_f * batch
    n_out = n_out * batch
    if stream:
        work, pf, mid, out = n_f, n_f, n_f if fuse_steps > 1 else 0, n_out
        for a, (t, r) in enumerate(zip(block, radii)):
            work *= t + 2 * r * fuse_steps
            pf *= t if a == 0 else t + 2 * r * fuse_steps
            mid *= t + 2 * r * (fuse_steps - 1)
            out *= t
        return (work + 2 * pf + mid + out) * itemsize
    inp = n_f
    mid = n_f if fuse_steps > 1 else 0
    out = n_out
    for t, r in zip(block, radii):
        inp *= t + 2 * r * fuse_steps
        mid *= t + 2 * r * (fuse_steps - 1)
        out *= t
    return (2 * inp + mid + out) * itemsize


def _strategy_sid_no_batch(
    strategy, rank, unroll=1, fuse_steps=1, batch=1, accuracy=0,
    n_aux=0,
):
    """strategy_sid that drops the ensemble suffix — batched and
    single-member plans collide."""
    from repro.kernels import plan as plan_mod

    return plan_mod._REAL_STRATEGY_SID(
        strategy, rank, unroll, fuse_steps, 1, accuracy, n_aux
    )


# ---------------------------------------------------------------------------
# Patching + harness
# ---------------------------------------------------------------------------


@contextlib.contextmanager
def _patched(module: Any, attr: str, value: Any) -> Iterator[None]:
    saved = getattr(module, attr)
    setattr(module, attr, value)
    try:
        yield
    finally:
        setattr(module, attr, saved)


def _fixture_plans() -> dict[str, Any]:
    """Small fixed plans, one per audited regime."""
    ops2 = derivative_operator_set(2, accuracy=2)
    return {
        "ops": ops2,
        # pipelined, unrolled: interior (8, 256), block (8, 128), u2
        "unrolled": plan_stencil(
            ops2, (2, 10, 258), 2, strategy="swc", unroll=2
        ),
        # explicit streaming, depth 1: interior (64, 256), 4 chunks
        "stream": plan_stencil(
            ops2, (2, 66, 258), 2, strategy="swc_stream"
        ),
        # temporal fusion depth 2 (self-map: n_out == n_f)
        "temporal": plan_stencil(
            ops2, (2, 68, 260), 2, strategy="swc", fuse_steps=2
        ),
    }


def _audit_bounds(fix: dict, which: str) -> list[Finding]:
    return audit_plan(fix[which], fix["ops"]).findings


def _audit_vmem(fix: dict, which: str) -> list[Finding]:
    res = audit_plan(fix[which], fix["ops"])
    return res.findings + check_vmem(fix[which], res.measured_vmem)


def _audit_keys_sid(fix: dict) -> list[Finding]:
    return audit_sid_injectivity()[0]


def _audit_keys_roundtrip(fix: dict) -> list[Finding]:
    return audit_record_roundtrip(fix["unrolled"], fix["ops"])


@dataclasses.dataclass(frozen=True)
class Mutant:
    name: str
    description: str
    expected: frozenset[str]  # finding classes that count as detection
    apply: Callable[[], Any]  # -> context manager installing the defect
    audit: Callable[[dict], list[Finding]]


def _mutants() -> tuple[Mutant, ...]:
    from repro.kernels import emit
    from repro.kernels import plan as plan_mod
    from repro.tuning import costmodel

    def sid_patch():
        # Stash the real derivation where the mutant can reach it even
        # while plan_mod.strategy_sid points at the mutant.
        plan_mod._REAL_STRATEGY_SID = plan_mod.strategy_sid
        return _patched(
            plan_mod, "strategy_sid", _strategy_sid_no_batch
        )

    def record_patch():
        real = plan_mod.plan_from_record

        def dropping(ops, shape, n_out, record, **kw):
            rec = dataclasses.replace(record, unroll=1)
            return real(ops, shape, n_out, rec, **kw)

        return _patched(plan_mod, "plan_from_record", dropping)

    return (
        Mutant(
            "halo-slice-overrun",
            "tap slice one element past the staged window",
            frozenset({"bounds"}),
            lambda: _patched(emit, "_block_derivs", _block_derivs_wide),
            lambda fix: _audit_bounds(fix, "unrolled"),
        ),
        Mutant(
            "stream-prologue-short",
            "streaming prologue copies h0 planes instead of 2*h0",
            frozenset({"uninit"}),
            lambda: _patched(
                emit, "_kernel_stream",
                _make_kernel_stream_mutant(prologue_planes=1),
            ),
            lambda fix: _audit_bounds(fix, "stream"),
        ),
        Mutant(
            "stream-carry-skew",
            "carried halo planes sourced one plane early",
            frozenset({"bounds"}),
            lambda: _patched(
                emit, "_kernel_stream",
                _make_kernel_stream_mutant(carry_src_skew=-1),
            ),
            lambda fix: _audit_bounds(fix, "stream"),
        ),
        Mutant(
            "temporal-margin-skew",
            "non-final sweeps evaluated one margin too small",
            frozenset({"phi", "bounds"}),
            lambda: _patched(
                emit, "_temporal_sweeps", _temporal_sweeps_skewed
            ),
            lambda fix: _audit_bounds(fix, "temporal"),
        ),
        Mutant(
            "unroll-store-gap",
            "last unroll sub-tile never computed or stored",
            frozenset({"coverage"}),
            lambda: _patched(
                emit, "_kernel_pipelined", _kernel_pipelined_gap
            ),
            lambda fix: _audit_bounds(fix, "unrolled"),
        ),
        Mutant(
            "vmem-model-legacy",
            "cost model ignores unroll and aux residency",
            frozenset({"vmem"}),
            lambda: _patched(
                costmodel, "vmem_working_set", _vmem_working_set_legacy
            ),
            lambda fix: _audit_vmem(fix, "unrolled"),
        ),
        Mutant(
            "sid-drops-batch",
            "strategy id omits the :b{B} ensemble suffix",
            frozenset({"key"}),
            sid_patch,
            _audit_keys_sid,
        ),
        Mutant(
            "record-drops-unroll",
            "plan_from_record ignores the persisted unroll factor",
            frozenset({"key"}),
            record_patch,
            _audit_keys_roundtrip,
        ),
    )


def run_harness() -> dict[str, dict[str, Any]]:
    """Apply every mutant, re-run the relevant audit, report detection.

    Returns ``{name: {detected, expected, classes, description}}``;
    the clean fixture set is also audited first and must be
    finding-free (a noisy auditor detects everything vacuously).
    """
    fix = _fixture_plans()
    results: dict[str, dict[str, Any]] = {}
    clean: list[Finding] = []
    for which in ("unrolled", "stream", "temporal"):
        clean.extend(_audit_vmem(fix, which))
    clean.extend(_audit_keys_sid(fix))
    clean.extend(_audit_keys_roundtrip(fix))
    results["__clean__"] = {
        "detected": not clean,
        "expected": [],
        "classes": sorted({f.cls for f in clean}),
        "description": "fixture plans audit clean before any mutation",
    }
    for m in _mutants():
        with m.apply():
            found = m.audit(fix)
        classes = {f.cls for f in found}
        results[m.name] = {
            "detected": bool(classes & m.expected),
            "expected": sorted(m.expected),
            "classes": sorted(classes),
            "description": m.description,
        }
    return results
