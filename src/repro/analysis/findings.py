"""Finding records for the static plan auditor.

Every auditor check failure is a :class:`Finding` — a machine-checkable
record (never a print) with a defect class drawn from the closed
:data:`CLASSES` set, the strategy id / plan label it was proved
against, and a human-readable detail string. ``python -m
repro.analysis`` serializes the full list into ``BENCH_audit.json``
and exits nonzero if any survive; the mutation harness
(``repro.analysis.mutants``) asserts each class fires on its seeded
defect.
"""
from __future__ import annotations

import dataclasses
from typing import Any

# The closed set of defect classes the auditor can prove. "bounds"
# covers any access outside the staged window / valid store region
# (including stream-carry provenance skew: initialized planes that
# belong to the wrong global position are out-of-bounds in global
# coordinates); "uninit" a read of never-written scratch; "vmem" a
# divergence between the measured shadow working set and the cost
# model; "key" a strategy-id/tuning-key collision or a
# ``plan_from_record`` round-trip failure; "coverage" an output tile
# not exactly covered by the kernel's stores; "phi" a sweep geometry
# mismatch observed at a synthetic-φ call boundary.
CLASSES = ("bounds", "uninit", "vmem", "key", "coverage", "phi")


@dataclasses.dataclass(frozen=True)
class Finding:
    cls: str  # one of CLASSES
    plan: str  # strategy id / label of the audited plan (or sid pair)
    detail: str

    def __post_init__(self) -> None:
        if self.cls not in CLASSES:
            raise ValueError(f"unknown finding class {self.cls!r}")

    def to_json(self) -> dict[str, Any]:
        return {"cls": self.cls, "plan": self.plan, "detail": self.detail}


class AuditError(Exception):
    """Raised inside a shadow kernel run when a proof obligation fails;
    the audit driver converts it into a :class:`Finding` and moves on
    to the next plan."""

    def __init__(self, cls: str, detail: str):
        super().__init__(f"[{cls}] {detail}")
        self.cls = cls
        self.detail = detail
