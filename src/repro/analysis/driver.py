"""Audit driver: every lowerable plan of the registered shape set.

Two plan sources, both audited with the same obligations:

* the declarative audit-shape registry
  (:data:`repro.tuning.shapes.AUDIT_SHAPES` — mirrors the warm/bench
  registry plus auditor-only axes), expanded over its full strategy ×
  fuse × unroll × batch product;
* the cross-strategy tuner's own candidate space
  (:func:`repro.tuning.costmodel.enumerate_cross_strategy_nd` over
  each registry entry) — every non-hwc candidate the ``auto`` search
  could ever measure is lowered to its plan and audited, so a tuning
  winner can never be a plan the auditor has not proved.

Key obligations (sid injectivity, TuningKey uniqueness,
``plan_from_record`` round-trip) run over the union of both sets plus
the exhaustive sid axis product. The result is a JSON report
(``BENCH_audit.json``; schema in docs/analysis.md) and a process exit
code: nonzero iff any finding survived.
"""
from __future__ import annotations

import json
import subprocess
from typing import Any

import numpy as np

from repro.analysis.bounds import audit_plan
from repro.analysis.findings import Finding
from repro.analysis.keys import (
    audit_key_uniqueness,
    audit_record_roundtrip,
    audit_sid_injectivity,
)
from repro.analysis.vmem import check_vmem


def _git_sha() -> str:
    try:
        return subprocess.run(
            ["git", "rev-parse", "--short", "HEAD"],
            capture_output=True, text=True, timeout=10, check=True,
        ).stdout.strip()
    except (OSError, subprocess.SubprocessError):
        return "unknown"


def _device_kind() -> str:
    try:
        import jax

        return jax.devices()[0].device_kind
    except Exception:  # repolint: allow[broad-except] — stamp only
        return "unknown"


def _candidate_plans(entry: Any, domain: tuple[int, ...]):
    """Lower every non-hwc cross-strategy candidate for one registry
    entry to its StencilPlan. Yields (plan, ops); candidates the plan
    layer rejects are yielded as (ValueError, candidate) for the
    caller to report — the search must never rank a config that cannot
    lower."""
    from repro.kernels.plan import plan_stencil
    from repro.tuning.costmodel import enumerate_cross_strategy_nd

    ops = entry.operator_set()
    radii = ops.radius_per_axis()
    fuse_opts = (
        (1, 2)
        if entry.n_out == entry.n_f + entry.n_aux and not entry.n_aux
        else (1,)
    )
    cands = enumerate_cross_strategy_nd(
        domain, radii, entry.n_f, entry.n_out,
        np.dtype(entry.dtype).itemsize,
        fuse_steps_options=fuse_opts,
        stream_ok=not entry.n_aux,
        tc_ok=entry.dtype in ("float32", "bfloat16"),
        backend="audit",
    )
    for c in cands:
        if c.strategy == "hwc":
            continue
        padded = tuple(
            n + 2 * r * c.fuse_steps for n, r in zip(domain, radii)
        )
        try:
            plan = plan_stencil(
                ops, (entry.n_f,) + padded, entry.n_out,
                strategy=c.strategy, block=c.block, dtype=entry.dtype,
                n_aux=entry.n_aux, fuse_steps=c.fuse_steps,
            )
        except ValueError as e:
            yield e, c
            continue
        yield plan, ops


def run_audit(
    *,
    full: bool = False,
    vmem_tol: float = 0.0,
    enumerate_candidates: bool = True,
) -> dict[str, Any]:
    """Run the complete audit; returns the JSON-serializable report."""
    from repro.tuning.shapes import AUDIT_SHAPES

    findings: list[Finding] = []
    audited: list[tuple[Any, Any]] = []  # (plan, ops)
    n_registry = 0
    n_candidates = 0
    for entry in AUDIT_SHAPES:
        domain = entry.full if full else entry.smoke
        for plan, ops in entry.plans(domain):
            res = audit_plan(plan, ops)
            findings.extend(res.findings)
            findings.extend(
                check_vmem(plan, res.measured_vmem, tol=vmem_tol)
            )
            audited.append((plan, ops))
            n_registry += 1
        if enumerate_candidates:
            # Candidate space over the smoke extents regardless of
            # --full: the point is coverage of the search space, and
            # the space only shrinks as extents grow past the budget.
            for plan, ops in _candidate_plans(entry, entry.smoke):
                if isinstance(plan, ValueError):
                    findings.append(Finding(
                        "bounds", f"{entry.name}:{ops.strategy}",
                        f"enumerated candidate does not lower: {plan}",
                    ))
                    continue
                res = audit_plan(plan, ops)
                findings.extend(res.findings)
                findings.extend(
                    check_vmem(plan, res.measured_vmem, tol=vmem_tol)
                )
                audited.append((plan, ops))
                n_candidates += 1

    sid_findings, n_combos = audit_sid_injectivity()
    findings.extend(sid_findings)
    findings.extend(audit_key_uniqueness([p for p, _ in audited]))
    seen_sids: set[tuple] = set()
    n_roundtrips = 0
    for plan, ops in audited:
        k = (plan.strategy_id, plan.interior, plan.block, plan.dtype)
        if k in seen_sids:
            continue
        seen_sids.add(k)
        findings.extend(audit_record_roundtrip(plan, ops))
        n_roundtrips += 1

    return {
        "schema": 1,
        "mode": "full" if full else "smoke",
        "device": _device_kind(),
        "git_sha": _git_sha(),
        "vmem_tol": vmem_tol,
        "counts": {
            "registry_plans": n_registry,
            "candidate_plans": n_candidates,
            "sid_combos": n_combos,
            "record_roundtrips": n_roundtrips,
            "findings": len(findings),
        },
        "findings": [f.to_json() for f in findings],
    }


def run_mutants() -> dict[str, Any]:
    """Run the mutation harness; report schema mirrors
    :func:`run_audit` with a ``mutants`` section instead of findings."""
    from repro.analysis.mutants import run_harness

    results = run_harness()
    undetected = sorted(
        name for name, r in results.items() if not r["detected"]
    )
    return {
        "schema": 1,
        "mode": "mutants",
        "device": _device_kind(),
        "git_sha": _git_sha(),
        "mutants": results,
        "undetected": undetected,
    }


def write_report(report: dict[str, Any], path: str) -> None:
    with open(path, "w") as fh:
        json.dump(report, fh, indent=2, sort_keys=True)
        fh.write("\n")
