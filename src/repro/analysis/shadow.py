"""Interval abstract domain for shadow-executing Pallas kernel bodies.

The auditor never runs real compute: it calls the kernel bodies in
``repro.kernels.emit`` directly (no ``pl.pallas_call``) with
:class:`ShadowRef` operands whose reads and writes are interval boxes
— the min/max index touched along every axis. Slicing is STRICT:
where ``numpy``/``jnp`` silently clamp an out-of-range slice (the
exact defect class that turns a halo-arithmetic bug into wrong answers
instead of a crash), a shadow access raises
:class:`~repro.analysis.findings.AuditError` with the offending box.

Arithmetic on :class:`ShadowArray` relies on JAX deferring binary ops
to unrecognized operand types (``jnp_scalar * shadow`` dispatches to
``shadow.__rmul__``), so the emitter's tap loops run unchanged. The
one data-dependent MXU op (``emit._contract``) dispatches to
:meth:`ShadowArray.shadow_contract`.

The streaming kernel additionally needs the Pallas/JAX module surface
(``pl.program_id``/``pl.ds``/``pl.when``, ``pltpu.make_async_copy``,
``jax.lax.fori_loop``/``rem``): :func:`shadow_shims` monkeypatches
``emit``'s module globals with concrete shims for the duration of a
shadow run — ``fori_loop`` becomes a Python loop, DMA a synchronous
shadow copy (start() lands the data; wait() is a no-op — DMA/compute
overlap hazards are out of scope, see docs/analysis.md).
"""
from __future__ import annotations

import contextlib
from typing import Any, Callable, Iterator, Sequence

import numpy as np

from repro.analysis.findings import AuditError

Box = tuple[tuple[int, int], ...]  # per-axis (lo, hi) half-open


# ---------------------------------------------------------------------------
# Box algebra
# ---------------------------------------------------------------------------


def normalize_index(
    idx: Any, shape: tuple[int, ...], label: str
) -> tuple[Box, tuple[bool, ...]]:
    """Resolve an index expression into a strict interval box.

    Returns ``(box, keep)`` where ``keep[a]`` is False for axes an
    integer index collapses. Raises :class:`AuditError` (class
    ``bounds``) for ANY component outside ``[0, dim]`` — negative
    indices, clamped slices and empty slices are all treated as proof
    failures, not conveniences.
    """
    if not isinstance(idx, tuple):
        idx = (idx,)
    if any(e is Ellipsis for e in idx):
        pos = idx.index(Ellipsis)
        fill = len(shape) - (len(idx) - 1)
        idx = idx[:pos] + (slice(None),) * fill + idx[pos + 1 :]
    if len(idx) > len(shape):
        raise AuditError(
            "bounds", f"{label}: {len(idx)} indices for rank {len(shape)}"
        )
    idx = idx + (slice(None),) * (len(shape) - len(idx))
    box: list[tuple[int, int]] = []
    keep: list[bool] = []
    for a, (e, dim) in enumerate(zip(idx, shape)):
        if isinstance(e, slice):
            if e.step not in (None, 1):
                raise AuditError(
                    "bounds", f"{label}: strided slice on axis {a}"
                )
            lo = 0 if e.start is None else int(e.start)
            hi = dim if e.stop is None else int(e.stop)
            if lo < 0 or hi > dim or lo >= hi:
                raise AuditError(
                    "bounds",
                    f"{label}: axis {a} slice [{lo}, {hi}) outside "
                    f"[0, {dim}) or empty",
                )
            box.append((lo, hi))
            keep.append(True)
        else:
            i = int(e)
            if i < 0 or i >= dim:
                raise AuditError(
                    "bounds",
                    f"{label}: axis {a} index {i} outside [0, {dim})",
                )
            box.append((i, i + 1))
            keep.append(False)
    return tuple(box), tuple(keep)


def box_extents(box: Box) -> tuple[int, ...]:
    return tuple(hi - lo for lo, hi in box)


def subtract_box(target: Box, cut: Box) -> list[Box]:
    """``target`` minus ``cut`` as a disjoint box list (axis sweep)."""
    inter = tuple(
        (max(tl, cl), min(th, ch))
        for (tl, th), (cl, ch) in zip(target, cut)
    )
    if any(lo >= hi for lo, hi in inter):
        return [target]
    out: list[Box] = []
    cur = list(target)
    for a, ((tl, th), (il, ih)) in enumerate(zip(target, inter)):
        if tl < il:
            out.append(tuple(cur[:a]) + ((tl, il),) + tuple(cur[a + 1 :]))
        if ih < th:
            out.append(tuple(cur[:a]) + ((ih, th),) + tuple(cur[a + 1 :]))
        cur[a] = (il, ih)
    return out


def uncovered(target: Box, cover: Sequence[Box]) -> list[Box]:
    """Sub-boxes of ``target`` not covered by the union of ``cover``."""
    remain = [target]
    for c in cover:
        remain = [piece for r in remain for piece in subtract_box(r, c)]
        if not remain:
            return []
    return remain


# ---------------------------------------------------------------------------
# Shadow values
# ---------------------------------------------------------------------------


class ShadowArray:
    """An abstract array value: shape + dtype, no data.

    ``src`` carries read provenance — the ``(ref, box)`` a direct ref
    read produced this value from — consumed by the streaming audit's
    plane-provenance hooks; any arithmetic or slicing drops it (the
    value is then derived, not a copy).
    """

    __slots__ = ("shape", "dtype", "src")

    def __init__(
        self,
        shape: tuple[int, ...],
        dtype: Any = np.float32,
        src: tuple["ShadowRef", Box] | None = None,
    ):
        self.shape = tuple(int(s) for s in shape)
        self.dtype = np.dtype(dtype)
        self.src = src

    @property
    def ndim(self) -> int:
        return len(self.shape)

    def astype(self, dtype: Any) -> "ShadowArray":
        return ShadowArray(self.shape, dtype)

    def __getitem__(self, idx: Any) -> "ShadowArray":
        box, keep = normalize_index(idx, self.shape, "shadow slice")
        ext = box_extents(box)
        return ShadowArray(
            tuple(e for e, k in zip(ext, keep) if k), self.dtype
        )

    def _binop(self, other: Any) -> "ShadowArray":
        if isinstance(other, ShadowArray):
            if other.shape != self.shape:
                raise AuditError(
                    "bounds",
                    f"shape mismatch in arithmetic: {self.shape} vs "
                    f"{other.shape}",
                )
            return ShadowArray(self.shape, self.dtype)
        # scalar / 0-d jnp operand: broadcast, keep our shape
        if getattr(other, "ndim", 0) != 0 and not np.isscalar(other):
            raise AuditError(
                "bounds",
                f"unsupported broadcast of {getattr(other, 'shape', other)}"
                f" against shadow {self.shape}",
            )
        return ShadowArray(self.shape, self.dtype)

    __add__ = __radd__ = __sub__ = __rsub__ = _binop
    __mul__ = __rmul__ = __truediv__ = __rtruediv__ = _binop
    __pow__ = __rpow__ = _binop

    def __neg__(self) -> "ShadowArray":
        return ShadowArray(self.shape, self.dtype)

    def shadow_contract(self, band: Any, axis: int) -> "ShadowArray":
        """Shadow of ``emit._contract``: validate the window/band
        geometry of one banded MXU contraction and return the
        contracted shape (f32, as the real path accumulates)."""
        ext_in, ext_out = int(band.shape[0]), int(band.shape[1])
        if self.shape[1 + axis] != ext_in:
            raise AuditError(
                "bounds",
                f"tc contraction axis {axis}: window extent "
                f"{self.shape[1 + axis]} != band rows {ext_in}",
            )
        shape = list(self.shape)
        shape[1 + axis] = ext_out
        return ShadowArray(tuple(shape), np.float32)

    def __repr__(self) -> str:
        return f"ShadowArray(shape={self.shape}, dtype={self.dtype})"


class ShadowView:
    """``ref.at[idx]`` — a deferred slice used as a DMA endpoint."""

    def __init__(self, ref: "ShadowRef", idx: Any):
        self.ref = ref
        self.idx = idx

    def read(self) -> ShadowArray:
        return self.ref.read(self.idx)

    def write(self, value: Any) -> None:
        self.ref.write(self.idx, value)


class _AtIndexer:
    def __init__(self, ref: "ShadowRef"):
        self._ref = ref

    def __getitem__(self, idx: Any) -> ShadowView:
        return ShadowView(self._ref, idx)


class ShadowRef:
    """A shadow of one kernel operand/scratch Ref.

    Records every read and write box. Reads of a non-``initialized``
    ref must be fully covered by prior write boxes (uninitialized-read
    proof). ``read_hook(box)`` / ``write_hook(box, value)`` let the
    streaming audit layer plane-provenance tracking on top without the
    core knowing about chunks.
    """

    def __init__(
        self,
        name: str,
        shape: Sequence[int],
        dtype: Any = np.float32,
        *,
        initialized: bool = False,
    ):
        self.name = name
        self.shape = tuple(int(s) for s in shape)
        self.dtype = np.dtype(dtype)
        self.initialized = initialized
        self.reads: list[Box] = []
        self.writes: list[Box] = []
        self.read_hook: Callable[[Box], None] | None = None
        self.write_hook: Callable[[Box, Any], None] | None = None

    @property
    def at(self) -> _AtIndexer:
        return _AtIndexer(self)

    def read(self, idx: Any) -> ShadowArray:
        box, keep = normalize_index(idx, self.shape, f"read {self.name}")
        if not self.initialized:
            holes = uncovered(box, self.writes)
            if holes:
                raise AuditError(
                    "uninit",
                    f"read of {self.name}{box} touches never-written "
                    f"region {holes[0]}",
                )
        self.reads.append(box)
        if self.read_hook is not None:
            self.read_hook(box)
        ext = box_extents(box)
        return ShadowArray(
            tuple(e for e, k in zip(ext, keep) if k),
            self.dtype,
            src=(self, box),
        )

    def write(self, idx: Any, value: Any) -> None:
        box, keep = normalize_index(idx, self.shape, f"store {self.name}")
        ext = tuple(
            e for e, k in zip(box_extents(box), keep) if k
        )
        if isinstance(value, ShadowArray):
            if value.shape != ext:
                raise AuditError(
                    "bounds",
                    f"store {self.name}{box}: extents {ext} != value "
                    f"shape {value.shape}",
                )
        self.writes.append(box)
        if self.write_hook is not None:
            self.write_hook(box, value)

    # Ref syntax used by the kernel bodies
    def __getitem__(self, idx: Any) -> ShadowArray:
        return self.read(idx)

    def __setitem__(self, idx: Any, value: Any) -> None:
        self.write(idx, value)

    def full_box(self) -> Box:
        return tuple((0, s) for s in self.shape)

    def __repr__(self) -> str:
        return f"ShadowRef({self.name!r}, shape={self.shape})"


# ---------------------------------------------------------------------------
# Pallas / JAX module shims (streaming kernel surface)
# ---------------------------------------------------------------------------


class ShimContext:
    """Mutable state the shims thread through a shadow run: the grid
    position of the simulated step and a per-iteration callback the
    streaming audit uses to track the current chunk."""

    def __init__(self, program_ids: tuple[int, ...] = ()):
        self.program_ids = tuple(program_ids)
        self.on_iter: Callable[[int], None] | None = None


class ShadowCopy:
    """Shadow async DMA: ``start()`` performs the copy synchronously
    (read src box → write dst box, provenance attached); ``wait()`` is
    a no-op. The emitter constructs fresh copy objects for wait-only
    use, so the copy must happen at start(), never at wait()."""

    def __init__(self, src: Any, dst: Any):
        self.src = src
        self.dst = dst

    @staticmethod
    def _as_view(end: Any) -> ShadowView:
        if isinstance(end, ShadowView):
            return end
        if isinstance(end, ShadowRef):
            return ShadowView(end, Ellipsis)
        raise AuditError("bounds", f"DMA endpoint {end!r} is not a ref")

    def start(self) -> None:
        self._as_view(self.dst).write(self._as_view(self.src).read())

    def wait(self) -> None:
        pass


class ShimSem:
    """Inert stand-in for DMA semaphore refs (``sem.at[slot]``)."""

    @property
    def at(self) -> "ShimSem":
        return self

    def __getitem__(self, idx: Any) -> "ShimSem":
        return self


class ShimPl:
    def __init__(self, ctx: ShimContext):
        self._ctx = ctx

    def program_id(self, i: int) -> int:
        return self._ctx.program_ids[i]

    @staticmethod
    def ds(start: Any, size: Any) -> slice:
        return slice(int(start), int(start) + int(size))

    @staticmethod
    def when(cond: Any) -> Callable[[Callable[[], Any]], Any]:
        def deco(fn: Callable[[], Any]) -> Any:
            if bool(cond):
                fn()
            return fn

        return deco


class ShimPltpu:
    @staticmethod
    def make_async_copy(src: Any, dst: Any, sem: Any) -> ShadowCopy:
        return ShadowCopy(src, dst)


class _ShimLax:
    def __init__(self, ctx: ShimContext):
        self._ctx = ctx

    @staticmethod
    def rem(a: Any, b: Any) -> int:
        return int(a) % int(b)

    def fori_loop(
        self, lo: int, hi: int, body: Callable[[int, Any], Any], init: Any
    ) -> Any:
        carry = init
        for i in range(int(lo), int(hi)):
            if self._ctx.on_iter is not None:
                self._ctx.on_iter(i)
            carry = body(i, carry)
        return carry

    def __getattr__(self, name: str) -> Any:
        import jax

        return getattr(jax.lax, name)


class ShimJax:
    def __init__(self, ctx: ShimContext):
        self.lax = _ShimLax(ctx)

    def __getattr__(self, name: str) -> Any:
        import jax

        return getattr(jax, name)


@contextlib.contextmanager
def shadow_shims(ctx: ShimContext) -> Iterator[None]:
    """Swap ``emit``'s ``pl``/``pltpu``/``jax`` globals for shims while
    a kernel body runs in shadow; always restored on exit."""
    from repro.kernels import emit

    saved = (emit.pl, emit.pltpu, emit.jax)
    emit.pl, emit.pltpu, emit.jax = (
        ShimPl(ctx), ShimPltpu(), ShimJax(ctx),
    )
    try:
        yield
    finally:
        emit.pl, emit.pltpu, emit.jax = saved


# ---------------------------------------------------------------------------
# Synthetic φ
# ---------------------------------------------------------------------------


def make_synthetic_phis(
    plan: Any,
    expected_exts: Sequence[tuple[int, ...]] | None,
    *,
    observed_exts: list[tuple[int, ...]] | None = None,
) -> tuple[Callable[..., ShadowArray], ...]:
    """Auditor-supplied φ sequence (one per fused sweep).

    Each φ proves, at its call boundary, that (a) every operator's
    derivative block has identical spatial extents and ``n_f`` rows,
    (b) those extents equal the independently derived sweep geometry
    ``τ + 2r·(S-1-s)`` (when ``expected_exts`` is given), and (c) the
    aux carry, when present, is point-wise aligned with the derivative
    blocks. It returns a fresh ``(n_out, *ext)`` shadow — never runs
    user compute. ``observed_exts`` collects the extents each sweep
    actually saw, which the VMEM fidelity check replays as the measured
    carried-intermediate size.
    """

    def make_one(s: int) -> Callable[..., ShadowArray]:
        def phi(derivs: dict, aux: Any = None) -> ShadowArray:
            exts = {tuple(d.shape[1:]) for d in derivs.values()}
            rows = {int(d.shape[0]) for d in derivs.values()}
            if len(exts) != 1 or len(rows) != 1:
                raise AuditError(
                    "phi",
                    f"sweep {s}: misaligned derivative blocks "
                    f"(extents {sorted(exts)}, rows {sorted(rows)})",
                )
            (ext,) = exts
            (n_rows,) = rows
            if n_rows != plan.n_f:
                raise AuditError(
                    "phi",
                    f"sweep {s}: derivative rows {n_rows} != n_f "
                    f"{plan.n_f}",
                )
            if expected_exts is not None and ext != tuple(
                expected_exts[s]
            ):
                raise AuditError(
                    "phi",
                    f"sweep {s}: derivative extents {ext} != expected "
                    f"sweep geometry {tuple(expected_exts[s])}",
                )
            if plan.n_aux:
                if aux is None:
                    raise AuditError(
                        "phi", f"sweep {s}: aux-carrying plan called "
                        "φ without an aux operand"
                    )
                if tuple(aux.shape) != (plan.n_aux,) + ext:
                    raise AuditError(
                        "phi",
                        f"sweep {s}: aux carry shape "
                        f"{tuple(aux.shape)} not aligned with "
                        f"({plan.n_aux},) + {ext}",
                    )
            elif aux is not None:
                raise AuditError(
                    "phi", f"sweep {s}: unexpected aux operand"
                )
            if observed_exts is not None:
                observed_exts.append(ext)
            return ShadowArray((plan.n_out,) + ext, np.dtype(plan.dtype))

        return phi

    return tuple(make_one(s) for s in range(plan.fuse_steps))
