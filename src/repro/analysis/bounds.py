"""Interval-domain bounds proofs for every lowerable StencilPlan.

:func:`audit_plan` shadow-executes the plan's actual kernel body (the
very functions ``repro.kernels.emit`` hands to ``pl.pallas_call``)
against :class:`~repro.analysis.shadow.ShadowRef` operands shaped by
the emitter's own geometry hooks (``lowering_windows`` /
``stream_extents``), and proves:

* **placement** — per axis, the grid tiles the interior exactly and
  the extremal grid step's staged window lands exactly on the padded
  extent (affine index maps attain their extrema at grid corners, so
  corner arithmetic is a proof for the whole grid);
* **bounds** — every load in the body stays inside the staged window
  and every store inside the output tile (strict shadow slicing: any
  index numpy would silently clamp raises);
* **coverage** — the union of the body's store boxes covers the output
  tile exactly (catches unroll sub-tile gaps);
* **uninit** — scratch reads are covered by prior writes, across
  temporal-sweep shrinkage and the streaming kernel's carried halo
  planes (plane-provenance tracking: every working-set plane must hold
  exactly the global plane the chunk's input window calls for);
* **sweep geometry** — at each synthetic-φ call boundary, derivative
  blocks and aux carries are extent-aligned with the independently
  derived ``τ + 2r·(S-1-s)`` schedule.

The shadow run also measures the VMEM working set actually staged
(ref shapes + the observed carried intermediate), which
``repro.analysis.vmem`` checks against the cost model.
"""
from __future__ import annotations

import dataclasses
import itertools
import math
from typing import Any

import numpy as np

from repro.analysis.findings import AuditError, Finding
from repro.analysis.shadow import (
    Box,
    ShadowArray,
    ShadowRef,
    ShimContext,
    ShimSem,
    make_synthetic_phis,
    shadow_shims,
    uncovered,
)
from repro.kernels.plan import StencilPlan


@dataclasses.dataclass
class PlanAudit:
    """Result of auditing one plan: findings plus the measured VMEM
    working set (bytes) the shadow run staged, for the fidelity check."""

    sid: str
    findings: list[Finding]
    measured_vmem: int | None


def _derived_exec_plan(plan: StencilPlan) -> StencilPlan:
    """The batch=1 plan a batched launch actually lowers — mirror of
    the ``dataclasses.replace`` in ``emit._fused_batched`` (member-
    major flattening scales every field count by B)."""
    if plan.batch == 1:
        return plan
    return dataclasses.replace(
        plan, batch=1, n_f=plan.batch * plan.n_f,
        n_out=plan.batch * plan.n_out, n_aux=plan.batch * plan.n_aux,
    )


def _sweep_exts(plan: StencilPlan) -> list[tuple[int, ...]]:
    """Independently derived per-sweep derivative extents: sweep ``s``
    of ``S`` sees ``τ + 2r·(S-1-s)`` per axis."""
    return [
        tuple(
            t + 2 * r * (plan.fuse_steps - 1 - s)
            for t, r in zip(plan.block, plan.radii)
        )
        for s in range(plan.fuse_steps)
    ]


def _audit_pipelined(
    plan: StencilPlan, ops: Any, findings: list[Finding],
    observed: list[tuple[int, ...]],
) -> int:
    """Shadow-run the pipelined/temporal/tc body once (it is grid-
    position independent; placement is proved arithmetically) and
    return the measured VMEM bytes."""
    from repro.kernels import emit

    sid = plan.strategy_id
    windows = emit.lowering_windows(plan)
    window, out_tile = windows["window"], windows["out_tile"]
    aux_window = windows["aux_window"]
    steps = plan.block[:-1] + (plan.x_step,)
    padded = tuple(
        n + 2 * h for n, h in zip(plan.interior, plan.halo)
    )
    for a, (g, st) in enumerate(zip(plan.grid, steps)):
        if g * st != plan.interior[a]:
            findings.append(Finding(
                "coverage", sid,
                f"axis {a}: grid {g} x step {st} != interior "
                f"{plan.interior[a]}",
            ))
        if (g - 1) * st + window[a] != padded[a]:
            findings.append(Finding(
                "bounds", sid,
                f"axis {a}: extremal window [{(g - 1) * st}, "
                f"{(g - 1) * st + window[a]}) != padded extent "
                f"{padded[a]}",
            ))

    f_ref = ShadowRef(
        "f", (plan.n_f,) + window, plan.dtype, initialized=True
    )
    o_ref = ShadowRef("o", (plan.n_out,) + out_tile, plan.dtype)
    rest: list[ShadowRef] = []
    if plan.n_aux:
        rest.append(ShadowRef(
            "aux", (plan.n_aux,) + aux_window, plan.dtype,
            initialized=True,
        ))
    phis = make_synthetic_phis(
        plan,
        _sweep_exts(plan) if plan.fuse_steps > 1 else [plan.block],
        observed_exts=observed,
    )
    tc = plan.strategy == "tc"
    ctx = ShimContext(program_ids=(0,) * plan.rank)
    try:
        with shadow_shims(ctx):
            # Kernel bodies and derivs lowerings resolved through the
            # module AT CALL TIME so the mutation harness's patched
            # defects are what actually runs.
            derivs_fn = (
                emit._block_derivs_tc if tc else emit._block_derivs
            )
            if plan.fuse_steps > 1:
                emit._kernel_temporal(
                    f_ref, *rest, o_ref, ops=ops, radii=plan.radii,
                    tile=plan.block, phis=phis, n_f=plan.n_f,
                    has_aux=bool(plan.n_aux), derivs_fn=derivs_fn,
                )
            else:
                emit._kernel_pipelined(
                    f_ref, *rest, o_ref, ops=ops, radii=plan.radii,
                    tile=plan.block, phi=phis[0],
                    unroll=plan.unroll, has_aux=bool(plan.n_aux),
                    derivs_fn=derivs_fn,
                )
    except AuditError as e:
        findings.append(Finding(e.cls, sid, e.detail))
    else:
        holes = uncovered(o_ref.full_box(), o_ref.writes)
        if holes:
            findings.append(Finding(
                "coverage", sid,
                f"output tile region {holes[0]} never stored",
            ))

    itemsize = np.dtype(plan.dtype).itemsize
    mid = (
        plan.n_f * math.prod(observed[0])
        if plan.fuse_steps > 1 and observed else 0
    )
    aux_sz = (
        plan.n_aux * math.prod(aux_window) if plan.n_aux else 0
    )
    return itemsize * (
        2 * plan.n_f * math.prod(window)
        + 2 * aux_sz
        + mid
        + plan.n_out * math.prod(out_tile)
    )


def _audit_stream(
    plan: StencilPlan, ops: Any, findings: list[Finding],
    observed: list[tuple[int, ...]],
) -> int:
    """Shadow-run the streaming kernel at every cross-grid corner with
    plane-provenance tracking, and return the measured VMEM bytes.

    The invariant proved at every chunk's compute read: working-set
    plane ``p`` holds global (padded) plane ``chunk·τ₀ + p`` — which
    is exactly what the carried-halo + fresh-plane choreography must
    establish. A wrong prologue width surfaces as an uninitialized
    plane (-1), a skewed carry or fresh-plane offset as a provenance
    mismatch (out-of-bounds in global coordinates).
    """
    from repro.kernels import emit

    sid = plan.strategy_id
    ext = emit.stream_extents(plan)
    ts, hs = plan.block[0], plan.halo[0]
    n_chunks = ext["n_chunks"]
    padded = tuple(
        n + 2 * h for n, h in zip(plan.interior, plan.halo)
    )
    cross_grid = tuple(
        n // t for n, t in zip(plan.interior[1:], plan.block[1:])
    )
    corners = itertools.product(
        *[(0, g - 1) if g > 1 else (0,) for g in cross_grid]
    )
    for corner in corners:
        exp_halo = tuple(
            (c * t, c * t + t + 2 * h)
            for c, t, h in zip(corner, plan.block[1:], plan.halo[1:])
        )
        exp_tile = tuple(
            (c * t, (c + 1) * t)
            for c, t in zip(corner, plan.block[1:])
        )
        f_hbm = ShadowRef(
            "f_hbm", (plan.n_f,) + padded, plan.dtype, initialized=True
        )
        o_hbm = ShadowRef(
            "o_hbm", (plan.n_out,) + plan.interior, plan.dtype
        )
        work = ShadowRef("work", (plan.n_f,) + ext["work"], plan.dtype)
        pf0 = ShadowRef("pf0", (plan.n_f,) + ext["prefetch"], plan.dtype)
        pf1 = ShadowRef("pf1", (plan.n_f,) + ext["prefetch"], plan.dtype)
        outbuf = ShadowRef(
            "outbuf", (plan.n_out,) + ext["outbuf"], plan.dtype
        )
        g_work = np.full(ext["work"][0], -1, np.int64)
        g_pf = {id(pf0): np.full(ts, -1, np.int64),
                id(pf1): np.full(ts, -1, np.int64)}
        chunk_now = [0]

        def check_cross(sbox: Box, expect, what: str) -> None:
            if tuple(sbox[2:]) != tuple(expect):
                raise AuditError(
                    "bounds",
                    f"{what}: cross-stream box {tuple(sbox[2:])} != "
                    f"grid-step window {tuple(expect)}",
                )

        def src_of(value: Any, what: str):
            if not isinstance(value, ShadowArray) or value.src is None:
                raise AuditError(
                    "bounds", f"{what} written from a non-copy value"
                )
            return value.src

        def pf_write(ref, box, value, exp_halo=exp_halo):
            sref, sbox = src_of(value, ref.name)
            if sref is not f_hbm:
                raise AuditError(
                    "bounds",
                    f"prefetch {ref.name} filled from {sref.name}, "
                    "expected f_hbm",
                )
            check_cross(sbox, exp_halo, f"prefetch {ref.name}")
            lo, hi = box[1]
            slo, shi = sbox[1]
            g_pf[id(ref)][lo:hi] = np.arange(slo, shi)

        def work_write(box, value, exp_halo=exp_halo):
            sref, sbox = src_of(value, "work")
            lo, hi = box[1]
            slo, shi = sbox[1]
            if sref is f_hbm:
                check_cross(sbox, exp_halo, "work<-f_hbm")
                g_work[lo:hi] = np.arange(slo, shi)
            elif sref is pf0 or sref is pf1:
                g_work[lo:hi] = g_pf[id(sref)][slo:shi]
            elif sref is work:
                g_work[lo:hi] = g_work[slo:shi].copy()
            else:
                raise AuditError(
                    "bounds", f"work filled from {sref.name}"
                )

        def work_read(box):
            if box != work.full_box():
                return  # partial read (carry source) — covered by the
                # uninit check; provenance is proved at compute reads
            c = chunk_now[0]
            expect = np.arange(c * ts, c * ts + ts + 2 * hs)
            if not np.array_equal(g_work, expect):
                bad = int(np.argmax(g_work != expect))
                raise AuditError(
                    "uninit" if g_work[bad] < 0 else "bounds",
                    f"chunk {c}: working-set plane {bad} holds global "
                    f"plane {int(g_work[bad])}, input window needs "
                    f"{int(expect[bad])}",
                )

        def out_write(box, value, exp_tile=exp_tile):
            sref, _ = src_of(value, "o_hbm")
            if sref is not outbuf:
                raise AuditError(
                    "bounds", f"o_hbm written from {sref.name}"
                )
            c = chunk_now[0]
            if box[1] != (c * ts, (c + 1) * ts):
                raise AuditError(
                    "bounds",
                    f"chunk {c}: output planes {box[1]} != "
                    f"({c * ts}, {(c + 1) * ts})",
                )
            check_cross(box, exp_tile, "o_hbm store")

        pf0.write_hook = lambda box, v: pf_write(pf0, box, v)
        pf1.write_hook = lambda box, v: pf_write(pf1, box, v)
        work.write_hook = work_write
        work.read_hook = work_read
        o_hbm.write_hook = out_write

        phis = make_synthetic_phis(
            plan, _sweep_exts(plan), observed_exts=observed
        )
        ctx = ShimContext(program_ids=corner)
        ctx.on_iter = lambda i: chunk_now.__setitem__(0, i)
        try:
            with shadow_shims(ctx):
                emit._kernel_stream(
                    f_hbm, o_hbm, work, pf0, pf1, outbuf,
                    ShimSem(), ShimSem(),  # inert DMA semaphores
                    ops=ops, radii=plan.radii, tile=plan.block,
                    phis=phis, n_chunks=n_chunks,
                )
        except AuditError as e:
            findings.append(Finding(e.cls, sid, e.detail))
            continue
        target = ((0, plan.n_out), (0, plan.interior[0])) + exp_tile
        holes = uncovered(target, o_hbm.writes)
        if holes:
            findings.append(Finding(
                "coverage", sid,
                f"streamed output region {holes[0]} never stored "
                f"(cross corner {corner})",
            ))

    itemsize = np.dtype(plan.dtype).itemsize
    mid = (
        plan.n_f * math.prod(observed[0])
        if plan.fuse_steps > 1 and observed else 0
    )
    return itemsize * (
        plan.n_f * math.prod(ext["work"])
        + 2 * plan.n_f * math.prod(ext["prefetch"])
        + mid
        + plan.n_out * math.prod(ext["outbuf"])
    )


def audit_plan(plan: StencilPlan, ops: Any) -> PlanAudit:
    """Run the full bounds/coverage/uninit/geometry audit for one plan.

    Batched plans are audited through the batch=1 plan the launch
    actually lowers (member-major field scaling), reported under the
    ORIGINAL strategy id so findings name the user-facing plan.
    """
    sid = plan.strategy_id
    exec_plan = _derived_exec_plan(plan)
    findings: list[Finding] = []
    observed: list[tuple[int, ...]] = []
    try:
        if plan.strategy == "swc_stream":
            measured = _audit_stream(exec_plan, ops, findings, observed)
        else:
            measured = _audit_pipelined(
                exec_plan, ops, findings, observed
            )
    except AuditError as e:  # geometry failures outside the body run
        findings.append(Finding(e.cls, sid, e.detail))
        measured = None
    findings = [
        dataclasses.replace(f, plan=sid) if f.plan != sid else f
        for f in findings
    ]
    return PlanAudit(sid=sid, findings=findings, measured_vmem=measured)
