"""Checkpoint substrate: async atomic saves, keep-k retention, elastic
restore onto any mesh."""
from repro.checkpoint.manager import CheckpointManager  # noqa: F401
