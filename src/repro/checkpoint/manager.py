"""Checkpoint manager: async, atomic, keep-k, elastic.

Layout per step::

    <dir>/step_000120.tmp-<nonce>/   (written)
    <dir>/step_000120/               (atomic rename on completion)
        manifest.json                (tree structure, shapes, dtypes)
        <leaf-path>.npy              (one file per pytree leaf)

Properties needed at cluster scale, all honored here:

* **atomicity** — a checkpoint is visible iff complete (tmp-dir + rename;
  a crashed save never corrupts the latest-step discovery);
* **async**     — device→host transfer happens synchronously (cheap),
  file I/O on a background thread so the train loop isn't blocked;
* **keep-k**    — bounded disk usage with the newest k checkpoints;
* **elastic**   — leaves are stored UNsharded (gathered); ``restore``
  device_puts onto whatever shardings the NEW mesh dictates, so restarts
  may change pod count / mesh shape freely. (At 1000-node scale the
  gather becomes a sharded OCDBT-style store — the manifest format
  already records per-leaf shape/dtype to support that swap; see
  DESIGN.md §3.)
"""
from __future__ import annotations

import json
import os
import re
import shutil
import threading
import uuid
from typing import Any

import jax
import numpy as np

_STEP_RE = re.compile(r"^step_(\d+)$")


def _sanitize(path_str: str) -> str:
    return re.sub(r"[^A-Za-z0-9_.-]", "_", path_str)


def _path_str(path) -> str:
    return "/".join(
        str(getattr(p, "key", getattr(p, "name", p))) for p in path
    )


class CheckpointManager:
    def __init__(self, directory: str, *, keep: int = 3):
        self.dir = directory
        self.keep = keep
        os.makedirs(directory, exist_ok=True)
        self._thread: threading.Thread | None = None

    # -- save -----------------------------------------------------------------

    def save(self, step: int, tree: Any, *, blocking: bool = False) -> None:
        """Snapshot to host memory now; write to disk (a)synchronously."""
        flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
        # Gather to host immediately — the caller may donate/overwrite
        # device buffers right after this returns.
        host_leaves = [
            (_path_str(path), np.asarray(jax.device_get(leaf)))
            for path, leaf in flat
        ]
        self.wait()  # one in-flight save at a time
        worker = threading.Thread(
            target=self._write, args=(step, host_leaves, str(treedef)),
            daemon=True,
        )
        worker.start()
        self._thread = worker
        if blocking:
            self.wait()

    def _write(self, step: int, host_leaves, treedef_repr: str) -> None:
        final = os.path.join(self.dir, f"step_{step:08d}")
        tmp = final + f".tmp-{uuid.uuid4().hex[:8]}"
        os.makedirs(tmp, exist_ok=True)
        manifest = {"step": step, "leaves": [], "treedef": treedef_repr}
        for path_str, arr in host_leaves:
            fname = _sanitize(path_str) + ".npy"
            np.save(os.path.join(tmp, fname), arr)
            manifest["leaves"].append(
                {
                    "path": path_str,
                    "file": fname,
                    "shape": list(arr.shape),
                    "dtype": str(arr.dtype),
                }
            )
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f)
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)
        self._gc()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _gc(self) -> None:
        steps = self.all_steps()
        for s in steps[: -self.keep] if self.keep else []:
            shutil.rmtree(
                os.path.join(self.dir, f"step_{s:08d}"), ignore_errors=True
            )

    # -- restore ----------------------------------------------------------------

    def all_steps(self) -> list[int]:
        out = []
        for name in os.listdir(self.dir):
            m = _STEP_RE.match(name)
            if m and os.path.exists(
                os.path.join(self.dir, name, "manifest.json")
            ):
                out.append(int(m.group(1)))
        return sorted(out)

    def latest_step(self) -> int | None:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def restore(
        self,
        target_tree: Any,
        step: int | None = None,
        *,
        shardings: Any = None,
    ) -> tuple[Any, int]:
        """Load into the structure of ``target_tree``; device_put with
        ``shardings`` (same structure) when given — THE elastic path:
        the stored full arrays are resharded onto the current mesh."""
        if step is None:
            step = self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoints under {self.dir}")
        d = os.path.join(self.dir, f"step_{step:08d}")
        with open(os.path.join(d, "manifest.json")) as f:
            manifest = json.load(f)
        by_path = {e["path"]: e for e in manifest["leaves"]}

        flat, treedef = jax.tree_util.tree_flatten_with_path(target_tree)
        shard_flat = (
            jax.tree_util.tree_leaves(shardings)
            if shardings is not None
            else [None] * len(flat)
        )
        leaves = []
        for (path, ref_leaf), shard in zip(flat, shard_flat):
            entry = by_path.get(_path_str(path))
            if entry is None:
                raise KeyError(
                    f"checkpoint step {step} missing leaf {_path_str(path)}"
                )
            arr = np.load(os.path.join(d, entry["file"]))
            if tuple(arr.shape) != tuple(ref_leaf.shape):
                raise ValueError(
                    f"shape mismatch for {_path_str(path)}: "
                    f"ckpt {arr.shape} vs model {ref_leaf.shape}"
                )
            if shard is not None:
                leaves.append(jax.device_put(arr, shard))
            else:
                leaves.append(jax.numpy.asarray(arr))
        return jax.tree_util.tree_unflatten(treedef, leaves), step
