import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

# --- everything below may import jax (device count is now locked) -----------
"""Multi-pod dry-run (assignment requirement e).

For every (architecture × input shape) cell and each production mesh
(single-pod 16×16 = 256 chips, multi-pod 2×16×16 = 512 chips):

    lowered  = jax.jit(step, in_shardings=…, out_shardings=…).lower(**specs)
    compiled = lowered.compile()
    memory_analysis / cost_analysis / collective-bytes (HLO parse)

A cell that fails to lower+compile (sharding mismatch, OOM at compile,
unsupported collective) is a bug in the framework — the sweep records
pass/fail per cell into a JSON consumed by EXPERIMENTS.md §Dry-run and
the roofline table (§Roofline, single-pod only per the assignment).

Usage:
    PYTHONPATH=src python -m repro.launch.dryrun --arch qwen2.5-3b \
        --shape train_4k --mesh both --out results/dryrun.json
    PYTHONPATH=src python -m repro.launch.dryrun --all
"""
import argparse  # noqa: E402
import json  # noqa: E402
import time  # noqa: E402
import traceback  # noqa: E402

import jax  # noqa: E402

from repro.configs.registry import (  # noqa: E402
    ARCH_IDS,
    SHAPES,
    cell_status,
    get_config,
    uses_fsdp,
)
from repro.core import rooflinelib as rl  # noqa: E402
from repro.distrib.sharding import rules_context  # noqa: E402
from repro.launch import specs as S  # noqa: E402
from repro.launch.mesh import make_production_mesh, mesh_chip_count  # noqa: E402
from repro.launch.steps import (  # noqa: E402
    jit_prefill_step,
    jit_serve_step,
    jit_train_step,
)


def lower_cell(arch_id: str, shape_name: str, mesh, cfg=None,
               profile: str = "tp"):
    """Build + lower the right step for one cell. Returns (lowered, meta)."""
    from repro.distrib.sharding import profile_act_rules

    cfg = cfg or get_config(arch_id)
    shape = SHAPES[shape_name]
    with rules_context(mesh, profile_act_rules(profile)):
        if shape.kind == "train":
            batch_abs = S.train_input_specs(cfg, shape)
            jitted, _ = jit_train_step(
                cfg, mesh, batch_abs, fsdp=uses_fsdp(arch_id),
                profile=profile,
            )
            params_abs = S.abstract_params(cfg)
            opt_abs = S.abstract_opt_state(params_abs)
            lowered = jitted.lower(params_abs, opt_abs, batch_abs)
            n_tokens = shape.global_batch * (
                cfg.max_target_len if cfg.is_encdec else shape.seq_len
            )
        elif shape.kind == "prefill":
            batch_abs = S.prefill_input_specs(cfg, shape)
            jitted, _ = jit_prefill_step(cfg, mesh, batch_abs)
            params_abs = S.abstract_params(cfg)
            lowered = jitted.lower(params_abs, batch_abs)
            n_tokens = shape.global_batch * (
                cfg.encoder_seq if cfg.is_encdec else shape.seq_len
            )
        else:  # decode
            batch_abs = S.decode_input_specs(cfg, shape)
            cache_abs = S.abstract_decode_cache(cfg, shape)
            jitted, _ = jit_serve_step(cfg, mesh, batch_abs, cache_abs)
            params_abs = S.abstract_params(cfg)
            lowered = jitted.lower(params_abs, cache_abs, batch_abs)
            n_tokens = shape.global_batch  # one new token per sequence
    return lowered, {"kind": shape.kind, "tokens_per_step": n_tokens}


def _metrics_from(compiled, chips) -> dict:
    hlo = compiled.as_text()
    ca = compiled.cost_analysis()
    if isinstance(ca, (list, tuple)):
        ca = ca[0]
    coll = rl.parse_collectives(hlo)
    return {
        "flops": float(ca.get("flops", 0.0)),
        "bytes": float(ca.get("bytes accessed", 0.0)),
        "coll_result": float(coll.total_result_bytes),
        "coll_wire": float(coll.total_wire_bytes),
        "coll_counts": {k: v for k, v in coll.counts.items() if v},
    }


def _lin(a: dict, b: dict, sa: float, sb: float) -> dict:
    """sa·a + sb·b element-wise (counts included, rounded)."""
    out = {}
    for k in ("flops", "bytes", "coll_result", "coll_wire"):
        out[k] = max(sa * a[k] + sb * b[k], 0.0)
    keys = set(a["coll_counts"]) | set(b["coll_counts"])
    out["coll_counts"] = {
        k: int(round(sa * a["coll_counts"].get(k, 0)
                     + sb * b["coll_counts"].get(k, 0)))
        for k in keys
    }
    return out


def extrapolated_metrics(arch_id: str, shape_name: str, mesh, cfg) -> dict:
    """Exact-FLOP roofline metrics via the layer-delta method.

    XLA's cost_analysis counts a while body once, so the scan build
    under-counts per-layer work. Fully unrolling the production depth
    compiles for minutes, so we lower python-UNROLLED builds at two
    reduced depths and extrapolate linearly (layers are homogeneous per
    family; embed/logits/loss land in the constant term). Every number
    still comes from a real compiled artifact at full sharding/shape.
    """
    import dataclasses as dc

    def measure(cfg_r):
        lowered, _ = lower_cell(
            arch_id, shape_name, mesh,
            cfg=dc.replace(cfg_r, analysis_unroll=True),
        )
        return _metrics_from(lowered.compile(), None)

    if cfg.is_encdec:
        mA = measure(dc.replace(cfg, n_layers=1, n_encoder_layers=1))
        mB = measure(dc.replace(cfg, n_layers=2, n_encoder_layers=2))
        per = _lin(mB, mA, 1.0, -1.0)
        return _lin(mA, per, 1.0, float(cfg.n_layers - 1))
    if cfg.hybrid_pattern:
        n_super = cfg.n_layers // cfg.hybrid_pattern
        n_tail = cfg.n_layers - n_super * cfg.hybrid_pattern
        mA = measure(dc.replace(cfg, n_layers=3))
        mB = measure(dc.replace(cfg, n_layers=6))
        per_super = _lin(mB, mA, 1.0, -1.0)
        total = _lin(mA, per_super, 1.0, float(n_super - 1))
        if n_tail:
            mC = measure(dc.replace(cfg, n_layers=7))
            per_tail = _lin(mC, mB, 1.0, -1.0)
            total = _lin(total, per_tail, 1.0, float(n_tail))
        return total
    mA = measure(dc.replace(cfg, n_layers=1))
    mB = measure(dc.replace(cfg, n_layers=2))
    per = _lin(mB, mA, 1.0, -1.0)
    return _lin(mA, per, 1.0, float(cfg.n_layers - 1))


def analyze_cell(
    arch_id: str, shape_name: str, multi_pod: bool, *,
    cfg_override=None, analysis: bool = True,
) -> dict:
    from repro.core.trafficmodel import modeled_hbm_bytes

    cfg = cfg_override or get_config(arch_id)
    mesh = make_production_mesh(multi_pod=multi_pod)
    chips = mesh_chip_count(mesh)
    t0 = time.time()
    lowered, meta = lower_cell(arch_id, shape_name, mesh, cfg=cfg)
    t_lower = time.time() - t0
    t0 = time.time()
    compiled = lowered.compile()
    t_compile = time.time() - t0

    if analysis and not multi_pod:
        t0 = time.time()
        m = extrapolated_metrics(arch_id, shape_name, mesh, cfg)
        t_analysis = time.time() - t0
    else:
        m = _metrics_from(compiled, chips)  # scan build (under-counted)
        t_analysis = None

    shape = SHAPES[shape_name]
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    dp_ways = sizes.get("pod", 1) * sizes.get("data", 1)
    modeled_bytes = modeled_hbm_bytes(
        cfg, shape.kind, shape.seq_len, shape.global_batch,
        model_ways=sizes.get("model", 1), dp_ways=dp_ways,
        fsdp=uses_fsdp(arch_id),
    )
    roof = rl.Roofline(
        flops=m["flops"],
        hbm_bytes=modeled_bytes,
        collective_result_bytes=m["coll_result"],
        collective_wire_bytes=m["coll_wire"],
        chips=chips,
        hw=rl.TPU_V5E,
        dtype_bytes=2,
    )
    hlo_memory_s = m["bytes"] / rl.TPU_V5E.hbm_bw
    mem = compiled.memory_analysis()
    mem_info = {}
    for attr in (
        "argument_size_in_bytes",
        "output_size_in_bytes",
        "temp_size_in_bytes",
        "generated_code_size_in_bytes",
    ):
        try:
            mem_info[attr] = int(getattr(mem, attr))
        except (AttributeError, TypeError, ValueError):
            pass  # field absent on this backend's MemoryAnalysis

    n_params = cfg.n_params()
    n_active = cfg.n_active_params()
    toks = meta["tokens_per_step"]
    if meta["kind"] == "train":
        model_flops_global = rl.model_flops_train(n_active, toks)
    else:
        model_flops_global = rl.model_flops_decode(n_active, toks)
    model_flops_chip = model_flops_global / chips

    return {
        "arch": arch_id,
        "shape": shape_name,
        "mesh": "multi" if multi_pod else "single",
        "chips": chips,
        "kind": meta["kind"],
        "status": "ok",
        "lower_s": round(t_lower, 1),
        "compile_s": round(t_compile, 1),
        "analysis_compile_s": (
            round(t_analysis, 1) if t_analysis is not None else None
        ),
        "flops_per_chip": roof.flops,
        "hbm_bytes_modeled_per_chip": roof.hbm_bytes,
        "hbm_bytes_hlo_per_chip": m["bytes"],
        "coll_result_bytes": roof.collective_result_bytes,
        "coll_wire_bytes": roof.collective_wire_bytes,
        "coll_counts": m["coll_counts"],
        "compute_s": roof.compute_s,
        "memory_s": roof.memory_s,
        "memory_s_hlo_upper": hlo_memory_s,
        "collective_s": roof.collective_s,
        "dominant": roof.dominant,
        "model_flops_per_chip": model_flops_chip,
        "useful_flops_ratio": roof.useful_flops_fraction(model_flops_chip),
        "roofline_fraction": roof.roofline_fraction(model_flops_chip),
        "memory": mem_info,
        "n_params": n_params,
        "n_active_params": n_active,
    }


def run_cells(cells, multi: str, out_path: str | None):
    results = []
    if out_path and os.path.exists(out_path):
        with open(out_path) as f:
            results = json.load(f)
    done = {(r["arch"], r["shape"], r["mesh"]) for r in results}
    meshes = {"single": [False], "multi": [True], "both": [False, True]}[multi]
    for arch_id, shape_name in cells:
        status = cell_status(arch_id, shape_name)
        for mp in meshes:
            mesh_name = "multi" if mp else "single"
            key = (arch_id, shape_name, mesh_name)
            if key in done:
                continue
            if status != "run":
                rec = {
                    "arch": arch_id, "shape": shape_name, "mesh": mesh_name,
                    "status": status,
                }
                print(f"[skip] {arch_id} × {shape_name} × {mesh_name}: {status}")
            else:
                print(f"[cell] {arch_id} × {shape_name} × {mesh_name} ...",
                      flush=True)
                try:
                    rec = analyze_cell(arch_id, shape_name, mp)
                    print(
                        f"    ok: compile {rec['compile_s']}s  "
                        f"dominant={rec['dominant']}  "
                        f"compute={rec['compute_s']:.3e}s "
                        f"memory={rec['memory_s']:.3e}s "
                        f"coll={rec['collective_s']:.3e}s",
                        flush=True,
                    )
                except Exception as e:  # noqa: BLE001
                    rec = {
                        "arch": arch_id, "shape": shape_name,
                        "mesh": mesh_name, "status": "FAIL",
                        "error": f"{type(e).__name__}: {e}",
                        "traceback": traceback.format_exc()[-2000:],
                    }
                    print(f"    FAIL: {rec['error']}", flush=True)
            results.append(rec)
            if out_path:
                os.makedirs(os.path.dirname(out_path) or ".", exist_ok=True)
                with open(out_path, "w") as f:
                    json.dump(results, f, indent=1)
    return results


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None, choices=list(ARCH_IDS))
    ap.add_argument("--shape", default=None, choices=list(SHAPES))
    ap.add_argument("--mesh", default="both",
                    choices=("single", "multi", "both"))
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default=None)
    args = ap.parse_args()

    if args.all:
        # shape-major: the roofline-critical training cells first
        cells = [(a, s) for s in SHAPES for a in ARCH_IDS]
    else:
        archs = [args.arch] if args.arch else list(ARCH_IDS)
        shapes = [args.shape] if args.shape else list(SHAPES)
        cells = [(a, s) for a in archs for s in shapes]
    results = run_cells(cells, args.mesh, args.out)
    ok = sum(1 for r in results if r.get("status") == "ok")
    fail = sum(1 for r in results if r.get("status") == "FAIL")
    skip = len(results) - ok - fail
    print(f"\ndry-run: {ok} ok, {skip} skipped (documented), {fail} FAILED")
    if fail:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
