"""Ensemble simulation serving: the stencil-workload front door.

``repro.launch.serve`` serves language-model decode; THIS module serves
stencil simulations — thousands of concurrent scenarios (parameter
sweeps, Monte-Carlo ensembles, per-user simulations) funneled through
the batched fused-stencil engine:

* ``SimRequest`` / ``RequestQueue`` — FIFO request intake with
  shape-bucketed draining: requests sharing (spatial shape, dtype,
  n_steps) form one plan-compatible group, and the oldest request's
  bucket is served first (head-of-line FIFO, no starvation).
* ``SimServer`` — one batched ``FusedStencilOp`` per bucket, stacked
  to a (B, n_f, *spatial) operand so one kernel walks all B members
  per block (member-major grid, shared halo — the batch axis of
  ``StencilPlan``). Ops are cached per bucket and ``block="auto"``
  resolves through the persistent tuning cache, so the first batch of
  a bucket warms the ``:b{B}``-keyed record and every later batch
  replays it.
* **Failure domains** — one poisoned request must cost one request,
  never the queue. Every batch runs under a :class:`RetryPolicy`:
  transient failures retry with backoff; repeated failures degrade the
  bucket down the strategy ladder (``tc → swc_stream → swc → hwc``);
  a batch that fails even at the bottom rung is bisected until the
  poison request is isolated and quarantined (its members get an error
  report in ``SimServer.error_reports``, everyone else completes).
  Outputs are validated for NaN/inf before results are handed back,
  and every request carries a status (``ok | retried | degraded |
  quarantined``) in ``BatchReport``/``BENCH_serve.json``.
* ``StragglerMonitor`` hooks (``repro.ft.supervisor``) — per-batch
  wall times feed the trailing-median monitor; a slow batch is flagged
  (and counted in the serve report) exactly like a slow training step.
* ``repro.ft.faults`` — the seeded deterministic fault-injection layer
  (``SimServer(faults=...)``); ``--chaos`` drives the standard seeded
  fault plan through a live serve and asserts the recovery contract.

Run:  PYTHONPATH=src python -m repro.launch.serve_sim --smoke

``--smoke`` serves a small mixed-shape queue, asserts batched-vs-vmap
parity per request, and writes a ``BENCH_serve.json`` throughput
artifact (CI serve-smoke job). ``--smoke --chaos`` additionally injects
the seeded fault plan (poison request, transient compile failure, slow
batch, failing tuning candidate, corrupted ``cache.json``) and writes
``BENCH_serve_chaos.json`` (CI chaos-smoke job).
"""
from __future__ import annotations

import argparse
import collections
import dataclasses
import json
import logging
import subprocess
import time
from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.fusion import FusedStencilOp, integrate
from repro.ft import faults as ftfaults
from repro.ft.faults import FaultInjector
from repro.ft.supervisor import StragglerMonitor
from repro.physics.diffusion import DiffusionProblem

log = logging.getLogger("repro.serve")

# (spatial shape, dtype string, n_steps): requests sharing a key lower
# through ONE batched plan (same domain/dtype) for the SAME step count.
BucketKey = tuple[tuple[int, ...], str, int]

# Graceful-degradation order: most specialized caching regime first,
# the compiler-managed baseline (which always lowers) last. The paper's
# cross-platform finding — no single regime wins everywhere — is also
# why the robust fallback shape is a LADDER across regimes rather than
# a single retry: each rung trades peak throughput for generality.
DEGRADATION_LADDER = ("tc", "swc_stream", "swc", "hwc")

# Per-request status severity: a request that was ever quarantined
# stays quarantined; degraded beats retried beats ok.
_SEVERITY = {"ok": 0, "retried": 1, "degraded": 2, "quarantined": 3}


@dataclasses.dataclass(frozen=True)
class SimRequest:
    """One ensemble member: advance ``f0`` (n_f, *spatial) by
    ``n_steps`` diffusion steps."""

    req_id: int
    f0: jnp.ndarray
    n_steps: int

    @property
    def bucket_key(self) -> BucketKey:
        return (
            tuple(int(n) for n in self.f0.shape[1:]),
            str(self.f0.dtype),
            int(self.n_steps),
        )


class RequestQueue:
    """FIFO request queue with bucket-aware batch draining.

    Generic over the request type: the LM example
    (``examples/serve_batched.py``) pops one request at a time into
    freed decode slots; ensemble serving drains plan-compatible batches
    with :meth:`next_bucket`. Backed by a ``collections.deque`` so the
    hot single-request pop is O(1), not ``list.pop(0)``'s O(n).
    """

    def __init__(self, items=()):
        self._items = collections.deque(items)

    def push(self, item) -> None:
        self._items.append(item)

    def pop(self):
        """Oldest request, or None when empty (LM slot refill)."""
        return self._items.popleft() if self._items else None

    def snapshot(self) -> list:
        """Copy of the queued items in FIFO order — the public,
        non-draining view (callers must not reach into the internal
        deque)."""
        return list(self._items)

    def __len__(self) -> int:
        return len(self._items)

    def __bool__(self) -> bool:
        return bool(self._items)

    def next_bucket(self, bucket_of: Callable, max_batch: int):
        """Drain up to ``max_batch`` requests sharing the OLDEST
        request's bucket key (head-of-line FIFO: the oldest waiting
        request is always served in the next batch). Returns
        ``(key, requests)`` or None when empty."""
        if not self._items:
            return None
        key = bucket_of(self._items[0])
        taken, kept = [], []
        for item in self._items:
            if len(taken) < max_batch and bucket_of(item) == key:
                taken.append(item)
            else:
                kept.append(item)
        self._items = collections.deque(kept)
        return key, taken


@dataclasses.dataclass(frozen=True)
class RetryPolicy:
    """Per-batch failure policy: how a failing batch is retried,
    degraded, and finally bisected.

    1. **Retry** the batch up to ``max_retries`` times at the current
       strategy, sleeping ``backoff_s · 2^(attempt-1)`` between tries
       (a transient compile hiccup or allocator race heals here).
    2. **Degrade** the bucket one rung down ``ladder`` when retries are
       exhausted (a strategy-specific failure — e.g. a tc dtype error
       or a VMEM-oversized streaming candidate — heals here); the rung
       sticks for later batches of the bucket until a quarantine
       re-attributes the fault to a request.
    3. **Bisect** the batch when even the bottom rung fails: halves are
       re-served independently, so a single poison request is isolated
       in O(log B) sub-batches and quarantined while every healthy
       member completes.
    """

    max_retries: int = 2
    backoff_s: float = 0.05
    ladder: tuple[str, ...] = DEGRADATION_LADDER

    def backoff(self, attempt: int) -> float:
        return self.backoff_s * (2 ** max(0, attempt - 1))

    def degrade(self, strategy: str) -> str | None:
        """Next rung down the ladder, or None at the bottom.
        ``"auto"`` — a meta-strategy that may have resolved to any
        regime — re-enters at the always-lowerable ``swc`` rung."""
        if strategy == "auto":
            return "swc"
        if strategy not in self.ladder:
            return None
        i = self.ladder.index(strategy)
        return self.ladder[i + 1] if i + 1 < len(self.ladder) else None


@dataclasses.dataclass
class BatchReport:
    """One executed batch: bucket, members, the timing the straggler
    monitor saw, and the failure-domain outcome (strategy actually
    used, retries consumed, per-request status)."""

    index: int
    key: BucketKey
    batch: int
    seconds: float
    straggler: bool
    strategy: str = ""
    retries: int = 0
    statuses: dict[int, str] = dataclasses.field(default_factory=dict)


class SimServer:
    """Shape-bucketed batch server over the batched fused engine.

    One ``FusedStencilOp`` per (bucket, strategy) — built lazily,
    cached for the server's lifetime (``op_builds`` counts cache
    misses); requests are stacked member-major to (B, n_f, *spatial)
    and integrated in one batched call per bucket.

    Failure domains: every batch executes inside a try/except driven
    by ``retry`` (:class:`RetryPolicy` — retry with backoff, then the
    strategy degradation ladder, then bisection + quarantine), outputs
    are NaN/inf-validated before being handed back
    (``validate_output``), and per-request outcomes accumulate in
    ``request_status`` (``ok | retried | degraded | quarantined``) and
    ``error_reports`` (quarantined requests only). A quarantine costs
    exactly the poisoned request: everyone else in its batch completes.

    ``batch_hook(index, requests)`` runs inside the timed region — the
    legacy fault-injection seam kept for straggler tests; structured
    injection goes through ``faults`` (a
    :class:`repro.ft.faults.FaultInjector`), whose batch faults fire
    inside the same timed try block.
    """

    def __init__(
        self,
        *,
        strategy: str = "swc",
        block=None,
        accuracy: int = 2,
        alpha: float = 1.0,
        max_batch: int = 8,
        straggler: StragglerMonitor | None = None,
        batch_hook: Callable[[int, list], None] | None = None,
        retry: RetryPolicy | None = None,
        faults: FaultInjector | None = None,
        validate_output: bool = True,
    ):
        self.strategy = strategy
        self.block = block
        self.accuracy = accuracy
        self.alpha = alpha
        self.max_batch = max_batch
        self.straggler = straggler or StragglerMonitor()
        self.batch_hook = batch_hook
        self.retry = retry or RetryPolicy()
        self.faults = faults
        self.validate_output = validate_output
        self.reports: list[BatchReport] = []
        self.op_builds = 0
        self.request_status: dict[int, str] = {}
        self.error_reports: dict[int, dict] = {}
        self._ops: dict[tuple, FusedStencilOp] = {}
        self._warmed: set = set()
        # Current degradation rung per bucket (absent = configured
        # strategy). Written when a batch only completes after
        # degrading; cleared when a quarantine re-attributes the
        # failure to a poison request rather than the strategy.
        self._strategy_for: dict[tuple, str] = {}

    def _op_for(self, key: BucketKey, strategy: str) -> FusedStencilOp:
        shape, dtype, _ = key
        op_key = (shape, dtype, strategy)  # n_steps lives in integrate
        if op_key not in self._ops:
            problem = DiffusionProblem(
                shape, accuracy=self.accuracy, alpha=self.alpha
            )
            # hwc ignores the block (XLA manages the cache); don't drag
            # the bottom rung through a pointless tuning resolution.
            block = None if strategy == "hwc" else self.block
            self._ops[op_key] = problem.step_op(strategy, block)
            self.op_builds += 1
        return self._ops[op_key]

    def serve(self, queue: RequestQueue) -> dict[int, np.ndarray]:
        """Drain the queue; returns {req_id: final (n_f, *spatial)}
        for every request that completed (quarantined requests are
        reported in ``error_reports`` instead)."""
        results: dict[int, np.ndarray] = {}
        while queue:
            key, reqs = queue.next_bucket(
                lambda r: r.bucket_key, self.max_batch
            )
            self._serve_batch(key, reqs, results)
        return results

    # -- failure-domain core ------------------------------------------------

    def _serve_batch(
        self, key: BucketKey, reqs: list, results: dict
    ) -> None:
        """Serve one plan-compatible batch through the retry →
        degrade → bisect → quarantine ladder."""
        bucket = (key[0], key[1])
        strategy = self._strategy_for.get(bucket, self.strategy)
        retries = 0
        while True:
            try:
                out, dt = self._run_batch(key, reqs, strategy)
                break
            except (KeyboardInterrupt, SystemExit):
                raise
            except Exception as e:
                last_err = e
                log.warning(
                    "batch of %d over %s failed under %s: %s: %s",
                    len(reqs), bucket, strategy, type(e).__name__, e,
                )
                if retries < self.retry.max_retries:
                    retries += 1
                    pause = self.retry.backoff(retries)
                    if pause:
                        time.sleep(pause)
                    continue
                nxt = self._next_viable(strategy, key)
                if nxt is not None:
                    log.warning(
                        "degrading bucket %s: %s -> %s", bucket,
                        strategy, nxt,
                    )
                    strategy = nxt
                    self._strategy_for[bucket] = nxt
                    retries = 0
                    continue
                if len(reqs) > 1:
                    # Ladder exhausted: a member is poisoning the
                    # batch. Bisect to isolate it — healthy halves
                    # complete, the poison ends up in a singleton.
                    mid = len(reqs) // 2
                    log.warning(
                        "bisecting failing batch of %d over %s",
                        len(reqs), bucket,
                    )
                    self._serve_batch(key, reqs[:mid], results)
                    self._serve_batch(key, reqs[mid:], results)
                    return
                self._quarantine(key, reqs[0], last_err, strategy)
                # The fault was request-attributable: later batches of
                # this bucket restart at the configured strategy.
                self._strategy_for.pop(bucket, None)
                self.reports.append(BatchReport(
                    index=len(self.reports), key=key, batch=1,
                    seconds=0.0, straggler=False, strategy=strategy,
                    retries=retries,
                    statuses={reqs[0].req_id: "quarantined"},
                ))
                return

        # Success: validate member outputs, then hand results back.
        base = "ok"
        if strategy != self.strategy:
            base = "degraded"
        elif retries:
            base = "retried"
        bad = (
            self._nonfinite_members(out) if self.validate_output else ()
        )
        statuses: dict[int, str] = {}
        for member, req in enumerate(reqs):
            if member in bad:
                self._quarantine(
                    key, req,
                    ValueError("non-finite output (NaN/inf)"),
                    strategy,
                )
                statuses[req.req_id] = "quarantined"
            else:
                results[req.req_id] = np.asarray(out[member])
                statuses[req.req_id] = base
                self._mark(req.req_id, base)
        index = len(self.reports)
        flagged = self.straggler.record(index, dt)
        self.reports.append(BatchReport(
            index=index, key=key, batch=len(reqs), seconds=dt,
            straggler=flagged, strategy=strategy, retries=retries,
            statuses=statuses,
        ))

    def _run_batch(self, key: BucketKey, reqs: list, strategy: str):
        """One batched integrate under ``strategy``: warm the tuning
        cache if needed, fire injected batch faults inside the timed
        region, and return ``(output array, seconds)``."""
        op = self._op_for(key, strategy)
        fb = jnp.stack([r.f0 for r in reqs])  # (B, n_f, *spatial)
        warm_key = (key[0], key[1], len(reqs), strategy)
        if (
            (self.block == "auto" or strategy == "auto")
            and strategy != "hwc"
            and warm_key not in self._warmed
        ):
            # Eager warm call OUTSIDE lax control flow: a cache miss
            # runs the rank-then-measure search and persists the
            # measured :b{B} record; under integrate's scan tracing
            # it could only have written a cost-model record.
            jax.block_until_ready(op(fb))
            self._warmed.add(warm_key)
        index = len(self.reports)
        req_ids = [r.req_id for r in reqs]
        t0 = time.perf_counter()
        if self.batch_hook is not None:
            self.batch_hook(index, reqs)
        if self.faults is not None:
            self.faults.on_batch(index, req_ids, strategy)
        out = jax.block_until_ready(integrate(op, fb, key[2]))
        dt = time.perf_counter() - t0
        out = np.asarray(out)
        if self.faults is not None:
            out = self.faults.corrupt_output(req_ids, out)
        return out, dt

    def _next_viable(self, strategy: str, key: BucketKey) -> str | None:
        """First rung below ``strategy`` whose op actually builds for
        this bucket (e.g. ``swc_stream`` needs rank ≥ 2, ``tc`` needs
        f32/bf16 — invalid rungs are skipped, not crashed into)."""
        nxt = self.retry.degrade(strategy)
        while nxt is not None:
            try:
                self._op_for(key, nxt)
                return nxt
            except (KeyboardInterrupt, SystemExit):
                raise
            except Exception as e:
                log.warning(
                    "ladder rung %s not viable for %s: %s",
                    nxt, key[0], e,
                )
                nxt = self.retry.degrade(nxt)
        return None

    @staticmethod
    def _nonfinite_members(out: np.ndarray) -> set[int]:
        """Member indices of a (B, ...) stack carrying NaN/inf — the
        output-validation gate before results are handed back."""
        bad: set[int] = set()
        for member in range(out.shape[0]):
            arr = out[member]
            try:
                finite = bool(np.isfinite(arr).all())
            except TypeError:  # exotic float dtypes (e.g. bfloat16)
                finite = bool(np.isfinite(arr.astype(np.float32)).all())
            if not finite:
                bad.add(member)
        return bad

    def _mark(self, req_id: int, status: str) -> None:
        cur = self.request_status.get(req_id, "ok")
        if _SEVERITY[status] >= _SEVERITY[cur]:
            self.request_status[req_id] = status

    def _quarantine(
        self, key: BucketKey, req, err: BaseException, strategy: str
    ) -> None:
        """Fail exactly one request: record its error report and mark
        it quarantined. Its batchmates are unaffected."""
        self._mark(req.req_id, "quarantined")
        self.error_reports[req.req_id] = {
            "req_id": req.req_id,
            "bucket": "x".join(map(str, key[0]))
            + f"/{key[1]}/n{key[2]}",
            "strategy": strategy,
            "error": f"{type(err).__name__}: {err}",
        }
        log.error(
            "quarantined request %d (%s under %s): %s: %s",
            req.req_id, key[0], strategy, type(err).__name__, err,
        )


# ---------------------------------------------------------------------------
# CLI: smoke queue, parity check, chaos plan, BENCH_serve*.json artifact.
# ---------------------------------------------------------------------------


def demo_queue(
    shapes, n_steps: int, requests: int, seed: int = 0
) -> RequestQueue:
    """Mixed-shape request stream: round-robin over ``shapes`` so every
    bucket interleaves with the others in FIFO order."""
    rng = np.random.default_rng(seed)
    queue = RequestQueue()
    for rid in range(requests):
        shape = shapes[rid % len(shapes)]
        f0 = jnp.asarray(
            rng.uniform(-1e-5, 1e-5, size=(1,) + shape), jnp.float32
        )
        queue.push(SimRequest(rid, f0, n_steps))
    return queue


def _vmap_reference(server: SimServer, reqs: list[SimRequest]):
    """The oracle the batched path must match: vmap of the SINGLE-member
    op over the stacked ensemble (B independent lowerings' numerics,
    one launch per member)."""
    key = reqs[0].bucket_key
    problem = DiffusionProblem(
        key[0], accuracy=server.accuracy, alpha=server.alpha
    )
    op = problem.step_op("hwc")
    fb = jnp.stack([r.f0 for r in reqs])
    return jax.vmap(lambda f: integrate(op, f, key[2]))(fb)


def _git_sha() -> str:
    try:
        return subprocess.run(
            ["git", "rev-parse", "HEAD"], capture_output=True, text=True,
            check=True, timeout=10,
        ).stdout.strip()
    except (OSError, subprocess.SubprocessError):
        return "unknown"


def _write_bench(path: str, rows: list[dict], smoke: bool) -> None:
    """BENCH_*.json with the benchmarks/run.py row schema (name,
    us_per_call, derived + device/git_sha stamps) so the CI artifact
    pipeline treats serving throughput like any other perf row."""
    from repro.tuning.cache import current_backend

    device, sha = current_backend(), _git_sha()
    payload = {
        "schema": 1,
        "device": device,
        "git_sha": sha,
        "smoke": smoke,
        "rows": [
            {**row, "device": device, "git_sha": sha} for row in rows
        ],
    }
    with open(path, "w") as fh:
        json.dump(payload, fh, indent=1, sort_keys=True)
        fh.write("\n")
    print(f"wrote {len(rows)} row(s) to {path}")


def _assert_parity(server, by_id, results) -> float:
    """Batched-vs-vmap parity over every COMPLETED request (f32
    workload, so bound the difference relative to the field scale);
    quarantined requests are excluded — they have no result to check.
    Returns the max abs error."""
    max_err = 0.0
    for key in {r.bucket_key for r in by_id.values()}:
        reqs = [
            r for r in by_id.values()
            if r.bucket_key == key and r.req_id in results
        ]
        if not reqs:
            continue
        expect = np.asarray(_vmap_reference(server, reqs))
        got = np.stack([results[r.req_id] for r in reqs])
        scale = float(np.abs(expect).max())
        err = float(np.abs(got - expect).max())
        max_err = max(max_err, err)
        assert err <= 1e-5 * max(scale, 1e-30), (
            f"batched-vs-vmap parity failed for bucket {key}: "
            f"max abs err {err:.2e} at field scale {scale:.2e}"
        )
    return max_err


def _assert_chaos_contract(server, injector, plan, by_id, results, cache):
    """The chaos acceptance contract: every healthy request completed,
    exactly the poison request is quarantined, the failing tuning
    candidate did not abort strategy="auto", and the corrupted
    cache.json was quarantined aside and rebuilt."""
    quarantined = set(server.error_reports)
    poison = plan["poison"]
    assert quarantined == {poison}, (
        f"expected exactly the poison request {poison} quarantined, "
        f"got {quarantined}"
    )
    assert server.request_status[poison] == "quarantined"
    assert poison not in results
    healthy = set(by_id) - {poison}
    assert set(results) == healthy, (
        f"missing healthy results: {healthy - set(results)}"
    )
    # The transient compile failure was retried to completion.
    assert plan["transient"] in results
    assert server.request_status[plan["transient"]] == "retried", (
        plan, server.request_status,
    )
    # A tuning candidate really failed — and auto still resolved
    # (ops were built and every healthy request produced a result).
    assert any(
        site == "tune.candidate" for site, _, _ in injector.fired
    ), f"tune.candidate fault never fired: {injector.fired}"
    # The garbled cache.json was quarantined aside and rebuilt.
    corpses = list(
        cache.file.parent.glob(cache.file.name + ".corrupt*")
    )
    assert corpses, "corrupt cache.json was not quarantined aside"
    from repro.tuning.cache import TuningCache

    assert cache.file.exists() and TuningCache().items(), (
        "tuning cache was not rebuilt after quarantine"
    )
    print(
        f"chaos contract OK: {len(injector.fired)} fault(s) fired, "
        f"request {poison} quarantined, request {plan['transient']} "
        f"retried, cache quarantined to {corpses[0].name}"
    )


def main() -> None:
    ap = argparse.ArgumentParser(
        description="Batched stencil-simulation serving loop"
    )
    ap.add_argument("--requests", type=int, default=12)
    ap.add_argument("--steps", type=int, default=8,
                    help="diffusion steps per request")
    ap.add_argument("--max-batch", type=int, default=4,
                    help="largest ensemble batch per kernel launch")
    ap.add_argument("--strategy", default="swc",
                    choices=("hwc", "swc", "swc_stream", "tc", "auto"))
    ap.add_argument("--auto-tune", action="store_true",
                    help="resolve the batched kernel block from the "
                         "persistent tuning cache (block='auto': the "
                         "first batch of each bucket tunes and persists "
                         "a :b{B}-keyed record, later batches replay it)")
    ap.add_argument("--smoke", action="store_true",
                    help="small mixed-shape queue + batched-vs-vmap "
                         "parity assertion (CI serve-smoke job)")
    ap.add_argument("--chaos", action="store_true",
                    help="inject the seeded deterministic fault plan "
                         "(repro.ft.faults.chaos_specs) and assert the "
                         "recovery contract; forces strategy='auto' + "
                         "block='auto' so the failing-tuning-candidate "
                         "fault has a search to disrupt")
    ap.add_argument("--fault-seed", type=int, default=0,
                    help="seed for the --chaos fault plan (same seed, "
                         "same faults, every run)")
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="write throughput rows as BENCH JSON (default "
                         "BENCH_serve.json under --smoke, "
                         "BENCH_serve_chaos.json under --chaos)")
    args = ap.parse_args()
    logging.basicConfig(level=logging.WARNING)

    shapes = [(16, 32), (12, 24)] if args.smoke else [(32, 64), (24, 48)]
    strategy = args.strategy
    block = "auto" if (args.auto_tune or strategy == "auto") else None
    if args.chaos:
        strategy, block = "auto", "auto"
    queue = demo_queue(shapes, args.steps, args.requests)
    by_id = {r.req_id: r for r in queue.snapshot()}

    injector = plan = cache = None
    if args.chaos:
        import os
        import tempfile

        from repro.tuning.cache import ENV_VAR, TuningCache

        # Chaos garbles cache.json on purpose; don't do that to the
        # developer's real cache — redirect to a scratch dir unless the
        # caller pinned one (CI does).
        if ENV_VAR not in os.environ:
            os.environ[ENV_VAR] = tempfile.mkdtemp(
                prefix="repro-chaos-cache-"
            )
            print(
                f"chaos: tuning cache redirected to {os.environ[ENV_VAR]}"
            )
        specs, plan = ftfaults.chaos_specs(
            args.fault_seed, list(by_id)
        )
        injector = FaultInjector(specs, slow_s=0.3)
        # Crashed-writer stand-in: garble cache.json BEFORE serving, so
        # the first tuning read must quarantine and rebuild it.
        cache = TuningCache()
        injector.corrupt_cache(cache.file)
        print(f"chaos plan (seed {args.fault_seed}): {plan}")

    server = SimServer(
        strategy=strategy, block=block, max_batch=args.max_batch,
        faults=injector,
    )

    t0 = time.time()
    if injector is not None:
        with ftfaults.active(injector):
            results = server.serve(queue)
    else:
        results = server.serve(queue)
    wall = time.time() - t0

    quarantined = set(server.error_reports)
    assert set(results) == set(by_id) - quarantined
    if not args.chaos:
        assert not quarantined, server.error_reports

    members = sum(rep.batch for rep in server.reports)
    stragglers = sum(rep.straggler for rep in server.reports)
    status_counts = collections.Counter(
        server.request_status.get(rid, "ok") for rid in by_id
    )
    print(
        f"served {len(results)}/{args.requests} request(s) in "
        f"{len(server.reports)} batch(es) / {server.op_builds} op "
        f"build(s), {wall:.2f}s "
        f"({members * args.steps / wall:.1f} member-steps/s, "
        f"{stragglers} straggler(s), "
        + ", ".join(f"{k}={v}" for k, v in sorted(status_counts.items()))
        + ")"
    )

    rows = []
    for rep in server.reports:
        shape = "x".join(map(str, rep.key[0]))
        counts = collections.Counter(rep.statuses.values())
        status_s = ",".join(
            f"{k}:{v}" for k, v in sorted(counts.items())
        )
        rows.append({
            "name": f"serve/{shape}/b{rep.batch}",
            "us_per_call": rep.seconds * 1e6,
            "derived": (
                f"n_steps={rep.key[2]};batch={rep.batch};"
                f"strategy={rep.strategy};retries={rep.retries};"
                f"straggler={int(rep.straggler)};statuses={status_s}"
            ),
        })
    for rid in sorted(server.error_reports):
        report = server.error_reports[rid]
        rows.append({
            "name": f"serve/quarantine/r{rid}",
            "us_per_call": 0.0,
            "derived": (
                f"status=quarantined;bucket={report['bucket']};"
                f"strategy={report['strategy']};error={report['error']}"
            ),
        })

    if args.smoke or args.chaos:
        max_err = _assert_parity(server, by_id, results)
        rows.append({
            "name": "serve/parity",
            "us_per_call": 0.0,
            "derived": f"max_abs_err={max_err:.3e};status=ok",
        })
        print(f"batched-vs-vmap parity OK (max abs err {max_err:.2e})")

    if args.chaos:
        _assert_chaos_contract(
            server, injector, plan, by_id, results, cache
        )
        rows.append({
            "name": "serve/chaos",
            "us_per_call": 0.0,
            "derived": (
                f"fault_seed={args.fault_seed};"
                f"faults_fired={len(injector.fired)};"
                f"poison={plan['poison']};transient={plan['transient']};"
                f"quarantined={len(quarantined)};status=ok"
            ),
        })

    json_path = args.json or (
        "BENCH_serve_chaos.json" if args.chaos
        else ("BENCH_serve.json" if args.smoke else None)
    )
    if json_path:
        _write_bench(json_path, rows, args.smoke or args.chaos)
    print("serve_sim OK")


if __name__ == "__main__":
    main()
