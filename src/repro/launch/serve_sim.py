"""Ensemble simulation serving: the stencil-workload front door.

``repro.launch.serve`` serves language-model decode; THIS module serves
stencil simulations — thousands of concurrent scenarios (parameter
sweeps, Monte-Carlo ensembles, per-user simulations) funneled through
the batched fused-stencil engine:

* ``SimRequest`` / ``RequestQueue`` — FIFO request intake with
  shape-bucketed draining: requests sharing (spatial shape, dtype,
  n_steps) form one plan-compatible group, and the oldest request's
  bucket is served first (head-of-line FIFO, no starvation).
* ``SimServer`` — one batched ``FusedStencilOp`` per bucket, stacked
  to a (B, n_f, *spatial) operand so one kernel walks all B members
  per block (member-major grid, shared halo — the batch axis of
  ``StencilPlan``). Ops are cached per bucket and ``block="auto"``
  resolves through the persistent tuning cache, so the first batch of
  a bucket warms the ``:b{B}``-keyed record and every later batch
  replays it.
* ``StragglerMonitor`` hooks (``repro.ft.supervisor``) — per-batch
  wall times feed the trailing-median monitor; a slow batch is flagged
  (and counted in the serve report) exactly like a slow training step.

Run:  PYTHONPATH=src python -m repro.launch.serve_sim --smoke

``--smoke`` serves a small mixed-shape queue, asserts batched-vs-vmap
parity per request, and writes a ``BENCH_serve.json`` throughput
artifact (CI serve-smoke job).
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import subprocess
import time
from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.fusion import FusedStencilOp, integrate
from repro.ft.supervisor import StragglerMonitor
from repro.physics.diffusion import DiffusionProblem

# (spatial shape, dtype string, n_steps): requests sharing a key lower
# through ONE batched plan (same domain/dtype) for the SAME step count.
BucketKey = tuple[tuple[int, ...], str, int]


@dataclasses.dataclass(frozen=True)
class SimRequest:
    """One ensemble member: advance ``f0`` (n_f, *spatial) by
    ``n_steps`` diffusion steps."""

    req_id: int
    f0: jnp.ndarray
    n_steps: int

    @property
    def bucket_key(self) -> BucketKey:
        return (
            tuple(int(n) for n in self.f0.shape[1:]),
            str(self.f0.dtype),
            int(self.n_steps),
        )


class RequestQueue:
    """FIFO request queue with bucket-aware batch draining.

    Generic over the request type: the LM example
    (``examples/serve_batched.py``) pops one request at a time into
    freed decode slots; ensemble serving drains plan-compatible batches
    with :meth:`next_bucket`.
    """

    def __init__(self, items=()):
        self._items = list(items)

    def push(self, item) -> None:
        self._items.append(item)

    def pop(self):
        """Oldest request, or None when empty (LM slot refill)."""
        return self._items.pop(0) if self._items else None

    def __len__(self) -> int:
        return len(self._items)

    def __bool__(self) -> bool:
        return bool(self._items)

    def next_bucket(self, bucket_of: Callable, max_batch: int):
        """Drain up to ``max_batch`` requests sharing the OLDEST
        request's bucket key (head-of-line FIFO: the oldest waiting
        request is always served in the next batch). Returns
        ``(key, requests)`` or None when empty."""
        if not self._items:
            return None
        key = bucket_of(self._items[0])
        taken, kept = [], []
        for item in self._items:
            if len(taken) < max_batch and bucket_of(item) == key:
                taken.append(item)
            else:
                kept.append(item)
        self._items = kept
        return key, taken


@dataclasses.dataclass
class BatchReport:
    """One executed batch: bucket, members, and the timing the
    straggler monitor saw."""

    index: int
    key: BucketKey
    batch: int
    seconds: float
    straggler: bool


class SimServer:
    """Shape-bucketed batch server over the batched fused engine.

    One ``FusedStencilOp`` per bucket (built lazily, cached for the
    server's lifetime — ``op_builds`` counts cache misses); requests
    are stacked member-major to (B, n_f, *spatial) and integrated in
    one batched call per bucket. ``batch_hook(index, requests)`` runs
    inside the timed region — the fault-injection seam for straggler
    tests, mirroring ``failure_at`` in ``ft.supervisor.Supervisor``.
    """

    def __init__(
        self,
        *,
        strategy: str = "swc",
        block=None,
        accuracy: int = 2,
        alpha: float = 1.0,
        max_batch: int = 8,
        straggler: StragglerMonitor | None = None,
        batch_hook: Callable[[int, list], None] | None = None,
    ):
        self.strategy = strategy
        self.block = block
        self.accuracy = accuracy
        self.alpha = alpha
        self.max_batch = max_batch
        self.straggler = straggler or StragglerMonitor()
        self.batch_hook = batch_hook
        self.reports: list[BatchReport] = []
        self.op_builds = 0
        self._ops: dict[tuple[tuple[int, ...], str], FusedStencilOp] = {}
        self._warmed: set = set()

    def _op_for(self, key: BucketKey) -> FusedStencilOp:
        shape, dtype, _ = key
        op_key = (shape, dtype)  # n_steps lives in integrate, not the plan
        if op_key not in self._ops:
            problem = DiffusionProblem(
                shape, accuracy=self.accuracy, alpha=self.alpha
            )
            self._ops[op_key] = problem.step_op(self.strategy, self.block)
            self.op_builds += 1
        return self._ops[op_key]

    def serve(self, queue: RequestQueue) -> dict[int, np.ndarray]:
        """Drain the queue; returns {req_id: final (n_f, *spatial)}."""
        results: dict[int, np.ndarray] = {}
        while queue:
            key, reqs = queue.next_bucket(
                lambda r: r.bucket_key, self.max_batch
            )
            op = self._op_for(key)
            fb = jnp.stack([r.f0 for r in reqs])  # (B, n_f, *spatial)
            warm_key = (key[0], key[1], len(reqs))
            if (
                (self.block == "auto" or self.strategy == "auto")
                and warm_key not in self._warmed
            ):
                # Eager warm call OUTSIDE lax control flow: a cache miss
                # runs the rank-then-measure search and persists the
                # measured :b{B} record; under integrate's scan tracing
                # it could only have written a cost-model record.
                jax.block_until_ready(op(fb))
                self._warmed.add(warm_key)
            index = len(self.reports)
            t0 = time.perf_counter()
            if self.batch_hook is not None:
                self.batch_hook(index, reqs)
            out = jax.block_until_ready(integrate(op, fb, key[2]))
            dt = time.perf_counter() - t0
            flagged = self.straggler.record(index, dt)
            self.reports.append(
                BatchReport(index, key, len(reqs), dt, flagged)
            )
            for member, req in enumerate(reqs):
                results[req.req_id] = np.asarray(out[member])
        return results


# ---------------------------------------------------------------------------
# CLI: smoke queue, parity check, BENCH_serve.json artifact.
# ---------------------------------------------------------------------------


def demo_queue(
    shapes, n_steps: int, requests: int, seed: int = 0
) -> RequestQueue:
    """Mixed-shape request stream: round-robin over ``shapes`` so every
    bucket interleaves with the others in FIFO order."""
    rng = np.random.default_rng(seed)
    queue = RequestQueue()
    for rid in range(requests):
        shape = shapes[rid % len(shapes)]
        f0 = jnp.asarray(
            rng.uniform(-1e-5, 1e-5, size=(1,) + shape), jnp.float32
        )
        queue.push(SimRequest(rid, f0, n_steps))
    return queue


def _vmap_reference(server: SimServer, reqs: list[SimRequest]):
    """The oracle the batched path must match: vmap of the SINGLE-member
    op over the stacked ensemble (B independent lowerings' numerics,
    one launch per member)."""
    key = reqs[0].bucket_key
    problem = DiffusionProblem(
        key[0], accuracy=server.accuracy, alpha=server.alpha
    )
    op = problem.step_op("hwc")
    fb = jnp.stack([r.f0 for r in reqs])
    return jax.vmap(lambda f: integrate(op, f, key[2]))(fb)


def _git_sha() -> str:
    try:
        return subprocess.run(
            ["git", "rev-parse", "HEAD"], capture_output=True, text=True,
            check=True, timeout=10,
        ).stdout.strip()
    except Exception:
        return "unknown"


def _write_bench(path: str, rows: list[dict], smoke: bool) -> None:
    """BENCH_*.json with the benchmarks/run.py row schema (name,
    us_per_call, derived + device/git_sha stamps) so the CI artifact
    pipeline treats serving throughput like any other perf row."""
    from repro.tuning.cache import current_backend

    device, sha = current_backend(), _git_sha()
    payload = {
        "schema": 1,
        "device": device,
        "git_sha": sha,
        "smoke": smoke,
        "rows": [
            {**row, "device": device, "git_sha": sha} for row in rows
        ],
    }
    with open(path, "w") as fh:
        json.dump(payload, fh, indent=1, sort_keys=True)
        fh.write("\n")
    print(f"wrote {len(rows)} row(s) to {path}")


def main() -> None:
    ap = argparse.ArgumentParser(
        description="Batched stencil-simulation serving loop"
    )
    ap.add_argument("--requests", type=int, default=12)
    ap.add_argument("--steps", type=int, default=8,
                    help="diffusion steps per request")
    ap.add_argument("--max-batch", type=int, default=4,
                    help="largest ensemble batch per kernel launch")
    ap.add_argument("--strategy", default="swc",
                    choices=("hwc", "swc", "swc_stream", "auto"))
    ap.add_argument("--auto-tune", action="store_true",
                    help="resolve the batched kernel block from the "
                         "persistent tuning cache (block='auto': the "
                         "first batch of each bucket tunes and persists "
                         "a :b{B}-keyed record, later batches replay it)")
    ap.add_argument("--smoke", action="store_true",
                    help="small mixed-shape queue + batched-vs-vmap "
                         "parity assertion (CI serve-smoke job)")
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="write throughput rows as BENCH JSON "
                         "(default BENCH_serve.json under --smoke)")
    args = ap.parse_args()

    shapes = [(16, 32), (12, 24)] if args.smoke else [(32, 64), (24, 48)]
    block = "auto" if (args.auto_tune or args.strategy == "auto") else None
    server = SimServer(
        strategy=args.strategy, block=block, max_batch=args.max_batch
    )
    queue = demo_queue(shapes, args.steps, args.requests)
    by_id = {r.req_id: r for r in queue._items}

    t0 = time.time()
    results = server.serve(queue)
    wall = time.time() - t0
    assert len(results) == args.requests

    members = sum(rep.batch for rep in server.reports)
    stragglers = sum(rep.straggler for rep in server.reports)
    print(
        f"served {args.requests} request(s) in {len(server.reports)} "
        f"batch(es) / {server.op_builds} op build(s), {wall:.2f}s "
        f"({members * args.steps / wall:.1f} member-steps/s, "
        f"{stragglers} straggler(s))"
    )

    rows = []
    for rep in server.reports:
        shape = "x".join(map(str, rep.key[0]))
        rows.append({
            "name": f"serve/{shape}/b{rep.batch}",
            "us_per_call": rep.seconds * 1e6,
            "derived": (
                f"n_steps={rep.key[2]};batch={rep.batch};"
                f"strategy={args.strategy};straggler={int(rep.straggler)}"
            ),
        })

    if args.smoke:
        # Parity: the batched lowering must match vmap of the
        # single-member path on every request (f32 workload, so bound
        # the difference relative to the field scale).
        max_err = 0.0
        for key in {r.bucket_key for r in by_id.values()}:
            reqs = [r for r in by_id.values() if r.bucket_key == key]
            expect = np.asarray(_vmap_reference(server, reqs))
            got = np.stack([results[r.req_id] for r in reqs])
            scale = float(np.abs(expect).max())
            err = float(np.abs(got - expect).max())
            max_err = max(max_err, err)
            assert err <= 1e-5 * max(scale, 1e-30), (
                f"batched-vs-vmap parity failed for bucket {key}: "
                f"max abs err {err:.2e} at field scale {scale:.2e}"
            )
        rows.append({
            "name": "serve/parity",
            "us_per_call": 0.0,
            "derived": f"max_abs_err={max_err:.3e};status=ok",
        })
        print(f"batched-vs-vmap parity OK (max abs err {max_err:.2e})")

    json_path = args.json or ("BENCH_serve.json" if args.smoke else None)
    if json_path:
        _write_bench(json_path, rows, args.smoke)
    print("serve_sim OK")


if __name__ == "__main__":
    main()
