"""Jitted step builders: train_step / prefill_step / serve_step with full
sharding specifications, donation, and optimizer integration.

These are THE functions the dry-run lowers and the examples execute —
one code path for both (assignment requirement e).
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.registry import get_model
from repro.distrib import sharding as shlib
from repro.models import layers as L
from repro.models.config import ModelConfig
from repro.optim import AdamWConfig, adamw_update


def batch_shardings(batch_abs: dict, mesh: Mesh, profile: str = "tp") -> dict:
    """Batch dims: leading batch over the DP axes ((pod, data), or all
    axes under the pure-DP profile); positions (3, b, s) carry batch on
    dim 1."""
    dp = ("pod", "data", "model") if profile == "dp" else ("pod", "data")
    out = {}
    for k, v in batch_abs.items():
        if k == "positions":
            wanted = (None, dp, None)
        else:
            wanted = (dp,) + (None,) * (len(v.shape) - 1)
        out[k] = NamedSharding(mesh, shlib.safe_spec(v.shape, wanted, mesh))
    return out


def opt_shardings(opt_abs, param_shardings) -> Any:
    """Adam moments mirror parameter shardings; step is replicated."""
    mesh = jax.tree_util.tree_leaves(param_shardings)[0].mesh
    return type(opt_abs)(
        step=NamedSharding(mesh, P()),
        mu=param_shardings,
        nu=jax.tree.map(lambda s: s, param_shardings),
    )


def cache_shardings(cache_abs, mesh: Mesh):
    """Decode-cache shardings per cache family (DESIGN.md §3)."""
    from repro.models.encdec import EncDecCache
    from repro.models.hybrid import HybridCache
    from repro.models.ssm import SSMCache

    dp = ("pod", "data")

    def ns(shape, wanted):
        return NamedSharding(mesh, shlib.safe_spec(shape, wanted, mesh))

    if isinstance(cache_abs, L.KVCache):
        # Prefer KV-head TP; when kv-heads don't divide the model axis
        # (qwen14b: 8 kv / 16-way), shard the SEQUENCE dim instead —
        # sequence-parallel decode attention: each chip scores 1/M of the
        # context and the softmax merge is a per-token psum (bytes ~
        # b·h·dh, not the multi-GiB cache gather GSPMD otherwise emits;
        # measured in EXPERIMENTS.md §Perf).
        kv = [
            (None, dp, None, "model", None),
            (None, dp, "model", None, None),
        ]
        return L.KVCache(
            k=ns(cache_abs.k.shape, kv),
            v=ns(cache_abs.v.shape, kv),
            length=NamedSharding(mesh, P()),
        )
    if isinstance(cache_abs, SSMCache):
        return SSMCache(
            conv=ns(cache_abs.conv.shape, (None, dp, None, "model")),
            state=ns(cache_abs.state.shape, (None, dp, "model", None, None)),
            length=NamedSharding(mesh, P()),
        )
    if isinstance(cache_abs, HybridCache):
        kv = (None, dp, None, "model", None)
        return HybridCache(
            lru_h=ns(cache_abs.lru_h.shape, (None, dp, "model")),
            conv=ns(cache_abs.conv.shape, (None, dp, None, "model")),
            k=ns(cache_abs.k.shape, kv),
            v=ns(cache_abs.v.shape, kv),
            length=NamedSharding(mesh, P()),
        )
    if isinstance(cache_abs, EncDecCache):
        kv = (None, dp, None, "model", None)
        return EncDecCache(
            k=ns(cache_abs.k.shape, kv),
            v=ns(cache_abs.v.shape, kv),
            xk=ns(cache_abs.xk.shape, kv),
            xv=ns(cache_abs.xv.shape, kv),
            length=NamedSharding(mesh, P()),
        )
    raise TypeError(type(cache_abs))


# --- step functions -----------------------------------------------------------


def make_train_step(cfg: ModelConfig, opt_cfg: AdamWConfig):
    api = get_model(cfg)

    def train_step(params, opt_state, batch):
        (loss, metrics), grads = jax.value_and_grad(
            api.lm_loss, has_aux=True
        )(params, cfg, batch)
        params, opt_state, opt_metrics = adamw_update(
            opt_cfg, grads, opt_state, params
        )
        metrics = dict(metrics, loss=loss, **opt_metrics)
        return params, opt_state, metrics

    return train_step


def make_prefill_step(cfg: ModelConfig):
    api = get_model(cfg)

    def prefill_step(params, batch):
        logits, _ = api.forward(
            params, cfg, batch["tokens"],
            positions=batch.get("positions"),
            patch_embeds=batch.get("patch_embeds"),
            **({"frames": batch["frames"]} if "frames" in batch else {}),
        )
        return logits[:, -1].astype(jnp.float32)

    return prefill_step


def make_serve_step(cfg: ModelConfig):
    api = get_model(cfg)

    def serve_step(params, cache, batch):
        logits, cache = api.decode_step(params, cfg, batch["tokens"], cache)
        return logits.astype(jnp.float32), cache

    return serve_step


# --- jit assembly ---------------------------------------------------------------


def jit_train_step(
    cfg: ModelConfig,
    mesh: Mesh,
    batch_abs: dict,
    *,
    fsdp: bool = False,
    opt_cfg: AdamWConfig | None = None,
    donate: bool = True,
    profile: str = "tp",
):
    """Returns (jitted fn, (param_sh, opt_sh, batch_sh)) — callers lower
    or execute under ``shlib.rules_context(mesh,
    shlib.profile_act_rules(profile))``."""
    from repro.launch.specs import abstract_opt_state, abstract_params

    opt_cfg = opt_cfg or AdamWConfig()
    params_abs = abstract_params(cfg)
    p_sh = shlib.param_shardings(params_abs, mesh, fsdp=fsdp,
                                 profile=profile)
    o_sh = opt_shardings(abstract_opt_state(params_abs), p_sh)
    b_sh = batch_shardings(batch_abs, mesh, profile)
    fn = make_train_step(cfg, opt_cfg)
    jitted = jax.jit(
        fn,
        in_shardings=(p_sh, o_sh, b_sh),
        out_shardings=(p_sh, o_sh, None),
        donate_argnums=(0, 1) if donate else (),
    )
    return jitted, (p_sh, o_sh, b_sh)


def jit_prefill_step(cfg: ModelConfig, mesh: Mesh, batch_abs: dict):
    from repro.launch.specs import abstract_params

    params_abs = abstract_params(cfg)
    p_sh = shlib.param_shardings(params_abs, mesh)
    b_sh = batch_shardings(batch_abs, mesh)
    fn = make_prefill_step(cfg)
    jitted = jax.jit(fn, in_shardings=(p_sh, b_sh), out_shardings=None)
    return jitted, (p_sh, b_sh)


def jit_serve_step(
    cfg: ModelConfig, mesh: Mesh, batch_abs: dict, cache_abs, *,
    donate_cache: bool = True,
):
    from repro.launch.specs import abstract_params

    params_abs = abstract_params(cfg)
    p_sh = shlib.param_shardings(params_abs, mesh)
    c_sh = cache_shardings(cache_abs, mesh)
    b_sh = batch_shardings(batch_abs, mesh)
    fn = make_serve_step(cfg)
    jitted = jax.jit(
        fn,
        in_shardings=(p_sh, c_sh, b_sh),
        out_shardings=(None, c_sh),
        donate_argnums=(1,) if donate_cache else (),
    )
    return jitted, (p_sh, c_sh, b_sh)
