"""Launch layer: production mesh, input specs, jitted step builders,
multi-pod dry-run, training/serving drivers."""
