"""LM serving driver: batched decode with KV caches.

    PYTHONPATH=src python -m repro.launch.serve --arch mamba2-780m \
        --reduced --batch 4 --steps 32

This front door is decode-only language-model serving. Stencil
simulation workloads (ensemble batching over the fused engine) have
their own entry point: ``python -m repro.launch.serve_sim``.
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.registry import get_config, get_model, reduced_config
from repro.distrib import sharding as shlib
from repro.launch.mesh import make_mesh


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="mamba2-780m")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--steps", type=int, default=32)
    ap.add_argument("--max-len", type=int, default=128)
    ap.add_argument("--temperature", type=float, default=1.0)
    ap.add_argument("--auto-tune", action="store_true",
                    help="resolve Pallas kernel blocks from the persistent "
                         "tuning cache (no effect on the pure-decode loop, "
                         "which uses the recurrent einsum path; applies if "
                         "a Pallas kernel enters the serving graph — "
                         "stencil serving, where tuning IS load-bearing, "
                         "lives in repro.launch.serve_sim --auto-tune)")
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = reduced_config(cfg)
    if args.auto_tune:
        from repro import tuning

        tuning.enable_auto()
        # Decode-only serving never launches the Pallas conv (the
        # recurrent form is a per-token einsum), so there is nothing to
        # pre-measure — the flag just arms "auto" resolution.
        print(f"auto-tune: enabled; cache at {tuning.default_cache_dir()} "
              f"(decode path has no Pallas kernels to warm)")
    if cfg.is_encdec:
        raise SystemExit(
            "repro.launch.serve is decoder-only LM serving; enc-dec "
            "decode is examples/serve_batched.py territory, and stencil "
            "simulations are served by `python -m repro.launch.serve_sim`"
        )
    mesh = make_mesh((1, 1), ("data", "model"))
    shlib.set_rules(mesh)

    api = get_model(cfg)
    key = jax.random.PRNGKey(0)
    params = api.init_params(cfg, key)
    cache = api.init_decode_cache(cfg, args.batch, args.max_len)

    @jax.jit
    def step(params, cache, tokens, key):
        logits, cache = api.decode_step(params, cfg, tokens, cache)
        key, sub = jax.random.split(key)
        nxt = jax.random.categorical(
            sub, logits / args.temperature, axis=-1
        )[:, None]
        return cache, nxt.astype(jnp.int32), key

    tokens = jax.random.randint(key, (args.batch, 1), 0, cfg.vocab)
    outs = [np.asarray(tokens)]
    t0 = time.time()
    for _ in range(args.steps):
        cache, tokens, key = step(params, cache, tokens, key)
        outs.append(np.asarray(tokens))
    dt = time.time() - t0
    gen = np.concatenate(outs, axis=1)
    tps = args.batch * args.steps / dt
    print(f"generated {gen.shape} tokens in {dt:.2f}s ({tps:.1f} tok/s)")
    for row in gen[: min(4, args.batch)]:
        print("  ", " ".join(map(str, row[:24])), "...")


if __name__ == "__main__":
    main()
