"""Production mesh construction.

Axes contract (matches DESIGN.md §3 and the sharding rules):

* single-pod: ``(data=16, model=16)`` — 256 chips (one v5e pod slice);
* multi-pod : ``(pod=2, data=16, model=16)`` — 512 chips across 2 pods;
  the ``pod`` axis is OUTERMOST so cross-pod collectives (gradient
  all-reduce) ride the inter-pod links while ``data``/``model`` stay on
  in-pod ICI.

Functions, not module-level constants: importing this module never
touches jax device state (the dry-run sets
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` BEFORE any jax
import; see dryrun.py).
"""
from __future__ import annotations

import jax


def _mesh_kwargs(n_axes: int) -> dict:
    """``axis_types`` only exists on newer jax (>= 0.6); older versions
    treat every axis as Auto already."""
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is None:
        return {}
    return {"axis_types": (axis_type.Auto,) * n_axes}


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes, **_mesh_kwargs(len(axes)))


def make_mesh(shape, axes):
    """Arbitrary mesh for tests/examples (e.g. (2, 4) on 8 CPU devices)."""
    return jax.make_mesh(
        tuple(shape), tuple(axes), **_mesh_kwargs(len(axes))
    )


def mesh_chip_count(mesh) -> int:
    import numpy as np

    return int(np.prod(mesh.devices.shape))
