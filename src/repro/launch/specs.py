"""input_specs(): ShapeDtypeStruct stand-ins for every model input —
weak-type-correct, shardable, no device allocation (assignment
requirement e.2). Also builds abstract param/optimizer/cache trees via
``jax.eval_shape`` so the dry-run never materializes a single weight.
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from repro.configs.registry import ShapeSpec, get_config, get_model
from repro.models.config import ModelConfig

I32 = jnp.int32


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(tuple(shape), dtype)


def train_input_specs(cfg: ModelConfig, shape: ShapeSpec) -> dict[str, Any]:
    """{tokens, labels, ...} for one global training batch."""
    b, s = shape.global_batch, shape.seq_len
    if cfg.is_encdec:
        # enc-dec contract: source = encoder frames, target ≤ max_target.
        return {
            "tokens": _sds((b, cfg.max_target_len), I32),
            "labels": _sds((b, cfg.max_target_len), I32),
            "frames": _sds((b, cfg.encoder_seq, cfg.d_model), jnp.bfloat16),
        }
    out = {
        "tokens": _sds((b, s), I32),
        "labels": _sds((b, s), I32),
    }
    if cfg.family == "vlm":
        out["patch_embeds"] = _sds(
            (b, cfg.n_patches, cfg.d_model), jnp.bfloat16
        )
        out["positions"] = _sds((3, b, s), I32)
    return out


def prefill_input_specs(cfg: ModelConfig, shape: ShapeSpec) -> dict[str, Any]:
    b, s = shape.global_batch, shape.seq_len
    if cfg.is_encdec:
        return {"frames": _sds((b, cfg.encoder_seq, cfg.d_model), jnp.bfloat16),
                "tokens": _sds((b, cfg.max_target_len), I32)}
    out = {"tokens": _sds((b, s), I32)}
    if cfg.family == "vlm":
        out["patch_embeds"] = _sds(
            (b, cfg.n_patches, cfg.d_model), jnp.bfloat16
        )
        out["positions"] = _sds((3, b, s), I32)
    return out


def decode_input_specs(cfg: ModelConfig, shape: ShapeSpec) -> dict[str, Any]:
    """One new token against a seq_len-deep cache (serve_step)."""
    b = shape.global_batch
    return {"tokens": _sds((b, 1), I32)}


def abstract_params(cfg: ModelConfig) -> Any:
    api = get_model(cfg)
    return jax.eval_shape(
        lambda: api.init_params(cfg, jax.random.PRNGKey(0))
    )


def abstract_opt_state(params_abs: Any) -> Any:
    from repro.optim import adamw_init

    return jax.eval_shape(lambda: adamw_init(params_abs))


def abstract_decode_cache(cfg: ModelConfig, shape: ShapeSpec) -> Any:
    api = get_model(cfg)
    return jax.eval_shape(
        lambda: api.init_decode_cache(cfg, shape.global_batch, shape.seq_len)
    )


def input_specs(arch_id: str, shape: ShapeSpec) -> dict[str, Any]:
    """The assignment's entry point: all model inputs for (arch, shape)."""
    cfg = get_config(arch_id)
    if shape.kind == "train":
        return train_input_specs(cfg, shape)
    if shape.kind == "prefill":
        return prefill_input_specs(cfg, shape)
    return decode_input_specs(cfg, shape)
