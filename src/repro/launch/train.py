"""Training driver: any arch, any mesh, fault-tolerant.

Examples (CPU, reduced config):
    PYTHONPATH=src python -m repro.launch.train --arch qwen2.5-3b \
        --reduced --steps 50 --global-batch 8 --seq-len 64

    # fault injection + restart (the ft path exercised end-to-end):
    PYTHONPATH=src python -m repro.launch.train --arch mamba2-780m \
        --reduced --steps 60 --simulate-failure-at 25

On a real cluster the same driver runs under `jax.distributed.initialize`
with the production mesh (launch/mesh.py) — the only difference is the
--mesh argument.
"""
from __future__ import annotations

import argparse
import time

import jax

from repro.checkpoint import CheckpointManager
from repro.configs.registry import get_config, get_model, reduced_config
from repro.data import BatchIterator, MarkovLMDataset
from repro.distrib import sharding as shlib
from repro.ft import Supervisor
from repro.launch.mesh import make_mesh
from repro.launch.steps import jit_train_step
from repro.optim import AdamWConfig, adamw_init


def parse_mesh(s: str):
    dims = tuple(int(x) for x in s.split("x"))
    axes = ("data", "model") if len(dims) == 2 else ("pod", "data", "model")
    return make_mesh(dims, axes)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2.5-3b")
    ap.add_argument("--reduced", action="store_true",
                    help="toy-size config (CPU-trainable)")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--global-batch", type=int, default=8)
    ap.add_argument("--seq-len", type=int, default=64)
    ap.add_argument("--mesh", default="1x1")
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=20)
    ap.add_argument("--simulate-failure-at", type=int, default=None)
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--auto-tune", action="store_true",
                    help="resolve Pallas kernel blocks from the persistent "
                         "tuning cache (repro.tuning), pre-measuring this "
                         "run's shapes before the first jitted step")
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = reduced_config(cfg)
    if args.auto_tune:
        from repro import tuning

        tuning.enable_auto()
        warmed = tuning.warm_model_kernels(
            cfg, args.global_batch, args.seq_len
        )
        print(f"auto-tune: {warmed} kernel shape(s) warmed; cache at "
              f"{tuning.default_cache_dir()}")
    if cfg.is_encdec:
        raise SystemExit("use examples/ for enc-dec training demos")
    mesh = parse_mesh(args.mesh)
    shlib.set_rules(mesh)

    api = get_model(cfg)
    key = jax.random.PRNGKey(0)
    opt_cfg = AdamWConfig(
        lr_peak=args.lr, warmup_steps=max(args.steps // 10, 1),
        total_steps=args.steps,
    )

    dataset = MarkovLMDataset(
        vocab=cfg.vocab, seq_len=args.seq_len, branching=4
    )
    print(f"dataset entropy rate: {dataset.entropy_rate:.3f} nats/token")

    batch_abs = {
        "tokens": jax.ShapeDtypeStruct(
            (args.global_batch, args.seq_len), jax.numpy.int32
        ),
        "labels": jax.ShapeDtypeStruct(
            (args.global_batch, args.seq_len), jax.numpy.int32
        ),
    }
    step_fn, (p_sh, o_sh, b_sh) = jit_train_step(
        cfg, mesh, batch_abs, opt_cfg=opt_cfg
    )

    params = api.init_params(cfg, key)
    params = jax.device_put(params, p_sh)
    opt_state = jax.device_put(adamw_init(params), o_sh)

    ckpt = CheckpointManager(args.ckpt_dir, keep=3)
    sup = Supervisor(ckpt, ckpt_every=args.ckpt_every)

    state = {"params": params, "opt": opt_state}
    losses: list[float] = []

    def one_step(state, step):
        it = BatchIterator(
            dataset, args.global_batch, host_index=0, host_count=1,
            start_step=step,
        )
        batch = {
            k: jax.device_put(v, b_sh[k]) for k, v in it.next_local().items()
        }
        params, opt, metrics = step_fn(state["params"], state["opt"], batch)
        loss = float(metrics["loss"])
        losses.append(loss)
        if step % args.log_every == 0 or step == args.steps - 1:
            print(
                f"step {step:5d}  loss {loss:.4f}  "
                f"lr {float(metrics['lr']):.2e}  "
                f"gnorm {float(metrics['grad_norm']):.3f}",
                flush=True,
            )
        return {"params": params, "opt": opt}

    def restore(state, step):
        if step is None:
            return state, 0
        tpl = {"params": jax.tree.map(lambda x: x, state["params"]),
               "opt": state["opt"]}
        restored, got = ckpt.restore(
            tpl, step, shardings={"params": p_sh, "opt": o_sh}
        )
        return restored, got

    t0 = time.time()
    with shlib.rules_context(mesh):
        state, report = sup.run(
            state, one_step, args.steps,
            failure_at=args.simulate_failure_at,
            restore_fn=restore,
            save_filter=lambda s: s,
        )
    dt = time.time() - t0
    print(
        f"\ndone: {args.steps} steps in {dt:.1f}s  "
        f"final loss {losses[-1]:.4f}  (entropy rate "
        f"{dataset.entropy_rate:.3f})  restarts={report['restarts']} "
        f"stragglers={report['stragglers']}"
    )


if __name__ == "__main__":
    main()
