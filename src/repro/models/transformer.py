"""Decoder-only transformer (dense + MoE + VLM families).

Covers qwen2.5-3b/14b, gemma-2b, llama3-8b (dense), mixtral-8x7b,
qwen3-moe-30b-a3b (MoE via :mod:`repro.models.moe`) and qwen2-vl-7b
(M-RoPE + stubbed patch embeddings).

Parameters are explicit pytrees; blocks are stacked along a leading layer
axis and applied with ``lax.scan`` so the traced HLO is one block —
critical for fast multi-pod dry-run compiles at 48 layers.
"""
from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp

from repro.distrib.sharding import constrain
from repro.models import layers as L
from repro.models.config import ModelConfig
from repro.models.moe import init_moe_params, moe_ffn

Params = dict[str, Any]

_KEEP_F32 = ("ln1", "ln2", "q_norm", "k_norm", "final_norm", "ssm_norm",
             "A_log", "dt_bias", "a_param")


def cast_params(tree: Params, dtype) -> Params:
    """Mixed precision: matmul weights in compute dtype, norms/gates f32."""

    def one(path, leaf):
        name = str(path[-1].key) if hasattr(path[-1], "key") else ""
        if any(k in name for k in _KEEP_F32):
            return leaf
        return leaf.astype(dtype)

    return jax.tree_util.tree_map_with_path(one, tree)


def scan_layers(body, x, stacked, unroll: bool):
    """lax.scan over stacked layer params, or a python unroll when the
    config asks for analysis mode (cost_analysis counts a while body
    once — unrolling makes the dry-run FLOPs exact)."""
    if not unroll:
        return jax.lax.scan(body, x, stacked)
    n = jax.tree_util.tree_leaves(stacked)[0].shape[0]
    ys = []
    for i in range(n):
        blk = jax.tree.map(lambda p, i=i: p[i], stacked)
        x, y = body(x, blk)
        ys.append(y)
    ys = jax.tree.map(lambda *leaves: jnp.stack(leaves), *ys)
    return x, ys


# --- init --------------------------------------------------------------------


def init_block_params(cfg: ModelConfig, key, n_layers: int) -> Params:
    """Stacked block params with leading (n_layers,) axis."""
    d, hd, h, g = cfg.d_model, cfg.hd, cfg.n_heads, cfg.n_kv_heads
    ks = jax.random.split(key, 12)
    p: Params = {
        "ln1": jnp.zeros((n_layers, d)),
        "ln2": jnp.zeros((n_layers, d)),
        "wq": L.dense_init(ks[0], (n_layers, d, h * hd)),
        "wk": L.dense_init(ks[1], (n_layers, d, g * hd)),
        "wv": L.dense_init(ks[2], (n_layers, d, g * hd)),
        "wo": L.dense_init(ks[3], (n_layers, h * hd, d)),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((n_layers, h * hd))
        p["bk"] = jnp.zeros((n_layers, g * hd))
        p["bv"] = jnp.zeros((n_layers, g * hd))
    if cfg.qk_norm:
        p["q_norm"] = jnp.zeros((n_layers, hd))
        p["k_norm"] = jnp.zeros((n_layers, hd))
    if cfg.family == "moe":
        p["moe"] = init_moe_params(cfg, ks[4], n_layers)
    else:
        p["w_gate"] = L.dense_init(ks[5], (n_layers, d, cfg.d_ff))
        p["w_up"] = L.dense_init(ks[6], (n_layers, d, cfg.d_ff))
        p["w_down"] = L.dense_init(ks[7], (n_layers, cfg.d_ff, d))
    return p


def init_params(cfg: ModelConfig, key) -> Params:
    k_embed, k_blocks, k_out = jax.random.split(key, 3)
    params: Params = {
        "embed": L.dense_init(k_embed, (cfg.vocab, cfg.d_model), scale=cfg.d_model**-0.5),
        "blocks": init_block_params(cfg, k_blocks, cfg.n_layers),
        "final_norm": jnp.zeros((cfg.d_model,)),
    }
    if not cfg.tie_embeddings:
        params["unembed"] = L.dense_init(k_out, (cfg.d_model, cfg.vocab))
    return params


# --- attention sub-block -----------------------------------------------------


def _qkv(x, blk, cfg: ModelConfig):
    b, s, d = x.shape
    q = x @ blk["wq"]
    k = x @ blk["wk"]
    v = x @ blk["wv"]
    if cfg.qkv_bias:
        q = q + blk["bq"]
        k = k + blk["bk"]
        v = v + blk["bv"]
    q = q.reshape(b, s, cfg.n_heads, cfg.hd)
    k = k.reshape(b, s, cfg.n_kv_heads, cfg.hd)
    v = v.reshape(b, s, cfg.n_kv_heads, cfg.hd)
    if cfg.qk_norm:
        q = L.rms_norm(q, blk["q_norm"], cfg.norm_eps)
        k = L.rms_norm(k, blk["k_norm"], cfg.norm_eps)
    return q, k, v


def attention_block(
    x: jnp.ndarray,
    blk: Params,
    cfg: ModelConfig,
    cos: jnp.ndarray,
    sin: jnp.ndarray,
) -> jnp.ndarray:
    """Training/prefill self-attention with RoPE and GQA."""
    q, k, v = _qkv(x, blk, cfg)
    if cfg.rope_style != "none":
        q = L.apply_rope(q, cos, sin)
        k = L.apply_rope(k, cos, sin)
    q = constrain(q, "act_bshd")
    s = x.shape[1]
    win = cfg.sliding_window
    if win is not None and win >= s:
        win = None  # window covers the whole sequence → plain causal
    unroll = cfg.analysis_unroll or cfg.attn_block_skip
    if s > 2048:
        if win is not None and s % win == 0:
            # Banded O(s·w): kv chunk = window, only the 2 covering chunks.
            out = L.chunked_attention(
                q, k, v, causal=True, window=win,
                q_chunk=min(1024, win), kv_chunk=win,
                unroll=unroll, skip_masked_blocks=cfg.attn_block_skip,
            )
        else:
            out = L.chunked_attention(
                q, k, v, causal=True, window=win,
                q_chunk=1024, kv_chunk=1024,
                unroll=unroll, skip_masked_blocks=cfg.attn_block_skip,
            )
    else:
        out = L.attention(q, k, v, causal=True, window=win)
    b = x.shape[0]
    return out.reshape(b, s, cfg.n_heads * cfg.hd) @ blk["wo"]


def ffn_block(x, blk, cfg: ModelConfig):
    if cfg.family == "moe":
        out, aux = moe_ffn(x, blk["moe"], cfg)
        return out, aux
    return L.gated_mlp(
        x, blk["w_gate"], blk["w_up"], blk["w_down"], cfg.mlp
    ), jnp.zeros((), jnp.float32)


def decoder_block(x, blk, cfg: ModelConfig, cos, sin):
    h = x + attention_block(
        L.rms_norm(x, blk["ln1"], cfg.norm_eps), blk, cfg, cos, sin
    )
    h = constrain(h, "act_bsd")
    ff, aux = ffn_block(L.rms_norm(h, blk["ln2"], cfg.norm_eps), blk, cfg)
    out = constrain(h + ff, "act_bsd")
    return out, aux


# --- forward -----------------------------------------------------------------


def _rope_tables(cfg: ModelConfig, positions: jnp.ndarray | None, b: int, s: int):
    if cfg.rope_style == "none":
        return None, None
    if positions is None:
        positions = jnp.broadcast_to(jnp.arange(s)[None], (b, s))
    if cfg.rope_style == "mrope":
        if positions.ndim == 2:  # text-only: all three streams identical
            positions = jnp.broadcast_to(positions[None], (3, b, s))
        return L.mrope_cos_sin(
            positions, cfg.hd, cfg.rope_theta, cfg.mrope_sections
        )
    return L.rope_cos_sin(positions, cfg.hd, cfg.rope_theta)


def embed_inputs(
    params: Params,
    cfg: ModelConfig,
    tokens: jnp.ndarray,
    patch_embeds: jnp.ndarray | None = None,
) -> jnp.ndarray:
    """Token embedding; for the VLM family the stubbed vision frontend
    supplies ``patch_embeds`` (b, n_patches, d) that REPLACE the first
    n_patches token positions (the image-pad region of the sequence)."""
    x = params["embed"][tokens].astype(cfg.dtype)
    if cfg.family == "vlm" and patch_embeds is not None:
        n_p = patch_embeds.shape[1]
        x = jnp.concatenate(
            [patch_embeds.astype(cfg.dtype), x[:, n_p:]], axis=1
        )
    if cfg.family == "dense" and cfg.arch_id.startswith("gemma"):
        x = x * jnp.asarray(cfg.d_model**0.5, x.dtype)
    return x


def forward(
    params: Params,
    cfg: ModelConfig,
    tokens: jnp.ndarray,
    *,
    positions: jnp.ndarray | None = None,
    patch_embeds: jnp.ndarray | None = None,
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Full forward pass → (logits (b, s, V), moe aux loss)."""
    b, s = tokens.shape
    x = embed_inputs(params, cfg, tokens, patch_embeds)
    x = constrain(x, "act_bsd")
    cos, sin = _rope_tables(cfg, positions, b, s)

    block = functools.partial(decoder_block, cfg=cfg, cos=cos, sin=sin)
    if cfg.remat != "none":
        policy = (
            jax.checkpoint_policies.nothing_saveable
            if cfg.remat == "full"
            else jax.checkpoint_policies.dots_with_no_batch_dims_saveable
        )
        block = jax.checkpoint(block, policy=policy)

    def scan_body(carry, blk_params):
        out, aux = block(carry, cast_params(blk_params, cfg.dtype))
        return out, aux

    x, auxes = scan_layers(
        scan_body, x, params["blocks"], cfg.analysis_unroll
    )
    x = L.rms_norm(x, params["final_norm"], cfg.norm_eps)
    unembed = params.get("unembed")
    if unembed is None:
        unembed = params["embed"].T
    logits = x @ constrain(unembed.astype(cfg.dtype), "unembed_dv")
    return constrain(logits, "logits_bsv"), jnp.sum(auxes)


def lm_loss(
    params: Params,
    cfg: ModelConfig,
    batch: dict[str, jnp.ndarray],
) -> tuple[jnp.ndarray, dict[str, jnp.ndarray]]:
    """Next-token cross-entropy (f32 logsumexp over sharded logits)."""
    logits, aux = forward(
        params, cfg, batch["tokens"],
        positions=batch.get("positions"),
        patch_embeds=batch.get("patch_embeds"),
    )
    xent = L.token_xent(logits, batch["labels"], batch.get("loss_mask"))
    loss = xent + cfg.router_aux_weight * aux
    return loss, {"xent": xent, "aux": aux}


# --- decode ------------------------------------------------------------------


def init_decode_cache(
    cfg: ModelConfig, batch: int, max_len: int, dtype=None
) -> L.KVCache:
    """KV cache; sliding-window archs (mixtral) get a ring buffer of the
    window size — decode stays O(w) even at 524k contexts."""
    if dtype is None:
        dtype = jnp.bfloat16 if cfg.dtype == "bfloat16" else cfg.dtype
    cache_len = (
        min(cfg.sliding_window, max_len) if cfg.sliding_window else max_len
    )
    shape = (cfg.n_layers, batch, cache_len, cfg.n_kv_heads, cfg.hd)
    return L.KVCache(
        k=jnp.zeros(shape, dtype),
        v=jnp.zeros(shape, dtype),
        length=jnp.zeros((), jnp.int32),
    )


def decode_step(
    params: Params,
    cfg: ModelConfig,
    tokens: jnp.ndarray,  # (b, 1)
    cache: L.KVCache,
) -> tuple[jnp.ndarray, L.KVCache]:
    """One decode step: append to the KV cache, return next-token logits.

    ``serve_step`` for the dry-run: one new token against a
    ``cache.length``-long context.
    """
    b = tokens.shape[0]
    x = embed_inputs(params, cfg, tokens)
    pos = jnp.broadcast_to(cache.length[None, None], (b, 1))
    cos, sin = _rope_tables(cfg, pos, b, 1)

    cache_size = cache.k.shape[2]
    ring = cfg.sliding_window is not None and cache_size <= cfg.sliding_window
    slot = jnp.mod(cache.length, cache_size) if ring else cache.length
    valid = (
        jnp.minimum(cache.length + 1, cache_size)
        if ring
        else cache.length + 1
    )

    def scan_body(carry, scanned):
        x, = carry
        blk, k_cache, v_cache = scanned
        blk = cast_params(blk, cfg.dtype)
        xin = L.rms_norm(x, blk["ln1"], cfg.norm_eps)
        q, k, v = _qkv(xin, blk, cfg)
        if cfg.rope_style != "none":
            q = L.apply_rope(q, cos, sin)
            k = L.apply_rope(k, cos, sin)
        k_cache = constrain(
            jax.lax.dynamic_update_slice_in_dim(
                k_cache, k.astype(k_cache.dtype), slot, axis=1
            ),
            "cache_blgd",
        )
        v_cache = constrain(
            jax.lax.dynamic_update_slice_in_dim(
                v_cache, v.astype(v_cache.dtype), slot, axis=1
            ),
            "cache_blgd",
        )
        # Ring eviction already enforces the window; absolute RoPE keeps
        # scores position-correct regardless of slot order.
        out = L.decode_attention(
            q, k_cache, v_cache, valid,
            window=None if ring else cfg.sliding_window,
        )
        h = x + out.reshape(b, 1, cfg.n_heads * cfg.hd) @ blk["wo"]
        ff, _ = ffn_block(L.rms_norm(h, blk["ln2"], cfg.norm_eps), blk, cfg)
        return (h + ff,), (k_cache, v_cache)

    (x,), (k_new, v_new) = scan_layers(
        scan_body, (x,), (params["blocks"], cache.k, cache.v),
        cfg.analysis_unroll,
    )
    x = L.rms_norm(x, params["final_norm"], cfg.norm_eps)
    unembed = params.get("unembed")
    if unembed is None:
        unembed = params["embed"].T
    logits = x @ constrain(unembed.astype(cfg.dtype), "unembed_dv")
    new_cache = L.KVCache(k=k_new, v=v_new, length=cache.length + 1)
    return logits[:, 0], new_cache
