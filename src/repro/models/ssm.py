"""Mamba-2 (SSD — state-space duality) family (mamba2-780m).

The block follows arXiv:2405.21060: in_proj → depthwise causal conv (the
paper-technique stencil: see kernels/conv1d_depthwise.py) → SSD sequence
mixing in the chunked dual form (intra-chunk quadratic attention-like
matmuls on the MXU + inter-chunk linear recurrence) → gated RMSNorm →
out_proj.

Both the chunked-parallel form (training) and the O(1)-state recurrent
form (decode — the ``long_500k`` cell runs THIS, which is why the arch
supports 524k contexts) are implemented; tests assert they match.
"""
from __future__ import annotations

from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

from repro.distrib.sharding import constrain
from repro.kernels import ops as kops
from repro.models import layers as L
from repro.models.config import ModelConfig

Params = dict[str, Any]


class SSMCache(NamedTuple):
    conv: jnp.ndarray  # (n_layers, b, k-1, conv_ch)
    state: jnp.ndarray  # (n_layers, b, h, n, p)
    length: jnp.ndarray


def _dims(cfg: ModelConfig):
    dv = cfg.d_inner
    h = cfg.ssm_n_heads
    p = cfg.ssm_head_dim
    g = cfg.ssm_n_groups
    n = cfg.ssm_state
    conv_ch = dv + 2 * g * n
    return dv, h, p, g, n, conv_ch


def init_block_params(cfg: ModelConfig, key, n_layers: int) -> Params:
    d = cfg.d_model
    dv, h, p, g, n, conv_ch = _dims(cfg)
    ks = jax.random.split(key, 6)
    in_dim = 2 * dv + 2 * g * n + h  # z, xBC, dt
    return {
        "ln1": jnp.zeros((n_layers, d)),
        "in_proj": L.dense_init(ks[0], (n_layers, d, in_dim)),
        "conv_w": L.dense_init(ks[1], (n_layers, cfg.ssm_conv_kernel, conv_ch)),
        "conv_b": jnp.zeros((n_layers, conv_ch)),
        "A_log": jnp.zeros((n_layers, h)),  # A = -exp(A_log) = -1
        "D": jnp.ones((n_layers, h)),
        "dt_bias": jnp.zeros((n_layers, h)),
        "ssm_norm": jnp.zeros((n_layers, dv)),
        "out_proj": L.dense_init(ks[2], (n_layers, dv, d)),
    }


def init_params(cfg: ModelConfig, key) -> Params:
    k1, k2 = jax.random.split(key)
    return {
        "embed": L.dense_init(k1, (cfg.vocab, cfg.d_model), scale=cfg.d_model**-0.5),
        "blocks": init_block_params(cfg, k2, cfg.n_layers),
        "final_norm": jnp.zeros((cfg.d_model,)),
        "unembed": L.dense_init(k2, (cfg.d_model, cfg.vocab)),
    }


# --- SSD core ---------------------------------------------------------------


def ssd_chunked(
    x: jnp.ndarray,  # (b, l, h, p) — dt-scaled inputs
    dA: jnp.ndarray,  # (b, l, h)   — log decay per step (≤ 0)
    B: jnp.ndarray,  # (b, l, g, n)
    C: jnp.ndarray,  # (b, l, g, n)
    chunk: int,
    initial_state: jnp.ndarray | None = None,  # (b, h, n, p)
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Chunked SSD dual form → (y (b, l, h, p), final state (b, h, n, p)).

    Within a chunk: quadratic masked-matmul (attention-like, MXU-friendly).
    Across chunks: linear recurrence over per-chunk states (lax.scan).
    """
    b, l, h, p = x.shape
    g, n = B.shape[2], B.shape[3]
    hg = h // g
    if l % chunk:
        raise ValueError(f"seq {l} not divisible by chunk {chunk}")
    nc = l // chunk
    f32 = jnp.float32

    xc = x.reshape(b, nc, chunk, h, p)
    dAc = dA.reshape(b, nc, chunk, h).astype(f32)
    Bc = B.reshape(b, nc, chunk, g, n)
    Cc = C.reshape(b, nc, chunk, g, n)

    A_cs = jnp.cumsum(dAc, axis=2)  # inclusive within-chunk cumsum
    A_end = A_cs[:, :, -1]  # (b, nc, h)

    # Intra-chunk: y_i += Σ_{j≤i} C_i·B_j · exp(A_cs_i − A_cs_j) · x_j
    CB = jnp.einsum("bkigN,bkjgN->bkgij", Cc.astype(f32), Bc.astype(f32))
    CB = jnp.repeat(CB, hg, axis=2)  # (b, nc, h, c, c)
    decay = jnp.exp(A_cs[:, :, :, None, :] - A_cs[:, :, None, :, :])
    decay = jnp.where(
        jnp.tril(jnp.ones((chunk, chunk), bool))[None, None, :, :, None],
        decay.transpose(0, 1, 2, 3, 4),
        0.0,
    )
    # decay computed as (b, nc, i, j, h) → move h forward
    M = CB * decay.transpose(0, 1, 4, 2, 3)
    y_intra = jnp.einsum("bkhij,bkjhp->bkihp", M, xc.astype(f32))

    # Per-chunk end states: S_k = Σ_j exp(A_end − A_cs_j) B_j x_j^T
    dec_state = jnp.exp(A_end[:, :, None, :] - A_cs)  # (b, nc, c, h)
    Bh = jnp.repeat(Bc, hg, axis=3).reshape(b, nc, chunk, h, n)
    S = jnp.einsum(
        "bkchn,bkchp->bkhnp",
        (Bh.astype(f32) * dec_state[..., None]),
        xc.astype(f32),
    )

    # Inter-chunk recurrence: S_run_k = exp(A_end_k)·S_run_{k-1} + S_k
    def scan_fn(s_prev, inp):
        a_end, s_k = inp
        s_new = jnp.exp(a_end)[:, :, None, None] * s_prev + s_k
        return s_new, s_prev  # emit the state ENTERING chunk k

    s0 = (
        jnp.zeros((b, h, n, p), f32)
        if initial_state is None
        else initial_state.astype(f32)
    )
    final_state, S_prev = jax.lax.scan(
        scan_fn,
        s0,
        (A_end.transpose(1, 0, 2), S.transpose(1, 0, 2, 3, 4)),
    )
    S_prev = S_prev.transpose(1, 0, 2, 3, 4)  # (b, nc, h, n, p)

    # Inter-chunk contribution: y_i += C_i · exp(A_cs_i) · S_prev
    Ch = jnp.repeat(Cc, hg, axis=3).reshape(b, nc, chunk, h, n)
    y_inter = jnp.einsum(
        "bkchn,bkhnp->bkchp",
        Ch.astype(f32) * jnp.exp(A_cs)[..., None],
        S_prev,
    )
    y = (y_intra + y_inter).reshape(b, l, h, p)
    return y, final_state


def ssd_sequential(x, dA, B, C, initial_state=None):
    """Step-by-step oracle for :func:`ssd_chunked` (tests)."""
    b, l, h, p = x.shape
    g, n = B.shape[2], B.shape[3]
    hg = h // g
    f32 = jnp.float32
    Bh = jnp.repeat(B, hg, axis=2).astype(f32)
    Ch = jnp.repeat(C, hg, axis=2).astype(f32)

    def step(state, t):
        a = jnp.exp(dA[:, t].astype(f32))  # (b, h)
        upd = jnp.einsum("bhn,bhp->bhnp", Bh[:, t], x[:, t].astype(f32))
        state = a[:, :, None, None] * state + upd
        y = jnp.einsum("bhn,bhnp->bhp", Ch[:, t], state)
        return state, y

    s0 = (
        jnp.zeros((b, h, n, p), f32)
        if initial_state is None
        else initial_state.astype(f32)
    )
    state, ys = jax.lax.scan(step, s0, jnp.arange(l))
    return jnp.moveaxis(ys, 0, 1), state


# --- block -------------------------------------------------------------------


def _split_in_proj(proj, cfg: ModelConfig):
    dv, h, p, g, n, conv_ch = _dims(cfg)
    z = proj[..., :dv]
    xBC = proj[..., dv : dv + conv_ch]
    dt = proj[..., dv + conv_ch :]
    return z, xBC, dt


def ssm_block(x, blk: Params, cfg: ModelConfig, use_pallas_conv: bool):
    """Full mamba2 mixer over (b, l, d)."""
    b, l, d = x.shape
    dv, h, p, g, n, conv_ch = _dims(cfg)
    proj = x @ blk["in_proj"]
    z, xBC, dt = _split_in_proj(proj, cfg)
    if use_pallas_conv:
        xBC = kops.conv1d_depthwise(
            xBC, blk["conv_w"].astype(x.dtype), activation="none"
        ) + blk["conv_b"].astype(x.dtype)
        xBC = jax.nn.silu(xBC.astype(jnp.float32)).astype(x.dtype)
    else:
        from repro.kernels import ref as kref

        xBC = kref.conv1d_depthwise_causal(xBC, blk["conv_w"].astype(x.dtype))
        xBC = xBC + blk["conv_b"].astype(x.dtype)
        xBC = jax.nn.silu(xBC.astype(jnp.float32)).astype(x.dtype)
    xs = xBC[..., :dv].reshape(b, l, h, p)
    B = xBC[..., dv : dv + g * n].reshape(b, l, g, n)
    C = xBC[..., dv + g * n :].reshape(b, l, g, n)
    dt = jax.nn.softplus(
        dt.astype(jnp.float32) + blk["dt_bias"].astype(jnp.float32)
    )  # (b, l, h)
    A = -jnp.exp(blk["A_log"].astype(jnp.float32))  # (h,)
    dA = dt * A  # (b, l, h)
    x_in = (xs.astype(jnp.float32) * dt[..., None]).astype(x.dtype)
    y, _ = ssd_chunked(x_in, dA, B, C, min(cfg.ssm_chunk, l))
    y = y + blk["D"].astype(jnp.float32)[None, None, :, None] * xs.astype(
        jnp.float32
    )
    y = y.reshape(b, l, dv)
    gated = y * jax.nn.silu(z.astype(jnp.float32))
    y = L.rms_norm(gated.astype(x.dtype), blk["ssm_norm"], cfg.norm_eps)
    return y @ blk["out_proj"]


def forward(params: Params, cfg: ModelConfig, tokens, **_):
    from repro.models.transformer import cast_params

    b, s = tokens.shape
    x = params["embed"][tokens].astype(cfg.dtype)
    x = constrain(x, "act_bsd")
    use_pallas = jax.default_backend() == "tpu"

    def block(xc, blk):
        out = xc + ssm_block(
            L.rms_norm(xc, blk["ln1"], cfg.norm_eps), blk, cfg, use_pallas
        )
        return constrain(out, "act_bsd")

    if cfg.remat != "none":
        block = jax.checkpoint(
            block,
            policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable,
        )

    def scan_body(carry, blk):
        return block(carry, cast_params(blk, cfg.dtype)), 0.0

    from repro.models.transformer import scan_layers

    x, _ = scan_layers(scan_body, x, params["blocks"], cfg.analysis_unroll)
    x = L.rms_norm(x, params["final_norm"], cfg.norm_eps)
    logits = x @ constrain(params["unembed"].astype(cfg.dtype), "unembed_dv")
    return constrain(logits, "logits_bsv"), jnp.zeros((), jnp.float32)


def lm_loss(params, cfg: ModelConfig, batch):
    logits, _ = forward(params, cfg, batch["tokens"])
    loss = L.token_xent(logits, batch["labels"], batch.get("loss_mask"))
    return loss, {"xent": loss}


# --- decode ------------------------------------------------------------------


def init_decode_cache(cfg: ModelConfig, batch: int, max_len: int) -> SSMCache:
    del max_len  # O(1) state — the whole point of the SSM family
    dv, h, p, g, n, conv_ch = _dims(cfg)
    return SSMCache(
        conv=jnp.zeros(
            (cfg.n_layers, batch, cfg.ssm_conv_kernel - 1, conv_ch),
            jnp.float32,
        ),
        state=jnp.zeros((cfg.n_layers, batch, h, n, p), jnp.float32),
        length=jnp.zeros((), jnp.int32),
    )


def decode_step(params: Params, cfg: ModelConfig, tokens, cache: SSMCache):
    """One recurrent decode step — O(1) in context length."""
    from repro.models.transformer import cast_params

    b = tokens.shape[0]
    dv, h, p, g, n, conv_ch = _dims(cfg)
    x = params["embed"][tokens].astype(cfg.dtype)  # (b, 1, d)

    def scan_body(carry, scanned):
        (xc,) = carry
        blk, conv_st, ssm_st = scanned
        blk = cast_params(blk, cfg.dtype)
        xin = L.rms_norm(xc, blk["ln1"], cfg.norm_eps)
        proj = xin @ blk["in_proj"]
        z, xBC, dt = _split_in_proj(proj, cfg)
        # conv over the (k-1) carried inputs + current
        window = jnp.concatenate(
            [conv_st.astype(xc.dtype), xBC], axis=1
        )  # (b, k, ch)
        conv = jnp.einsum("bkc,kc->bc", window, blk["conv_w"]) + blk["conv_b"]
        conv = jax.nn.silu(conv.astype(jnp.float32)).astype(xc.dtype)
        new_conv_st = window[:, 1:].astype(jnp.float32)
        xs = conv[..., :dv].reshape(b, h, p)
        B = conv[..., dv : dv + g * n].reshape(b, g, n)
        C = conv[..., dv + g * n :].reshape(b, g, n)
        dtv = jax.nn.softplus(
            dt[:, 0].astype(jnp.float32) + blk["dt_bias"].astype(jnp.float32)
        )  # (b, h)
        A = -jnp.exp(blk["A_log"].astype(jnp.float32))
        a = jnp.exp(dtv * A)  # (b, h)
        hg = h // g
        Bh = jnp.repeat(B, hg, axis=1).astype(jnp.float32)
        Ch = jnp.repeat(C, hg, axis=1).astype(jnp.float32)
        upd = jnp.einsum(
            "bhn,bhp->bhnp", Bh, xs.astype(jnp.float32) * dtv[..., None]
        )
        new_state = a[:, :, None, None] * ssm_st + upd
        y = jnp.einsum("bhn,bhnp->bhp", Ch, new_state)
        y = y + blk["D"].astype(jnp.float32)[None, :, None] * xs.astype(
            jnp.float32
        )
        y = y.reshape(b, 1, dv)
        gated = y * jax.nn.silu(z.astype(jnp.float32))
        y = L.rms_norm(gated.astype(xc.dtype), blk["ssm_norm"], cfg.norm_eps)
        out = xc + y @ blk["out_proj"]
        return (out,), (new_conv_st, new_state)

    from repro.models.transformer import scan_layers

    (x,), (conv_new, state_new) = scan_layers(
        scan_body, (x,), (params["blocks"], cache.conv, cache.state),
        cfg.analysis_unroll,
    )
    x = L.rms_norm(x, params["final_norm"], cfg.norm_eps)
    logits = x @ constrain(params["unembed"].astype(cfg.dtype), "unembed_dv")
    return logits[:, 0], SSMCache(conv_new, state_new, cache.length + 1)
