"""RecurrentGemma / Griffin hybrid family (recurrentgemma-9b).

Layer pattern 1:2 — every third block is local (sliding-window, MQA)
attention, the rest are recurrent blocks: temporal conv (k=4, a causal
1-D stencil — paper-technique applicability, DESIGN.md §4) followed by
the RG-LRU gated linear recurrence (arXiv:2402.19427):

    r_t = σ(W_a x_t + b_a)                        (recurrence gate)
    i_t = σ(W_x x_t + b_x)                        (input gate)
    log a_t = −c · softplus(Λ) · r_t              (c = 8)
    h_t = a_t · h_{t−1} + √(1 − a_t²) · (i_t ⊙ x_t)

Training evaluates the recurrence with an associative scan (log-depth on
TPU); decode carries (h, conv window) as O(1) state — with the bounded
local-attention KV window this is why ``long_500k`` runs for this arch.

Layers are stacked as super-blocks of (rec, rec, attn) scanned with
lax.scan, plus an unstacked tail for n_layers % 3.
"""
from __future__ import annotations

from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

from repro.distrib.sharding import constrain
from repro.models import layers as L
from repro.models.config import ModelConfig

Params = dict[str, Any]
LRU_C = 8.0


class HybridCache(NamedTuple):
    # recurrent blocks
    lru_h: jnp.ndarray  # (n_rec, b, w)
    conv: jnp.ndarray  # (n_rec, b, k-1, w)
    # attention blocks: bounded window of KV
    k: jnp.ndarray  # (n_att, b, window, g, dh)
    v: jnp.ndarray
    length: jnp.ndarray


def split_layers(n_layers: int, pattern: int) -> tuple[int, int, int]:
    """(n_super, n_tail_rec, n_att). Pattern 3 → (rec, rec, att) blocks."""
    n_super = n_layers // pattern
    return n_super, n_layers - n_super * pattern, n_super


def init_rec_params(cfg: ModelConfig, key, n: int) -> Params:
    d, w = cfg.d_model, cfg.lru_width or cfg.d_model
    ks = jax.random.split(key, 8)
    return {
        "ln": jnp.zeros((n, d)),
        "w_x": L.dense_init(ks[0], (n, d, w)),
        "w_gate_in": L.dense_init(ks[1], (n, d, w)),
        "conv_w": L.dense_init(ks[2], (n, cfg.ssm_conv_kernel, w)),
        "conv_b": jnp.zeros((n, w)),
        "w_a_gate": L.dense_init(ks[3], (n, w)),  # diagonal-ish gates
        "b_a_gate": jnp.zeros((n, w)),
        "w_i_gate": L.dense_init(ks[4], (n, w)),
        "b_i_gate": jnp.zeros((n, w)),
        "a_param": jnp.full((n, w), 2.0),  # Λ: softplus(2) ≈ 2.13
        "w_out": L.dense_init(ks[5], (n, w, d)),
        "ln_mlp": jnp.zeros((n, d)),
        "w_g": L.dense_init(ks[6], (n, d, cfg.d_ff)),
        "w_u": L.dense_init(ks[7], (n, d, cfg.d_ff)),
        "w_d": L.dense_init(ks[0], (n, cfg.d_ff, d)),
    }


def init_att_params(cfg: ModelConfig, key, n: int) -> Params:
    from repro.models.transformer import init_block_params

    return init_block_params(cfg, key, n)


def init_params(cfg: ModelConfig, key) -> Params:
    n_super, n_tail, n_att = split_layers(cfg.n_layers, cfg.hybrid_pattern)
    ks = jax.random.split(key, 6)
    return {
        "embed": L.dense_init(ks[0], (cfg.vocab, cfg.d_model), scale=cfg.d_model**-0.5),
        "super": {
            "rec1": init_rec_params(cfg, ks[1], n_super),
            "rec2": init_rec_params(cfg, ks[2], n_super),
            "att": init_att_params(cfg, ks[3], n_super),
        },
        "tail_rec": init_rec_params(cfg, ks[4], n_tail),
        "final_norm": jnp.zeros((cfg.d_model,)),
        "unembed": L.dense_init(ks[5], (cfg.d_model, cfg.vocab)),
    }


# --- RG-LRU ------------------------------------------------------------------


def rg_lru_scan(x: jnp.ndarray, r: jnp.ndarray, i: jnp.ndarray,
                lam: jnp.ndarray, h0: jnp.ndarray | None = None):
    """Associative-scan RG-LRU over (b, l, w) → (y, h_last)."""
    log_a = -LRU_C * jax.nn.softplus(lam)[None, None, :] * r  # (b, l, w)
    a = jnp.exp(log_a)
    # √(1−a²) via expm1: 1−a² cancels catastrophically as a→1 (r→0).
    # The max-clamp keeps ∂√ finite when r underflows to exactly 0.
    gated = jnp.sqrt(jnp.maximum(-jnp.expm1(2.0 * log_a), 1e-12)) * (i * x)
    if h0 is not None:
        # Fold the carried state in as a virtual step 0.
        a = jnp.concatenate([jnp.ones_like(a[:, :1]), a], axis=1)
        gated = jnp.concatenate([h0[:, None], gated], axis=1)

    def combine(c1, c2):
        a1, b1 = c1
        a2, b2 = c2
        return a1 * a2, a2 * b1 + b2

    a_cum, h = jax.lax.associative_scan(combine, (a, gated), axis=1)
    if h0 is not None:
        h = h[:, 1:]
    return h, h[:, -1]


def rec_block(x, blk: Params, cfg: ModelConfig):
    """Recurrent mixer: conv1d stencil → RG-LRU, gated by GeLU branch."""
    xin = L.rms_norm(x, blk["ln"], cfg.norm_eps)
    u = xin @ blk["w_x"]  # (b, l, w)
    gate = jax.nn.gelu(
        (xin @ blk["w_gate_in"]).astype(jnp.float32), approximate=True
    )
    from repro.kernels import ref as kref

    u = kref.conv1d_depthwise_causal(u, blk["conv_w"].astype(u.dtype))
    u = u + blk["conv_b"].astype(u.dtype)
    uf = u.astype(jnp.float32)
    r = jax.nn.sigmoid(uf * blk["w_a_gate"].astype(jnp.float32)
                       + blk["b_a_gate"].astype(jnp.float32))
    i = jax.nn.sigmoid(uf * blk["w_i_gate"].astype(jnp.float32)
                       + blk["b_i_gate"].astype(jnp.float32))
    y, _ = rg_lru_scan(uf, r, i, blk["a_param"].astype(jnp.float32))
    y = (y * gate).astype(x.dtype)
    h = x + y @ blk["w_out"]
    ff = L.gated_mlp(
        L.rms_norm(h, blk["ln_mlp"], cfg.norm_eps),
        blk["w_g"], blk["w_u"], blk["w_d"], cfg.mlp,
    )
    return h + ff


def att_block(x, blk: Params, cfg: ModelConfig, cos, sin):
    from repro.models.transformer import decoder_block
    import dataclasses

    cfg_local = dataclasses.replace(cfg, sliding_window=cfg.local_window)
    out, _ = decoder_block(x, blk, cfg_local, cos, sin)
    return out


# --- forward -----------------------------------------------------------------


def forward(params: Params, cfg: ModelConfig, tokens, **_):
    from repro.models.transformer import cast_params

    b, s = tokens.shape
    x = params["embed"][tokens].astype(cfg.dtype)
    x = x * jnp.asarray(cfg.d_model**0.5, x.dtype)  # gemma-style scale
    x = constrain(x, "act_bsd")
    positions = jnp.broadcast_to(jnp.arange(s)[None], (b, s))
    cos, sin = L.rope_cos_sin(positions, cfg.hd, cfg.rope_theta)

    def super_block(xc, sup):
        h = rec_block(xc, sup["rec1"], cfg)
        h = rec_block(h, sup["rec2"], cfg)
        h = att_block(h, sup["att"], cfg, cos, sin)
        return constrain(h, "act_bsd")

    if cfg.remat != "none":
        super_block = jax.checkpoint(
            super_block,
            policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable,
        )

    def scan_body(carry, sup):
        return super_block(carry, cast_params(sup, cfg.dtype)), 0.0

    from repro.models.transformer import scan_layers

    x, _ = scan_layers(scan_body, x, params["super"], cfg.analysis_unroll)
    n_tail = params["tail_rec"]["ln"].shape[0]
    for t in range(n_tail):
        blk = jax.tree.map(lambda p: p[t], params["tail_rec"])
        x = rec_block(x, cast_params(blk, cfg.dtype), cfg)
    x = L.rms_norm(x, params["final_norm"], cfg.norm_eps)
    logits = x @ constrain(params["unembed"].astype(cfg.dtype), "unembed_dv")
    return constrain(logits, "logits_bsv"), jnp.zeros((), jnp.float32)


def lm_loss(params, cfg: ModelConfig, batch):
    logits, _ = forward(params, cfg, batch["tokens"])
    loss = L.token_xent(logits, batch["labels"], batch.get("loss_mask"))
    return loss, {"xent": loss}


# --- decode ------------------------------------------------------------------


def init_decode_cache(cfg: ModelConfig, batch: int, max_len: int) -> HybridCache:
    n_super, n_tail, n_att = split_layers(cfg.n_layers, cfg.hybrid_pattern)
    n_rec = 2 * n_super + n_tail
    w = cfg.lru_width or cfg.d_model
    window = min(cfg.local_window, max_len)
    kv_dtype = jnp.bfloat16 if cfg.dtype == "bfloat16" else cfg.dtype
    return HybridCache(
        lru_h=jnp.zeros((n_rec, batch, w), jnp.float32),
        conv=jnp.zeros((n_rec, batch, cfg.ssm_conv_kernel - 1, w), jnp.float32),
        k=jnp.zeros((n_att, batch, window, cfg.n_kv_heads, cfg.hd), kv_dtype),
        v=jnp.zeros((n_att, batch, window, cfg.n_kv_heads, cfg.hd), kv_dtype),
        length=jnp.zeros((), jnp.int32),
    )


def _rec_step(xc, blk, cfg, lru_h, conv_st):
    """Single-token recurrent block step (O(1) state)."""
    b = xc.shape[0]
    xin = L.rms_norm(xc, blk["ln"], cfg.norm_eps)
    u = xin @ blk["w_x"]  # (b, 1, w)
    gate = jax.nn.gelu(
        (xin @ blk["w_gate_in"]).astype(jnp.float32), approximate=True
    )
    window = jnp.concatenate([conv_st.astype(xc.dtype), u], axis=1)
    u1 = jnp.einsum("bkc,kc->bc", window, blk["conv_w"]) + blk["conv_b"]
    new_conv = window[:, 1:].astype(jnp.float32)
    uf = u1.astype(jnp.float32)
    r = jax.nn.sigmoid(uf * blk["w_a_gate"].astype(jnp.float32)
                       + blk["b_a_gate"].astype(jnp.float32))
    i = jax.nn.sigmoid(uf * blk["w_i_gate"].astype(jnp.float32)
                       + blk["b_i_gate"].astype(jnp.float32))
    log_a = -LRU_C * jax.nn.softplus(blk["a_param"].astype(jnp.float32)) * r
    a = jnp.exp(log_a)
    h_new = a * lru_h + jnp.sqrt(
        jnp.maximum(-jnp.expm1(2.0 * log_a), 1e-12)
    ) * (i * uf)
    y = (h_new[:, None] * gate).astype(xc.dtype)
    h = xc + y @ blk["w_out"]
    ff = L.gated_mlp(
        L.rms_norm(h, blk["ln_mlp"], cfg.norm_eps),
        blk["w_g"], blk["w_u"], blk["w_d"], cfg.mlp,
    )
    return h + ff, h_new, new_conv


def _att_step(xc, blk, cfg, k_cache, v_cache, length, cos, sin):
    """Single-token local attention step against a ring-buffer window."""
    import dataclasses

    from repro.models.transformer import _qkv, ffn_block

    b = xc.shape[0]
    cfg_l = dataclasses.replace(cfg, sliding_window=cfg.local_window)
    xin = L.rms_norm(xc, blk["ln1"], cfg.norm_eps)
    q, k, v = _qkv(xin, blk, cfg_l)
    q = L.apply_rope(q, cos, sin)
    k = L.apply_rope(k, cos, sin)
    window = k_cache.shape[1]
    slot = jnp.mod(length, window)
    k_cache = jax.lax.dynamic_update_slice_in_dim(
        k_cache, k.astype(k_cache.dtype), slot, axis=1
    )
    v_cache = jax.lax.dynamic_update_slice_in_dim(
        v_cache, v.astype(v_cache.dtype), slot, axis=1
    )
    # Ring buffer: all entries < min(length+1, window) are valid; RoPE is
    # absolute so attention scores are position-correct regardless of slot
    # order.
    valid = jnp.minimum(length + 1, window)
    out = L.decode_attention(q, k_cache, v_cache, valid)
    h = xc + out.reshape(b, 1, cfg.n_heads * cfg.hd) @ blk["wo"]
    ff, _ = ffn_block(L.rms_norm(h, blk["ln2"], cfg.norm_eps), blk, cfg_l)
    return h + ff, k_cache, v_cache


def decode_step(params: Params, cfg: ModelConfig, tokens, cache: HybridCache):
    from repro.models.transformer import cast_params

    b = tokens.shape[0]
    x = params["embed"][tokens].astype(cfg.dtype)
    x = x * jnp.asarray(cfg.d_model**0.5, x.dtype)
    pos = jnp.broadcast_to(cache.length[None, None], (b, 1))
    cos, sin = L.rope_cos_sin(pos, cfg.hd, cfg.rope_theta)

    n_super, n_tail, _ = split_layers(cfg.n_layers, cfg.hybrid_pattern)
    lru_h, conv, ks, vs = cache.lru_h, cache.conv, cache.k, cache.v
    new_h, new_conv, new_k, new_v = [], [], [], []
    ri, ai = 0, 0
    for si in range(n_super):
        sup = jax.tree.map(lambda p, si=si: p[si], params["super"])
        sup = cast_params(sup, cfg.dtype)
        for rec_name in ("rec1", "rec2"):
            x, h1, c1 = _rec_step(x, sup[rec_name], cfg, lru_h[ri], conv[ri])
            new_h.append(h1)
            new_conv.append(c1)
            ri += 1
        x, k1, v1 = _att_step(
            x, sup["att"], cfg, ks[ai], vs[ai], cache.length, cos, sin
        )
        new_k.append(k1)
        new_v.append(v1)
        ai += 1
    for t in range(n_tail):
        blk = cast_params(
            jax.tree.map(lambda p, t=t: p[t], params["tail_rec"]), cfg.dtype
        )
        x, h1, c1 = _rec_step(x, blk, cfg, lru_h[ri], conv[ri])
        new_h.append(h1)
        new_conv.append(c1)
        ri += 1
    x = L.rms_norm(x, params["final_norm"], cfg.norm_eps)
    logits = x @ constrain(params["unembed"].astype(cfg.dtype), "unembed_dv")
    return logits[:, 0], HybridCache(
        lru_h=jnp.stack(new_h),
        conv=jnp.stack(new_conv),
        k=jnp.stack(new_k),
        v=jnp.stack(new_v),
        length=cache.length + 1,
    )
