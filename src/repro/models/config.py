"""Architecture configuration — one dataclass covering all ten assigned
families; per-arch instances live in :mod:`repro.configs`."""
from __future__ import annotations

import dataclasses
from typing import Literal

Family = Literal["dense", "moe", "vlm", "hybrid", "audio", "ssm"]


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    arch_id: str
    family: Family

    # transformer backbone
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: int | None = None  # default d_model // n_heads
    qkv_bias: bool = False
    qk_norm: bool = False  # qwen3-style per-head RMSNorm on q/k
    mlp: Literal["swiglu", "geglu", "gelu"] = "swiglu"
    tie_embeddings: bool = False
    rope_theta: float = 1e6
    rope_style: Literal["standard", "mrope", "none"] = "standard"
    mrope_sections: tuple[int, int, int] = (16, 24, 24)  # t/h/w splits
    norm_eps: float = 1e-6
    sliding_window: int | None = None  # SWA width (mixtral)

    # MoE
    n_experts: int = 0
    top_k: int = 0
    d_ff_expert: int = 0
    router_aux_weight: float = 0.01
    capacity_factor: float = 1.25

    # SSM (mamba2)
    ssm_state: int = 0
    ssm_head_dim: int = 64
    ssm_expand: int = 2
    ssm_conv_kernel: int = 4
    ssm_chunk: int = 256
    ssm_n_groups: int = 1

    # hybrid (recurrentgemma): layer i is local-attn iff (i % 3 == 2)
    hybrid_pattern: int = 0  # 0 = not hybrid; 3 = 1 attn per 3 layers
    lru_width: int = 0
    local_window: int = 2048

    # encoder-decoder (whisper)
    n_encoder_layers: int = 0
    encoder_seq: int = 1500  # stubbed conv-frontend output frames
    max_target_len: int = 448

    # vlm stub
    n_patches: int = 0  # patch embeds prepended by the stub frontend

    # numerics / runtime
    dtype: str = "bfloat16"
    remat: Literal["none", "selective", "full"] = "selective"
    # Dry-run analysis mode: python-unroll layer/attention loops so the
    # compiled HLO's cost_analysis counts EVERY iteration (XLA reports a
    # while-loop body once). Semantically identical; used only when
    # lowering for the roofline, never for execution.
    analysis_unroll: bool = False
    # Perf knob: statically skip fully-masked (above-diagonal) attention
    # blocks — requires the unrolled attention path.
    attn_block_skip: bool = False

    # which technique integrations apply (DESIGN.md §Arch-applicability)
    uses_stencil_kernel: bool = False

    @property
    def hd(self) -> int:
        if self.head_dim:
            return self.head_dim
        return self.d_model // self.n_heads if self.n_heads else 0

    @property
    def d_inner(self) -> int:  # ssm
        return self.ssm_expand * self.d_model

    @property
    def ssm_n_heads(self) -> int:
        return self.d_inner // self.ssm_head_dim

    @property
    def is_encdec(self) -> bool:
        return self.n_encoder_layers > 0

    def n_params(self) -> float:
        """Total parameter count (analytic; used for 6·N·D model FLOPs)."""
        d, hd = self.d_model, self.hd
        p = self.vocab * d  # embed
        if not self.tie_embeddings:
            p += self.vocab * d
        if self.family == "ssm":
            dv = self.d_inner
            conv_ch = dv + 2 * self.ssm_n_groups * self.ssm_state
            per = (
                d * (2 * dv + 2 * self.ssm_n_groups * self.ssm_state
                     + self.ssm_n_heads)  # in_proj
                + conv_ch * self.ssm_conv_kernel
                + 2 * self.ssm_n_heads  # A_log, D
                + dv  # norm
                + dv * d  # out_proj
                + d  # ln
            )
            return p + self.n_layers * per
        attn = d * self.n_heads * hd + 2 * d * self.n_kv_heads * hd \
            + self.n_heads * hd * d
        if self.qkv_bias:
            attn += (self.n_heads + 2 * self.n_kv_heads) * hd
        def ffn(ff):
            mult = 3 if self.mlp in ("swiglu", "geglu") else 2
            return mult * d * ff
        per = attn + 2 * d  # norms
        if self.family == "moe":
            per += d * self.n_experts + self.n_experts * ffn(self.d_ff_expert)
        else:
            per += ffn(self.d_ff)
        total = p + self.n_layers * per + d
        if self.hybrid_pattern:
            # recurrent layers replace attention with conv + RG-LRU
            n_rec = self.n_layers - self.n_layers // self.hybrid_pattern
            w = self.lru_width or d
            rec = d * w * 2 + w * 4 + w * d + 4 * w  # in/out proj + gates
            total += n_rec * (rec - attn)
        if self.is_encdec:
            # encoder blocks + decoder cross-attention
            total += self.n_encoder_layers * (attn + ffn(self.d_ff) + 2 * d)
            total += self.n_layers * attn  # cross-attn per decoder layer
        return float(total)

    def n_active_params(self) -> float:
        """Active per-token params (MoE: top_k experts only)."""
        if self.family != "moe":
            return self.n_params()
        def ffn(ff):
            return 3 * self.d_model * ff
        inactive = (self.n_experts - self.top_k) * ffn(self.d_ff_expert)
        return self.n_params() - self.n_layers * inactive
