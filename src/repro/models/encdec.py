"""Whisper-style encoder-decoder (whisper-small).

Per the assignment spec the conv/mel frontend is a STUB: ``input_specs``
provides precomputed frame embeddings (b, 1500, d) — the transformer
backbone (12 bidirectional encoder layers, 12 causal decoder layers with
cross-attention, learned positional embeddings, pre-LN + GELU MLP) is
implemented in full. Decode shapes (decode_32k / long_500k) are out of
this architecture's contract (max target length 448) and are skipped by
the dry-run matrix; a short-form ``decode_step`` is provided for the
serving example.
"""
from __future__ import annotations

from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

from repro.distrib.sharding import constrain
from repro.models import layers as L
from repro.models.config import ModelConfig

Params = dict[str, Any]


class EncDecCache(NamedTuple):
    k: jnp.ndarray  # (L, b, max_target, h, dh) decoder self-attn
    v: jnp.ndarray
    xk: jnp.ndarray  # (L, b, enc_seq, h, dh) precomputed cross K/V
    xv: jnp.ndarray
    length: jnp.ndarray


def _attn_params(key, n, d, h, hd):
    ks = jax.random.split(key, 4)
    return {
        "wq": L.dense_init(ks[0], (n, d, h * hd)),
        "wk": L.dense_init(ks[1], (n, d, h * hd)),
        "wv": L.dense_init(ks[2], (n, d, h * hd)),
        "wo": L.dense_init(ks[3], (n, h * hd, d)),
        "bq": jnp.zeros((n, h * hd)),
        "bv": jnp.zeros((n, h * hd)),
        "bo": jnp.zeros((n, d)),
    }


def _block_params(key, n, d, h, hd, ff, cross: bool):
    ks = jax.random.split(key, 4)
    p = {
        "ln1_w": jnp.ones((n, d)), "ln1_b": jnp.zeros((n, d)),
        "attn": _attn_params(ks[0], n, d, h, hd),
        "ln2_w": jnp.ones((n, d)), "ln2_b": jnp.zeros((n, d)),
        "w_in": L.dense_init(ks[1], (n, d, ff)),
        "b_in": jnp.zeros((n, ff)),
        "w_out": L.dense_init(ks[2], (n, ff, d)),
        "b_out": jnp.zeros((n, d)),
    }
    if cross:
        p["lnx_w"] = jnp.ones((n, d))
        p["lnx_b"] = jnp.zeros((n, d))
        p["xattn"] = _attn_params(ks[3], n, d, h, hd)
    return p


def init_params(cfg: ModelConfig, key) -> Params:
    d, h, hd, ff = cfg.d_model, cfg.n_heads, cfg.hd, cfg.d_ff
    ks = jax.random.split(key, 6)
    return {
        "enc_pos": L.dense_init(ks[0], (cfg.encoder_seq, d), scale=0.02),
        "enc_blocks": _block_params(ks[1], cfg.n_encoder_layers, d, h, hd, ff, False),
        "enc_ln_w": jnp.ones((d,)), "enc_ln_b": jnp.zeros((d,)),
        "embed": L.dense_init(ks[2], (cfg.vocab, d), scale=0.02),
        "dec_pos": L.dense_init(ks[3], (cfg.max_target_len, d), scale=0.02),
        "blocks": _block_params(ks[4], cfg.n_layers, d, h, hd, ff, True),
        "dec_ln_w": jnp.ones((d,)), "dec_ln_b": jnp.zeros((d,)),
    }


def _mha(x, p, cfg, *, kv: jnp.ndarray | None = None, causal: bool):
    """Whisper MHA (no k bias, per the original)."""
    b, s, d = x.shape
    h, hd = cfg.n_heads, cfg.hd
    src = x if kv is None else kv
    q = (x @ p["wq"] + p["bq"]).reshape(b, s, h, hd)
    k = (src @ p["wk"]).reshape(b, src.shape[1], h, hd)
    v = (src @ p["wv"] + p["bv"]).reshape(b, src.shape[1], h, hd)
    if s > 2048:
        out = L.chunked_attention(q, k, v, causal=causal)
    else:
        out = L.attention(q, k, v, causal=causal)
    return out.reshape(b, s, h * hd) @ p["wo"] + p["bo"]


def encode(params: Params, cfg: ModelConfig, frames: jnp.ndarray):
    """frames (b, enc_seq, d) — stubbed conv-frontend output."""
    from repro.models.transformer import cast_params

    x = frames.astype(cfg.dtype) + params["enc_pos"].astype(cfg.dtype)
    x = constrain(x, "act_bsd")

    def body(carry, blk):
        blk = cast_params(blk, cfg.dtype)
        h = carry + _mha(
            L.layer_norm(carry, blk["ln1_w"], blk["ln1_b"]),
            blk["attn"], cfg, causal=False,
        )
        ff = L.gelu_mlp(
            L.layer_norm(h, blk["ln2_w"], blk["ln2_b"]),
            blk["w_in"], blk["b_in"], blk["w_out"], blk["b_out"],
        )
        return constrain(h + ff, "act_bsd"), 0.0

    from repro.models.transformer import scan_layers

    x, _ = scan_layers(body, x, params["enc_blocks"], cfg.analysis_unroll)
    return L.layer_norm(x, params["enc_ln_w"], params["enc_ln_b"])


def decode_train(params: Params, cfg: ModelConfig, tokens, enc_out):
    from repro.models.transformer import cast_params

    b, s = tokens.shape
    x = params["embed"][tokens].astype(cfg.dtype)
    x = x + params["dec_pos"][:s].astype(cfg.dtype)

    def body(carry, blk):
        blk = cast_params(blk, cfg.dtype)
        h = carry + _mha(
            L.layer_norm(carry, blk["ln1_w"], blk["ln1_b"]),
            blk["attn"], cfg, causal=True,
        )
        h = h + _mha(
            L.layer_norm(h, blk["lnx_w"], blk["lnx_b"]),
            blk["xattn"], cfg, kv=enc_out, causal=False,
        )
        ff = L.gelu_mlp(
            L.layer_norm(h, blk["ln2_w"], blk["ln2_b"]),
            blk["w_in"], blk["b_in"], blk["w_out"], blk["b_out"],
        )
        return constrain(h + ff, "act_bsd"), 0.0

    from repro.models.transformer import scan_layers

    x, _ = scan_layers(body, x, params["blocks"], cfg.analysis_unroll)
    x = L.layer_norm(x, params["dec_ln_w"], params["dec_ln_b"])
    # Whisper ties output projection to the token embedding.
    return x @ params["embed"].T.astype(cfg.dtype)


def forward(params, cfg: ModelConfig, tokens, *, frames=None, **_):
    enc = encode(params, cfg, frames)
    return decode_train(params, cfg, tokens, enc), jnp.zeros((), jnp.float32)


def lm_loss(params, cfg: ModelConfig, batch):
    logits, _ = forward(
        params, cfg, batch["tokens"], frames=batch["frames"]
    )
    loss = L.token_xent(logits, batch["labels"], batch.get("loss_mask"))
    return loss, {"xent": loss}


# --- decode (short-form serving) --------------------------------------------


def init_decode_cache(
    params: Params, cfg: ModelConfig, enc_out: jnp.ndarray
) -> EncDecCache:
    b = enc_out.shape[0]
    h, hd = cfg.n_heads, cfg.hd
    Lc = cfg.n_layers

    def cross_kv(blk, enc):
        k = (enc @ blk["xattn"]["wk"]).reshape(b, -1, h, hd)
        v = (enc @ blk["xattn"]["wv"] + blk["xattn"]["bv"]).reshape(b, -1, h, hd)
        return k, v

    ks, vs = jax.vmap(
        lambda blk: cross_kv(blk, enc_out.astype(cfg.dtype))
    )(jax.tree.map(lambda p: p.astype(cfg.dtype), params["blocks"]))
    t = cfg.max_target_len
    return EncDecCache(
        k=jnp.zeros((Lc, b, t, h, hd), cfg.dtype),
        v=jnp.zeros((Lc, b, t, h, hd), cfg.dtype),
        xk=ks, xv=vs,
        length=jnp.zeros((), jnp.int32),
    )


def decode_step(params, cfg: ModelConfig, tokens, cache: EncDecCache):
    from repro.models.transformer import cast_params

    b = tokens.shape[0]
    h, hd = cfg.n_heads, cfg.hd
    x = params["embed"][tokens].astype(cfg.dtype)
    x = x + jax.lax.dynamic_slice_in_dim(
        params["dec_pos"], cache.length, 1
    ).astype(cfg.dtype)

    def body(carry, scanned):
        (xc,) = carry
        blk, kc, vc, xk, xv = scanned
        blk = cast_params(blk, cfg.dtype)
        xin = L.layer_norm(xc, blk["ln1_w"], blk["ln1_b"])
        ap = blk["attn"]
        q = (xin @ ap["wq"] + ap["bq"]).reshape(b, 1, h, hd)
        k = (xin @ ap["wk"]).reshape(b, 1, h, hd)
        v = (xin @ ap["wv"] + ap["bv"]).reshape(b, 1, h, hd)
        kc = jax.lax.dynamic_update_slice_in_dim(
            kc, k.astype(kc.dtype), cache.length, axis=1
        )
        vc = jax.lax.dynamic_update_slice_in_dim(
            vc, v.astype(vc.dtype), cache.length, axis=1
        )
        out = L.decode_attention(q, kc, vc, cache.length + 1)
        hh = xc + out.reshape(b, 1, h * hd) @ ap["wo"] + ap["bo"]
        # cross attention over the full (static) encoder output
        xp = blk["xattn"]
        xin2 = L.layer_norm(hh, blk["lnx_w"], blk["lnx_b"])
        q2 = (xin2 @ xp["wq"] + xp["bq"]).reshape(b, 1, h, hd)
        out2 = L.decode_attention(q2, xk, xv, jnp.asarray(xk.shape[1]))
        hh = hh + out2.reshape(b, 1, h * hd) @ xp["wo"] + xp["bo"]
        ff = L.gelu_mlp(
            L.layer_norm(hh, blk["ln2_w"], blk["ln2_b"]),
            blk["w_in"], blk["b_in"], blk["w_out"], blk["b_out"],
        )
        return (hh + ff,), (kc, vc)

    from repro.models.transformer import scan_layers

    (x,), (k_new, v_new) = scan_layers(
        body, (x,), (params["blocks"], cache.k, cache.v, cache.xk, cache.xv),
        cfg.analysis_unroll,
    )
    x = L.layer_norm(x, params["dec_ln_w"], params["dec_ln_b"])
    logits = x @ params["embed"].T.astype(cfg.dtype)
    return logits[:, 0], EncDecCache(
        k=k_new, v=v_new, xk=cache.xk, xv=cache.xv, length=cache.length + 1
    )
