"""Assigned-architecture zoo: dense/MoE/VLM/hybrid/audio/SSM LM families,
all selectable via ``--arch`` (see repro.configs.registry)."""
