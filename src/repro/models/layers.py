"""Shared model building blocks: norms, RoPE (incl. M-RoPE), GQA
attention (full / sliding-window / chunked-flash / KV-cache decode), and
gated MLPs. Pure functions over explicit parameter pytrees — no module
framework, so every layer composes with pjit/shard_map and scan.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np


# --- norms -------------------------------------------------------------------


def rms_norm(x: jnp.ndarray, w: jnp.ndarray, eps: float = 1e-6) -> jnp.ndarray:
    var = jnp.mean(jnp.square(x.astype(jnp.float32)), axis=-1, keepdims=True)
    y = x.astype(jnp.float32) * jax.lax.rsqrt(var + eps)
    return (y * (1.0 + w.astype(jnp.float32))).astype(x.dtype)


def layer_norm(x, w, b, eps: float = 1e-5):
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    y = (xf - mu) * jax.lax.rsqrt(var + eps)
    return (y * w + b).astype(x.dtype)


# --- rotary embeddings -------------------------------------------------------


def rope_freqs(head_dim: int, theta: float) -> np.ndarray:
    return 1.0 / theta ** (np.arange(0, head_dim, 2) / head_dim)


def rope_cos_sin(
    positions: jnp.ndarray, head_dim: int, theta: float
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """positions (..., s) int → cos/sin (..., s, head_dim/2) f32."""
    inv = jnp.asarray(rope_freqs(head_dim, theta), jnp.float32)
    ang = positions.astype(jnp.float32)[..., None] * inv
    return jnp.cos(ang), jnp.sin(ang)


def mrope_cos_sin(
    positions: jnp.ndarray,
    head_dim: int,
    theta: float,
    sections: tuple[int, int, int],
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Qwen2-VL multimodal RoPE: ``positions`` (3, b, s) carries the
    temporal/height/width position streams; the rotary frequency bands
    are split between them per ``sections`` (in dh/2 units)."""
    if sum(sections) != head_dim // 2:
        raise ValueError("mrope sections must sum to head_dim // 2")
    inv = jnp.asarray(rope_freqs(head_dim, theta), jnp.float32)
    ang = positions.astype(jnp.float32)[..., None] * inv  # (3, b, s, dh/2)
    parts = []
    start = 0
    for axis, width in enumerate(sections):
        parts.append(ang[axis, ..., start : start + width])
        start += width
    ang = jnp.concatenate(parts, axis=-1)  # (b, s, dh/2)
    return jnp.cos(ang), jnp.sin(ang)


def apply_rope(x: jnp.ndarray, cos: jnp.ndarray, sin: jnp.ndarray):
    """x (b, s, h, dh); cos/sin (b, s, dh/2) — rotate-half convention."""
    dh = x.shape[-1]
    x1 = x[..., : dh // 2].astype(jnp.float32)
    x2 = x[..., dh // 2 :].astype(jnp.float32)
    c = cos[:, :, None, :]
    s = sin[:, :, None, :]
    out = jnp.concatenate([x1 * c - x2 * s, x2 * c + x1 * s], axis=-1)
    return out.astype(x.dtype)


# --- attention ---------------------------------------------------------------


def expand_kv(
    kv: jnp.ndarray, n_heads: int, rule: str = "act_bshd"
) -> jnp.ndarray:
    """GQA: (b, t, g, dh) → (b, t, h, dh) by repeating groups.

    Head-dim einsums with FULL heads keep every attention op local under
    head-sharded TP (grouped 5-D einsums confuse the SPMD partitioner
    into per-chunk regathers — measured in EXPERIMENTS.md §Dry-run). The
    expansion is free per-device when heads are sharded: each chip
    materializes only its own heads' copies. ``rule`` picks the
    annotation — decode uses the cache rule (falls back to
    sequence-sharding when heads don't divide the model axis).
    """
    g = kv.shape[2]
    if g == n_heads:
        return kv
    kv = jnp.repeat(kv, n_heads // g, axis=2)
    from repro.distrib.sharding import constrain

    return constrain(kv, rule)


def attention(
    q: jnp.ndarray,
    k: jnp.ndarray,
    v: jnp.ndarray,
    *,
    causal: bool = True,
    window: int | None = None,
    softmax_scale: float | None = None,
) -> jnp.ndarray:
    """Materialized GQA attention (short sequences / smoke tests).

    q (b, s, h, dh); k, v (b, t, g, dh) with g | h.
    """
    b, s, h, dh = q.shape
    scale = softmax_scale or dh**-0.5
    kf = expand_kv(k, h)
    vf = expand_kv(v, h)
    logits = jnp.einsum(
        "bshd,bthd->bhst",
        (q * scale).astype(jnp.float32),
        kf.astype(jnp.float32),
    )
    t = k.shape[1]
    qpos = jnp.arange(s)[:, None] + (t - s)  # right-aligned queries
    kpos = jnp.arange(t)[None, :]
    mask = jnp.ones((s, t), bool)
    if causal:
        mask &= kpos <= qpos
    if window is not None:
        mask &= kpos > qpos - window
    logits = jnp.where(mask[None, None], logits, -1e30)
    p = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bhst,bthd->bshd", p.astype(vf.dtype), vf)
    return out


def chunked_attention(
    q: jnp.ndarray,
    k: jnp.ndarray,
    v: jnp.ndarray,
    *,
    causal: bool = True,
    window: int | None = None,
    q_chunk: int = 1024,
    kv_chunk: int = 1024,
    softmax_scale: float | None = None,
    unroll: bool = False,
    skip_masked_blocks: bool = False,
) -> jnp.ndarray:
    """Flash-style streaming-softmax attention in pure JAX: O(s·c) live
    memory, lax.scan over KV chunks with running (max, denom, acc).

    For ``window`` (sliding-window/local attention) the KV stream is
    restricted statically to the two chunks covering the window when
    ``kv_chunk == window`` — mixtral/recurrentgemma's banded pattern costs
    O(s·w), not O(s²).
    """
    b, s, h, dh = q.shape
    t = k.shape[1]
    scale = softmax_scale or dh**-0.5
    if s % q_chunk or t % kv_chunk:
        raise ValueError(f"chunk sizes must divide seq: {s}/{q_chunk}, {t}/{kv_chunk}")
    nq, nk = s // q_chunk, t // kv_chunk
    qg = (q * scale).reshape(b, nq, q_chunk, h, dh)
    kc = expand_kv(k, h).reshape(b, nk, kv_chunk, h, dh)
    vc = expand_kv(v, h).reshape(b, nk, kv_chunk, h, dh)

    banded = window is not None and window == kv_chunk and causal
    if banded and (kv_chunk % q_chunk != 0):
        raise ValueError("banded attention needs q_chunk | kv_chunk")

    def process_q_chunk(iq, q_blk):
        # q_blk (b, c, h, dh)
        def kv_step(carry, jk):
            m, l, acc = carry
            k_blk = jax.lax.dynamic_index_in_dim(kc, jk, 1, keepdims=False)
            v_blk = jax.lax.dynamic_index_in_dim(vc, jk, 1, keepdims=False)
            logits = jnp.einsum(
                "bshd,bthd->bhst",
                q_blk.astype(jnp.float32),
                k_blk.astype(jnp.float32),
            )
            qpos = iq * q_chunk + jnp.arange(q_chunk)[:, None]
            kpos = jk * kv_chunk + jnp.arange(kv_chunk)[None, :]
            mask = jnp.ones((q_chunk, kv_chunk), bool)
            if causal:
                mask &= kpos <= qpos
            if window is not None:
                mask &= kpos > qpos - window
            logits = jnp.where(mask[None, None], logits, -1e30)
            m_new = jnp.maximum(m, logits.max(axis=-1))
            alpha = jnp.exp(m - m_new)
            p = jnp.exp(logits - m_new[..., None])
            l_new = l * alpha + p.sum(axis=-1)
            acc_new = acc * alpha[..., None] + jnp.einsum(
                "bhst,bthd->bhsd", p, v_blk.astype(jnp.float32)
            )
            return (m_new, l_new, acc_new), None

        m0 = jnp.full((b, h, q_chunk), -jnp.inf, jnp.float32)
        l0 = jnp.zeros((b, h, q_chunk), jnp.float32)
        a0 = jnp.zeros((b, h, q_chunk, dh), jnp.float32)
        if unroll:
            # Static python loop: every block appears in the HLO, so the
            # dry-run's cost_analysis counts the true FLOPs (a lax.scan
            # body is only counted once). With ``skip_masked_blocks`` the
            # fully-above-diagonal blocks are dropped entirely — the
            # flash-style triangular saving the masked scan path cannot
            # express (≈2× attention FLOPs; see EXPERIMENTS.md §Perf).
            iq_c = int(iq)
            if banded:
                hi = ((iq_c + 1) * q_chunk - 1) // kv_chunk
                ids = sorted({max(hi - 1, 0), hi})
            elif causal and skip_masked_blocks:
                hi = ((iq_c + 1) * q_chunk - 1) // kv_chunk
                ids = list(range(hi + 1))
            else:
                ids = list(range(nk))
            carry = (m0, l0, a0)
            for jk in ids:
                carry, _ = kv_step(carry, jnp.asarray(jk))
            m, l, acc = carry
        else:
            if banded:
                # A clipped duplicate (hi == 0) is processed twice; the
                # streaming-softmax merge makes that a no-op on output.
                hi = ((iq + 1) * q_chunk - 1) // kv_chunk
                kv_ids = jnp.stack([jnp.maximum(hi - 1, 0), hi])
            else:
                kv_ids = jnp.arange(nk)
            (m, l, acc), _ = jax.lax.scan(kv_step, (m0, l0, a0), kv_ids)
        out = acc / jnp.maximum(l, 1e-30)[..., None]
        return out  # (b, h, c, dh)

    if unroll:
        outs = jnp.stack([
            process_q_chunk(iq, qg[:, iq]) for iq in range(nq)
        ])
    else:
        outs = jax.lax.map(
            lambda iq: process_q_chunk(iq, jax.lax.dynamic_index_in_dim(qg, iq, 1, keepdims=False)),
            jnp.arange(nq),
        )  # (nq, b, h, c, dh)
    out = jnp.moveaxis(outs, 0, 2)  # (b, h, nq, c, dh)
    out = out.reshape(b, h, s, dh).transpose(0, 2, 1, 3)
    return out.astype(q.dtype)


def decode_attention(
    q: jnp.ndarray,
    k_cache: jnp.ndarray,
    v_cache: jnp.ndarray,
    cache_len: jnp.ndarray,
    *,
    window: int | None = None,
    softmax_scale: float | None = None,
) -> jnp.ndarray:
    """Single-token decode against a KV cache.

    q (b, 1, h, dh); caches (b, L, g, dh); ``cache_len`` (scalar/int) =
    number of valid cache entries INCLUDING the current token.
    """
    b, _, h, dh = q.shape
    scale = softmax_scale or dh**-0.5
    kf = expand_kv(k_cache, h, rule="cache_blgd")
    vf = expand_kv(v_cache, h, rule="cache_blgd")
    logits = jnp.einsum(
        "bshd,bthd->bhst",
        (q * scale).astype(jnp.float32),
        kf.astype(jnp.float32),
    )
    kpos = jnp.arange(k_cache.shape[1])
    mask = kpos < cache_len
    if window is not None:
        mask &= kpos > cache_len - 1 - window
    logits = jnp.where(mask[None, None, None], logits, -1e30)
    p = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bhst,bthd->bshd", p.astype(vf.dtype), vf)
    return out


# --- MLPs --------------------------------------------------------------------


def gated_mlp(x, w_gate, w_up, w_down, kind: str = "swiglu"):
    gate = x @ w_gate
    up = x @ w_up
    if kind == "swiglu":
        act = jax.nn.silu(gate.astype(jnp.float32)).astype(x.dtype)
    elif kind == "geglu":
        act = jax.nn.gelu(
            gate.astype(jnp.float32), approximate=True
        ).astype(x.dtype)
    else:
        raise ValueError(kind)
    return (act * up) @ w_down


def gelu_mlp(x, w_in, b_in, w_out, b_out):
    h = jax.nn.gelu((x @ w_in + b_in).astype(jnp.float32), approximate=True)
    return h.astype(x.dtype) @ w_out + b_out


# --- init helpers ------------------------------------------------------------


def dense_init(key, shape, scale: float | None = None, dtype=jnp.float32):
    """Normal init scaled by fan_in^-1/2. For stacked layer params
    (L, d_in, d_out) the fan-in is the SECOND-TO-LAST dim, not the layer
    axis."""
    fan_in = shape[-2] if len(shape) >= 2 else shape[-1]
    scale = scale if scale is not None else max(fan_in, 1) ** -0.5
    return (jax.random.normal(key, shape) * scale).astype(dtype)


class KVCache(NamedTuple):
    """Per-layer-stacked decode cache."""

    k: jnp.ndarray  # (L, b, max_len, g, dh)
    v: jnp.ndarray
    length: jnp.ndarray  # scalar int32: valid entries


def token_xent(
    logits: jnp.ndarray,
    labels: jnp.ndarray,
    mask: jnp.ndarray | None = None,
) -> jnp.ndarray:
    """Mean next-token cross-entropy, sharding-friendly.

    The gold logit is extracted with an iota-compare + masked sum over
    the vocab axis rather than ``take_along_axis``: under vocab-parallel
    logits the gather would force GSPMD to all-gather the whole logits
    tensor (tokens × vocab bytes on the wire), while the masked sum
    reduces to a per-token psum.

    The f32 cast is re-constrained: sharding constraints bind the
    COTANGENT too, keeping the (tokens × vocab) f32 loss gradient
    vocab-sharded through the backward dot (without this, GSPMD
    all-gathers the full-vocab f32 cotangent — tens of GiB; measured in
    EXPERIMENTS.md §Perf).
    """
    from repro.distrib.sharding import constrain

    lf = constrain(logits.astype(jnp.float32), "logits_bsv")
    lse = jax.scipy.special.logsumexp(lf, axis=-1)
    iota = jax.lax.broadcasted_iota(jnp.int32, lf.shape, lf.ndim - 1)
    gold = jnp.sum(
        jnp.where(iota == labels[..., None], lf, 0.0), axis=-1
    )
    if mask is None:
        mask = jnp.ones_like(labels, jnp.float32)
    return jnp.sum((lse - gold) * mask) / jnp.maximum(jnp.sum(mask), 1.0)
