"""Mixture-of-Experts FFN (mixtral-8x7b, qwen3-moe-30b-a3b).

Baseline dispatch is the GShard/Switch grouped-einsum formulation — the
GSPMD-proven layout: tokens are split into groups (sharded over the DP
axes), a capacity-bounded one-hot dispatch tensor routes each group's
tokens to experts, and expert FFNs run as batched einsums with the expert
dim sharded over ``model`` (EP) when E ≥ mesh-model, or the expert hidden
dim sharded (expert-TP) when E < mesh-model (mixtral: 8 experts on a
16-way model axis).

The dispatch einsum burns ~5-10% extra MXU FLOPs vs an all-to-all
permutation — that trade is measured and attacked in EXPERIMENTS.md §Perf
(the a2a shard_map variant lives in repro.distrib.moe_a2a).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.distrib.sharding import constrain
from repro.models import layers as L
from repro.models.config import ModelConfig


def init_moe_params(cfg: ModelConfig, key, n_layers: int):
    E, d, f = cfg.n_experts, cfg.d_model, cfg.d_ff_expert
    ks = jax.random.split(key, 4)
    return {
        "router": L.dense_init(ks[0], (n_layers, d, E)),
        "w_gate": L.dense_init(ks[1], (n_layers, E, d, f)),
        "w_up": L.dense_init(ks[2], (n_layers, E, d, f)),
        "w_down": L.dense_init(ks[3], (n_layers, E, f, d)),
    }


def _group(x: jnp.ndarray, group_size: int = 1024):
    """(b, s, d) → (G, Tg, d) with Tg | b·s."""
    b, s, d = x.shape
    t = b * s
    tg = min(group_size, t)
    while t % tg:
        tg -= 1
    return x.reshape(t // tg, tg, d), tg


def router_topk(
    logits: jnp.ndarray, k: int
) -> tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Softmax router with renormalized top-k gates.

    logits (G, T, E) → (gates (G, T, k), idx (G, T, k), probs (G, T, E)).
    """
    probs = jax.nn.softmax(logits.astype(jnp.float32), axis=-1)
    vals, idx = jax.lax.top_k(probs, k)
    gates = vals / jnp.maximum(vals.sum(-1, keepdims=True), 1e-9)
    return gates, idx, probs


def moe_ffn(
    x: jnp.ndarray,
    p: dict,
    cfg: ModelConfig,
    *,
    group_size: int = 1024,
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Grouped capacity-based MoE FFN → (out (b, s, d), aux loss scalar)."""
    b, s, d = x.shape
    E, K = cfg.n_experts, cfg.top_k
    xg, tg = _group(x, group_size)
    G = xg.shape[0]
    cap = max(int(tg * K / E * cfg.capacity_factor), 1)

    logits = xg.astype(jnp.float32) @ p["router"].astype(jnp.float32)
    gates, idx, probs = router_topk(logits, K)  # (G,Tg,K) ×2, (G,Tg,E)

    # Expert selection mask summed over the K choices: (G, Tg, E).
    sel_k = jax.nn.one_hot(idx, E, dtype=jnp.float32)  # (G,Tg,K,E)
    # Priority: k-th choices compete in (k, token) order — flatten K into
    # the position axis ahead of tokens so 1st choices never get dropped
    # in favour of 2nd choices (GShard's priority rule).
    sel_kt = jnp.swapaxes(sel_k, 1, 2).reshape(G, K * tg, E)
    pos_kt = jnp.cumsum(sel_kt, axis=1) - 1.0  # position within expert
    pos = jnp.swapaxes(pos_kt.reshape(G, K, tg, E), 1, 2)  # (G,Tg,K,E)
    keep = (pos < cap) & (sel_k > 0)

    # dispatch (G,Tg,E,C): one-hot over capacity slots.
    pos_id = jnp.where(keep, pos, 0).astype(jnp.int32)
    slot = jax.nn.one_hot(pos_id, cap, dtype=jnp.float32) * keep[..., None]
    dispatch = slot.sum(axis=2)  # sum over K → (G,Tg,E,C)
    combine = (slot * gates[..., None, None]).sum(axis=2)

    dtype = x.dtype
    xe = jnp.einsum("gtec,gtd->gecd", dispatch.astype(dtype), xg)
    xe = constrain(xe, "moe_gecd")
    h_gate = jnp.einsum("gecd,edf->gecf", xe, p["w_gate"].astype(dtype))
    h_up = jnp.einsum("gecd,edf->gecf", xe, p["w_up"].astype(dtype))
    h = jax.nn.silu(h_gate.astype(jnp.float32)).astype(dtype) * h_up
    h = constrain(h, "moe_gecf")
    ye = jnp.einsum("gecf,efd->gecd", h, p["w_down"].astype(dtype))
    y = jnp.einsum("gtec,gecd->gtd", combine.astype(dtype), ye)

    # Switch load-balance auxiliary: E · Σ_e f̄_e · P̄_e.
    f_e = jnp.mean(sel_k.sum(2), axis=1)  # (G, E) fraction routed
    p_e = jnp.mean(probs, axis=1)
    aux = E * jnp.mean(jnp.sum(f_e * p_e, axis=-1)) / K
    return y.reshape(b, s, d), aux.astype(jnp.float32)
