"""Modeled per-chip HBM traffic — the *fused* memory roofline term.

``compiled.cost_analysis()['bytes accessed']`` sums every HLO op's
operand+result bytes as if nothing were fused — on the CPU backend this
overstates real HBM traffic by 10-30× (every intermediate counted).
We report that number as the spec'd upper bound, AND this analytic model
of what a fused TPU execution actually moves:

train step (per chip):
  params     f32 master read + bf16 cast write/read + f32 write (update)
  adam       mu, nu: read + write (f32)
  grads      write + read (f32)
  acts       remat-saved activations: write (fwd) + read (bwd)
  logits     write + read of the sharded logits block (f32-equivalent)
  batch      token ids + label reads (negligible, included)

decode step (per chip):
  params     one bf16-equivalent read of ACTIVE params
  kv/state   full cache read + one-slot write

prefill:
  params     one bf16 read
  acts       write+read once per layer boundary (no backward)

Assumptions (documented in EXPERIMENTS.md §Roofline): parameters are
TP-sharded over `model` (÷M), FSDP additionally over `data`; activations
are DP-sharded (÷D on tokens) with hidden dims TP-sharded where the rules
shard them. Within ~2× of a real profile, which is what a roofline term
needs.

The second half of this module models *stencil* HBM traffic under
temporal fusion (fuse_steps in-kernel time steps on halo-widened
blocks): what one simulated time step moves through HBM as a function
of (block, radii, depth), with a separate function for the explicit-
streaming kernel (whose carried halo planes eliminate the stream-axis
halo re-fetch). ``repro.tuning.costmodel`` scores its joint
(block, fuse_steps, stream) candidates through these exact functions,
so the autotuner's temporal/streaming terms and the reported traffic
model cannot diverge.
"""
from __future__ import annotations

from typing import Sequence

from repro.models.config import ModelConfig


def _act_bytes_per_token_layer(cfg: ModelConfig, model_ways: int) -> float:
    """Remat-saved bytes per token per layer (bf16), TP-sharded dims ÷M."""
    d = cfg.d_model
    m = model_ways
    if cfg.family == "ssm":
        dv = cfg.d_inner
        # in_proj out (2dv+2gn+h)/M, conv out, ssd y, out_proj in
        per = (2 * dv + 2 * cfg.ssm_n_groups * cfg.ssm_state) / m * 3 + d
    elif cfg.hybrid_pattern:
        w = cfg.lru_width or d
        per = (3 * w / m + d) * 2 / 3 + (  # rec blocks (2 of 3)
            (cfg.n_heads + 2 * cfg.n_kv_heads) * cfg.hd / m + d
            + 2 * cfg.d_ff / m
        ) / 3
    else:
        ff = cfg.d_ff_expert * cfg.top_k if cfg.family == "moe" else cfg.d_ff
        per = (
            (cfg.n_heads + 2 * cfg.n_kv_heads) * cfg.hd / m  # qkv
            + cfg.n_heads * cfg.hd / m  # attn out
            + 2 * ff / m  # gate/up
            + 2 * d  # residual stream saves
        )
    return per * 2.0  # bf16


def modeled_hbm_bytes(
    cfg: ModelConfig,
    kind: str,
    seq: int,
    global_batch: int,
    *,
    model_ways: int,
    dp_ways: int,
    fsdp: bool = False,
) -> float:
    """Per-chip HBM bytes for one step of ``kind``."""
    n_params = cfg.n_params()
    n_active = cfg.n_active_params()
    p_shard = n_params / model_ways / (dp_ways if fsdp else 1)
    eff_seq = cfg.max_target_len if cfg.is_encdec and kind != "prefill" else seq
    tokens_chip = global_batch * eff_seq / dp_ways / max(
        1, (model_ways if kind != "decode" else 1)
    )
    # sequence is model-sharded (SP) in train/prefill; decode has 1 token.
    if kind == "decode":
        tokens_chip = max(global_batch / dp_ways, 1.0)

    n_layers = cfg.n_layers + cfg.n_encoder_layers

    if kind == "train":
        param_traffic = p_shard * (4 + 4 + 2 + 2)  # f32 r/w + bf16 w/r
        opt_traffic = p_shard * 4 * 4  # mu, nu r+w
        grad_traffic = p_shard * 4 * 2
        act = tokens_chip * n_layers * _act_bytes_per_token_layer(
            cfg, model_ways
        ) * 2.0  # write fwd + read bwd
        logits = tokens_chip * (cfg.vocab / model_ways) * 2 * 3
        return param_traffic + opt_traffic + grad_traffic + act + logits
    if kind == "prefill":
        param_traffic = p_shard * 2  # bf16-equivalent read
        act = tokens_chip * n_layers * _act_bytes_per_token_layer(
            cfg, model_ways
        )
        return param_traffic + act
    # decode
    p_active_shard = n_active / model_ways
    param_traffic = p_active_shard * 2  # bf16 read per token
    cache = _decode_cache_bytes(cfg, seq, global_batch, dp_ways, model_ways)
    return param_traffic + cache


def _decode_cache_bytes(
    cfg: ModelConfig, seq: int, global_batch: int, dp_ways: int,
    model_ways: int,
) -> float:
    b_chip = max(global_batch / dp_ways, 1.0)
    if cfg.family == "ssm":
        dv = cfg.d_inner
        state = (
            cfg.ssm_n_heads * cfg.ssm_state * cfg.ssm_head_dim
            + (cfg.ssm_conv_kernel - 1)
            * (dv + 2 * cfg.ssm_n_groups * cfg.ssm_state)
        )
        shard = model_ways  # heads/channels sharded
        return b_chip * cfg.n_layers * state / shard * 4 * 2  # f32 r+w
    if cfg.hybrid_pattern:
        n_rec = cfg.n_layers - cfg.n_layers // cfg.hybrid_pattern
        n_att = cfg.n_layers // cfg.hybrid_pattern
        w = cfg.lru_width or cfg.d_model
        rec = b_chip * n_rec * (
            w + (cfg.ssm_conv_kernel - 1) * w
        ) / model_ways * 4 * 2
        win = min(cfg.local_window, seq)
        att = b_chip * n_att * win * cfg.n_kv_heads * cfg.hd * 2
        return rec + att
    cache_len = (
        min(cfg.sliding_window, seq) if cfg.sliding_window else seq
    )
    kv_shard = model_ways if cfg.n_kv_heads % model_ways == 0 else 1
    return (
        b_chip * cfg.n_layers * cache_len * 2  # k and v
        * cfg.n_kv_heads * cfg.hd / kv_shard * 2  # bf16
    )


# ---------------------------------------------------------------------------
# Stencil temporal-fusion traffic (the fused-kernel bandwidth lever).
# ---------------------------------------------------------------------------


def _ceil_div(a: int, b: int) -> int:
    return -(-a // b)


def stencil_hbm_bytes_per_step(
    domain: Sequence[int],
    block: Sequence[int],
    radii: Sequence[int],
    n_f: int,
    n_out: int,
    itemsize: int,
    fuse_steps: int = 1,
) -> float:
    """Modeled HBM bytes moved per simulated TIME step.

    One kernel launch stages, per block, the tile plus a halo widened to
    ``radii * fuse_steps`` (reads), writes the interior tile once, and
    advances ``fuse_steps`` steps — so the per-step traffic is the whole
    launch divided by the depth. Depth 1 reduces to the classic
    read-tile-plus-halo / write-tile model.
    """
    if fuse_steps < 1:
        raise ValueError(f"fuse_steps must be >= 1, got {fuse_steps}")
    n_blocks, read_per_block, points = 1, n_f, 1
    for n, t, r in zip(domain, block, radii):
        n_blocks *= _ceil_div(n, t)
        read_per_block *= t + 2 * r * fuse_steps
        points *= n
    read = n_blocks * read_per_block
    write = n_out * points
    return (read + write) * itemsize / fuse_steps


def stencil_stream_hbm_bytes_per_step(
    domain: Sequence[int],
    block: Sequence[int],
    radii: Sequence[int],
    n_f: int,
    n_out: int,
    itemsize: int,
    fuse_steps: int = 1,
) -> float:
    """Modeled HBM bytes per simulated TIME step for the explicit-
    streaming kernel (``swc_stream``, paper Fig. 5b), any fuse depth.

    The stream walks axis 0 (z at rank 3, y at rank 2) carrying
    ``2·r₀·fuse_steps`` halo planes in VMEM between chunks, so — unlike
    the pipelined model, which re-fetches the stream-axis halo for every
    block — each cross-stream tile column reads the full stream extent
    plus ONE leading/trailing halo: ``N₀ + 2·r₀·S`` planes of the
    ``Π(τ_a + 2·r_a·S)`` cross window. Cross-axis halos are still
    re-fetched per tile column. The interior is written once; a launch
    advances ``fuse_steps`` steps, so the total is divided by the depth.
    """
    if fuse_steps < 1:
        raise ValueError(f"fuse_steps must be >= 1, got {fuse_steps}")
    n_cols, read_per_col, points = 1, n_f, 1
    for a, (n, t, r) in enumerate(zip(domain, block, radii)):
        points *= n
        if a == 0:
            read_per_col *= n + 2 * r * fuse_steps
        else:
            n_cols *= _ceil_div(n, t)
            read_per_col *= t + 2 * r * fuse_steps
    read = n_cols * read_per_col
    write = n_out * points
    return (read + write) * itemsize / fuse_steps


# Fixed per-launch overhead charged by the batched per-member model:
# grid bookkeeping, kernel argument marshalling, and the pipeline's
# prologue/epilogue DMA ramp, expressed as equivalent HBM bytes. One
# batched launch walking B members amortizes this over the whole
# ensemble (and over the fuse depth), which is exactly the lever the
# batch axis pulls — B vmap'd launches would each pay it in full.
STENCIL_LAUNCH_OVERHEAD_BYTES = 64 * 1024


def stencil_batched_hbm_bytes_per_member_step(
    domain: Sequence[int],
    block: Sequence[int],
    radii: Sequence[int],
    n_f: int,
    n_out: int,
    itemsize: int,
    *,
    batch: int = 1,
    fuse_steps: int = 1,
    stream: bool = False,
    launch_overhead_bytes: float = STENCIL_LAUNCH_OVERHEAD_BYTES,
) -> float:
    """Modeled HBM bytes per ENSEMBLE MEMBER per simulated time step
    for a batched launch walking ``batch`` members per block.

    The field/halo traffic itself is per-member (every member's tile
    and halo must move regardless of batching — the per-member byte
    functions above already describe it), but the fixed per-launch
    overhead (:data:`STENCIL_LAUNCH_OVERHEAD_BYTES`) is paid once per
    launch and divides across all ``batch`` members and ``fuse_steps``
    in-kernel sweeps. Per-member bytes therefore strictly decrease in
    ``batch`` (for any positive overhead), which is the quantity the
    batched candidate enumeration ranks.
    """
    if batch < 1:
        raise ValueError(f"batch must be >= 1, got {batch}")
    bytes_fn = (
        stencil_stream_hbm_bytes_per_step
        if stream
        else stencil_hbm_bytes_per_step
    )
    member = bytes_fn(
        domain, block, radii, n_f, n_out, itemsize, fuse_steps
    )
    return member + launch_overhead_bytes / (batch * fuse_steps)


def stencil_redundant_compute_fraction(
    block: Sequence[int],
    radii: Sequence[int],
    fuse_steps: int = 1,
) -> float:
    """Extra stencil evaluations per useful output point under temporal
    fusion: sweep ``s`` of ``S`` covers the tile plus a
    ``radii * (S - 1 - s)`` margin (the valid region shrinks one radius
    per sweep), so fused blocks recompute halo points the unfused
    schedule would have read from HBM. Returns 0.0 at depth 1.
    """
    tile = 1
    for t in block:
        tile *= t
    total = 0
    for s in range(fuse_steps):
        vol = 1
        for t, r in zip(block, radii):
            vol *= t + 2 * r * (fuse_steps - 1 - s)
        total += vol
    return total / (fuse_steps * tile) - 1.0


# ---------------------------------------------------------------------------
# MXU compute model (the tc caching regime: stencils as banded matmuls).
# ---------------------------------------------------------------------------

# Peak matrix-unit FLOP/s at f32 accumulation with f32 inputs, keyed by
# a substring of the JAX device_kind / backend description. bf16 inputs
# run the MXU at double rate (see :func:`peak_mxu_flops`). The default
# matches ``repro.core.rooflinelib.TPU_V5E``.
PEAK_MXU_FLOPS_F32: dict[str, float] = {
    "v4": 137.5e12,
    "v5e": 98.5e12,
    "v5p": 229.5e12,
    "v6e": 459.5e12,
}
DEFAULT_PEAK_MXU_FLOPS_F32 = 98.5e12  # v5e-class

# Peak HBM bandwidth (bytes/s) per platform, same keying; used to
# normalize the tc compute term against the bandwidth roof the traffic
# scores are expressed in.
PEAK_HBM_BW: dict[str, float] = {
    "v4": 1228e9,
    "v5e": 819e9,
    "v5p": 2765e9,
    "v6e": 1640e9,
}
DEFAULT_PEAK_HBM_BW = 819e9  # v5e-class


def peak_mxu_flops(
    backend: str | None = None, itemsize: int = 4
) -> float:
    """Platform peak MXU FLOP/s for the given input itemsize.

    ``backend`` is matched as a substring against the platform table
    (e.g. a device_kind like ``"TPU v5e"``); unknown/None falls back to
    the v5e-class default. bf16 inputs (itemsize 2) double the rate —
    the f32-accumulate contract the tc emitter lowers with.
    """
    base = DEFAULT_PEAK_MXU_FLOPS_F32
    if backend:
        b = backend.lower()
        for key, v in PEAK_MXU_FLOPS_F32.items():
            if key in b:
                base = v
                break
    return base * (2.0 if itemsize == 2 else 1.0)


def peak_hbm_bw(backend: str | None = None) -> float:
    """Platform peak HBM bandwidth (bytes/s), same substring matching
    as :func:`peak_mxu_flops`."""
    if backend:
        b = backend.lower()
        for key, v in PEAK_HBM_BW.items():
            if key in b:
                return v
    return DEFAULT_PEAK_HBM_BW


# Peak vector-unit (VPU) FLOP/s at f32, same keying as the MXU table.
# TPU VPUs sustain roughly a quarter of the matrix-unit f32 rate (8×128
# lanes × 2 ALU slots vs the 128×128 systolic array), which is the rate
# the tap-by-tap swc/swc_stream/hwc regimes run their multiply-adds at.
# The generalized-order cost model normalizes per-point stencil FLOPs
# against this roof to weigh temporal fusion's redundant halo compute —
# an order-2 operator (few taps) tolerates deep fusion where an order-8
# one (4× the taps) may not.
PEAK_VPU_FLOPS_F32: dict[str, float] = {
    "v4": 34.375e12,
    "v5e": 24.625e12,
    "v5p": 57.375e12,
    "v6e": 114.875e12,
}
DEFAULT_PEAK_VPU_FLOPS_F32 = 24.625e12  # v5e-class


def peak_vpu_flops(backend: str | None = None) -> float:
    """Platform peak VPU (vector unit) FLOP/s, same substring matching
    as :func:`peak_mxu_flops`. Element-wise rate is dtype-agnostic on
    the f32-wide VPU, so there is no itemsize scaling."""
    if backend:
        b = backend.lower()
        for key, v in PEAK_VPU_FLOPS_F32.items():
            if key in b:
                return v
    return DEFAULT_PEAK_VPU_FLOPS_F32


def stencil_mxu_flops_per_step(
    domain: Sequence[int],
    block: Sequence[int],
    radii: Sequence[int],
    n_f: int,
    fuse_steps: int = 1,
    *,
    groups_per_axis: Sequence[int] | None = None,
) -> float:
    """Modeled MXU FLOPs per simulated TIME step of a ``tc`` plan.

    Each multi-tap contraction group on axis ``a`` (see
    :func:`~repro.kernels.plan.tc_groups_per_axis`) contracts the FULL
    staged window extent — the banded matrix is dense as far as the MXU
    is concerned, zeros included — so the per-point cost is
    ``2 · (τ_a + 2·r_a·(margin+1))`` FLOPs per group, growing with the
    tile, not the tap count. That tile dependence is exactly the
    VPU/MXU trade-off the cost model must see: big tiles amortize halo
    traffic but inflate matmul work. Temporal sweeps evaluate over the
    shrinking sub-windows (margin ``S-1-s``), and a launch advances
    ``fuse_steps`` steps, so the total divides by the depth.

    ``groups_per_axis`` defaults to one matmul group per axis (a star
    stencil like fused diffusion).
    """
    if fuse_steps < 1:
        raise ValueError(f"fuse_steps must be >= 1, got {fuse_steps}")
    rank = len(tuple(block))
    groups = (
        (1,) * rank
        if groups_per_axis is None
        else tuple(groups_per_axis)
    )
    n_blocks = 1
    for n, t in zip(domain, block):
        n_blocks *= _ceil_div(n, t)
    total = 0.0
    for s in range(fuse_steps):
        margin = fuse_steps - 1 - s
        sub = [
            t + 2 * r * margin for t, r in zip(block, radii)
        ]
        vol = 1
        for x in sub:
            vol *= x
        per_point = sum(
            2.0 * g * (sub[a] + 2 * radii[a])
            for a, g in enumerate(groups)
        )
        total += vol * per_point
    return n_blocks * n_f * total / fuse_steps


def stencil_traffic_reduction(
    domain: Sequence[int],
    radii: Sequence[int],
    n_f: int,
    n_out: int,
    itemsize: int,
    *,
    block_base: Sequence[int],
    block_fused: Sequence[int],
    fuse_steps: int,
    stream: bool = False,
) -> float:
    """Modeled per-step HBM-traffic reduction of a fused configuration
    over its depth-1 baseline (>1 means the fused plan moves less).
    ``stream=True`` models both sides with the explicit-streaming
    kernel's byte function instead of the pipelined one."""
    bytes_fn = (
        stencil_stream_hbm_bytes_per_step
        if stream
        else stencil_hbm_bytes_per_step
    )
    base = bytes_fn(domain, block_base, radii, n_f, n_out, itemsize, 1)
    fused = bytes_fn(
        domain, block_fused, radii, n_f, n_out, itemsize, fuse_steps
    )
    return base / fused
