"""Modeled per-chip HBM traffic — the *fused* memory roofline term.

``compiled.cost_analysis()['bytes accessed']`` sums every HLO op's
operand+result bytes as if nothing were fused — on the CPU backend this
overstates real HBM traffic by 10-30× (every intermediate counted).
We report that number as the spec'd upper bound, AND this analytic model
of what a fused TPU execution actually moves:

train step (per chip):
  params     f32 master read + bf16 cast write/read + f32 write (update)
  adam       mu, nu: read + write (f32)
  grads      write + read (f32)
  acts       remat-saved activations: write (fwd) + read (bwd)
  logits     write + read of the sharded logits block (f32-equivalent)
  batch      token ids + label reads (negligible, included)

decode step (per chip):
  params     one bf16-equivalent read of ACTIVE params
  kv/state   full cache read + one-slot write

prefill:
  params     one bf16 read
  acts       write+read once per layer boundary (no backward)

Assumptions (documented in EXPERIMENTS.md §Roofline): parameters are
TP-sharded over `model` (÷M), FSDP additionally over `data`; activations
are DP-sharded (÷D on tokens) with hidden dims TP-sharded where the rules
shard them. Within ~2× of a real profile, which is what a roofline term
needs.
"""
from __future__ import annotations

from repro.models.config import ModelConfig


def _act_bytes_per_token_layer(cfg: ModelConfig, model_ways: int) -> float:
    """Remat-saved bytes per token per layer (bf16), TP-sharded dims ÷M."""
    d = cfg.d_model
    m = model_ways
    if cfg.family == "ssm":
        dv = cfg.d_inner
        # in_proj out (2dv+2gn+h)/M, conv out, ssd y, out_proj in
        per = (2 * dv + 2 * cfg.ssm_n_groups * cfg.ssm_state) / m * 3 + d
    elif cfg.hybrid_pattern:
        w = cfg.lru_width or d
        per = (3 * w / m + d) * 2 / 3 + (  # rec blocks (2 of 3)
            (cfg.n_heads + 2 * cfg.n_kv_heads) * cfg.hd / m + d
            + 2 * cfg.d_ff / m
        ) / 3
    else:
        ff = cfg.d_ff_expert * cfg.top_k if cfg.family == "moe" else cfg.d_ff
        per = (
            (cfg.n_heads + 2 * cfg.n_kv_heads) * cfg.hd / m  # qkv
            + cfg.n_heads * cfg.hd / m  # attn out
            + 2 * ff / m  # gate/up
            + 2 * d  # residual stream saves
        )
    return per * 2.0  # bf16


def modeled_hbm_bytes(
    cfg: ModelConfig,
    kind: str,
    seq: int,
    global_batch: int,
    *,
    model_ways: int,
    dp_ways: int,
    fsdp: bool = False,
) -> float:
    """Per-chip HBM bytes for one step of ``kind``."""
    n_params = cfg.n_params()
    n_active = cfg.n_active_params()
    p_shard = n_params / model_ways / (dp_ways if fsdp else 1)
    eff_seq = cfg.max_target_len if cfg.is_encdec and kind != "prefill" else seq
    tokens_chip = global_batch * eff_seq / dp_ways / max(
        1, (model_ways if kind != "decode" else 1)
    )
    # sequence is model-sharded (SP) in train/prefill; decode has 1 token.
    if kind == "decode":
        tokens_chip = max(global_batch / dp_ways, 1.0)

    n_layers = cfg.n_layers + cfg.n_encoder_layers

    if kind == "train":
        param_traffic = p_shard * (4 + 4 + 2 + 2)  # f32 r/w + bf16 w/r
        opt_traffic = p_shard * 4 * 4  # mu, nu r+w
        grad_traffic = p_shard * 4 * 2
        act = tokens_chip * n_layers * _act_bytes_per_token_layer(
            cfg, model_ways
        ) * 2.0  # write fwd + read bwd
        logits = tokens_chip * (cfg.vocab / model_ways) * 2 * 3
        return param_traffic + opt_traffic + grad_traffic + act + logits
    if kind == "prefill":
        param_traffic = p_shard * 2  # bf16-equivalent read
        act = tokens_chip * n_layers * _act_bytes_per_token_layer(
            cfg, model_ways
        )
        return param_traffic + act
    # decode
    p_active_shard = n_active / model_ways
    param_traffic = p_active_shard * 2  # bf16 read per token
    cache = _decode_cache_bytes(cfg, seq, global_batch, dp_ways, model_ways)
    return param_traffic + cache


def _decode_cache_bytes(
    cfg: ModelConfig, seq: int, global_batch: int, dp_ways: int,
    model_ways: int,
) -> float:
    b_chip = max(global_batch / dp_ways, 1.0)
    if cfg.family == "ssm":
        dv = cfg.d_inner
        state = (
            cfg.ssm_n_heads * cfg.ssm_state * cfg.ssm_head_dim
            + (cfg.ssm_conv_kernel - 1)
            * (dv + 2 * cfg.ssm_n_groups * cfg.ssm_state)
        )
        shard = model_ways  # heads/channels sharded
        return b_chip * cfg.n_layers * state / shard * 4 * 2  # f32 r+w
    if cfg.hybrid_pattern:
        n_rec = cfg.n_layers - cfg.n_layers // cfg.hybrid_pattern
        n_att = cfg.n_layers // cfg.hybrid_pattern
        w = cfg.lru_width or cfg.d_model
        rec = b_chip * n_rec * (
            w + (cfg.ssm_conv_kernel - 1) * w
        ) / model_ways * 4 * 2
        win = min(cfg.local_window, seq)
        att = b_chip * n_att * win * cfg.n_kv_heads * cfg.hd * 2
        return rec + att
    cache_len = (
        min(cfg.sliding_window, seq) if cfg.sliding_window else seq
    )
    kv_shard = model_ways if cfg.n_kv_heads % model_ways == 0 else 1
    return (
        b_chip * cfg.n_layers * cache_len * 2  # k and v
        * cfg.n_kv_heads * cfg.hd / kv_shard * 2  # bf16
    )
