"""Boundary value functions β(f, i) — paper Eq. 2 — and
boundary-modified derivative operators for non-periodic domains.

The augmented array f̂ extends the computational domain by the stencil
influence radius. Supported boundary families map to the padding modes
used by the paper's test problems (periodic 2π domains for diffusion/MHD)
plus the usual PDE suspects.

Ghost-cell accuracy orders (what each padding mode is worth near a
wall, regardless of the interior operator's order):

* ``periodic``  — exact: the wrap IS the solution's continuation.
* ``dirichlet`` — the constant ghost value is a 0th-order extrapolation
  of the solution unless the true boundary value is that constant; even
  then derivatives above the wall value degrade to O(h).
* ``neumann``   — edge replicate: models ∂f/∂n = 0 by a piecewise-
  constant extension, a FIRST-order ghost fill (the mirror point
  f(-h) = f(0) forces f'(0) = 0 only to O(h)). Kept under this name
  for backward compatibility; see ``neumann2``.
* ``neumann2``  — mirror about the boundary NODE (ghost ``f(-h) =
  f(h)``): the even extension, which enforces f'(0) = 0 to SECOND
  order on a vertex-centered grid. This is the textbook ghost fill for
  zero-gradient walls and what the "neumann" mode should have been;
  the MMS convergence suite regression-tests the one-order slope gap
  between the two.
* ``reflect``   — same even extension as ``neumann2`` (mirror about the
  boundary cell), named for its geometric reading.

Any ghost fill caps the wall accuracy at its own order. To keep the
FULL interior order up to the wall, :func:`derivative_matrix_1d` builds
boundary-MODIFIED weight rows instead: within ``r`` cells of a
non-periodic face the centered stencil is replaced by an offset
(one-sided) stencil of the same order evaluated entirely on interior
samples — no ghost data at all — following the Fornberg-weight
construction (``repro.core.stencil.offset_difference_coeffs``).
:func:`apply_operator_set_bc` evaluates a whole generated operator set
that way; the fusion layer blends it over the wall-adjacent cells of
the fast padded kernel output (``FusedStencilOp(boundary_weights=
True)``).
"""
from __future__ import annotations

from functools import lru_cache
from typing import Sequence

import jax.numpy as jnp
import numpy as np

_MODES = ("periodic", "dirichlet", "neumann", "neumann2", "reflect")

# jnp.pad mode implementing each boundary family ("dirichlet" handled
# separately — it needs constant_values).
_PAD_MODE = {
    "periodic": "wrap",
    "neumann": "edge",
    "neumann2": "reflect",
    "reflect": "reflect",
}


def _normalize_modes(
    mode: str | Sequence[str], n_axes: int
) -> tuple[str, ...]:
    """Per-axis mode tuple from a scalar or per-axis spec."""
    modes = (
        (mode,) * n_axes
        if isinstance(mode, str)
        else tuple(mode)
    )
    if len(modes) != n_axes:
        raise ValueError(
            f"got {len(modes)} boundary modes for {n_axes} spatial axes"
        )
    for m in modes:
        if m not in _MODES:
            raise ValueError(
                f"unknown boundary mode {m!r}; want one of {_MODES}"
            )
    return modes


def pad(
    f: jnp.ndarray,
    radius: int | Sequence[int],
    mode: str | Sequence[str] = "periodic",
    *,
    spatial_axes: Sequence[int] | None = None,
    value: float = 0.0,
) -> jnp.ndarray:
    """Construct f̂ by padding ``f`` with ``radius`` ghost cells per
    spatial axis.

    ``spatial_axes`` defaults to all axes. ``radius`` may be per-axis,
    and so may ``mode`` (one entry per spatial axis, e.g. a channel
    flow periodic along x but walled along y). Modes and their ghost
    accuracy orders are documented in the module docstring:
    ``periodic`` (wrap, the paper's setup), ``dirichlet`` (constant
    ``value``), ``neumann`` (zero-gradient edge replicate, 1st order),
    ``neumann2`` (mirror-about-node even extension, 2nd order) and
    ``reflect`` (same mirror, geometric name).
    """
    axes = tuple(range(f.ndim)) if spatial_axes is None else tuple(spatial_axes)
    modes = _normalize_modes(mode, len(axes))
    if isinstance(radius, int):
        radius = [radius] * len(axes)
    if len(radius) != len(axes):
        raise ValueError("radius/spatial_axes length mismatch")
    if len(set(modes)) == 1:
        # Uniform mode: one jnp.pad over all axes (the common case).
        pad_width = [(0, 0)] * f.ndim
        for a, r in zip(axes, radius):
            pad_width[a] = (int(r), int(r))
        return _pad_one(f, pad_width, modes[0], value)
    # Mixed per-axis modes: pad axis by axis. Corner ghost regions are
    # filled by composition (each axis's rule applied to the already-
    # padded neighbor), which is the standard ghost-corner treatment.
    out = f
    for a, r, m in zip(axes, radius, modes):
        pad_width = [(0, 0)] * f.ndim
        pad_width[a] = (int(r), int(r))
        out = _pad_one(out, pad_width, m, value)
    return out


def _pad_one(
    f: jnp.ndarray,
    pad_width: Sequence[tuple[int, int]],
    mode: str,
    value: float,
) -> jnp.ndarray:
    if mode == "dirichlet":
        return jnp.pad(f, pad_width, mode="constant", constant_values=value)
    return jnp.pad(f, pad_width, mode=_PAD_MODE[mode])


def unpad(
    f: jnp.ndarray,
    radius: int | Sequence[int],
    *,
    spatial_axes: Sequence[int] | None = None,
) -> jnp.ndarray:
    """Inverse of :func:`pad` — strip ghost cells."""
    axes = tuple(range(f.ndim)) if spatial_axes is None else tuple(spatial_axes)
    if isinstance(radius, int):
        radius = [radius] * len(axes)
    slicer: list[slice] = [slice(None)] * f.ndim
    for a, r in zip(axes, radius):
        slicer[a] = slice(int(r), f.shape[a] - int(r)) if r else slice(None)
    return f[tuple(slicer)]


# ---------------------------------------------------------------------------
# Boundary-modified weight rows (the full-order alternative to ghost
# fills near non-periodic surfaces).
# ---------------------------------------------------------------------------


@lru_cache(maxsize=None)
def derivative_matrix_1d(
    n: int,
    deriv: int,
    accuracy: int,
    spacing: float = 1.0,
    mode: str = "dirichlet",
) -> np.ndarray:
    """Dense ``(n, n)`` derivative matrix with boundary-modified rows.

    Interior rows (``r ≤ i < n − r`` with ``r = radius``) carry the
    centered Fornberg weights of the requested ``accuracy``; for
    ``mode="periodic"`` the off-grid columns wrap, and for any
    non-periodic mode the first/last ``r`` rows are replaced by OFFSET
    stencils of the same nominal order
    (:func:`repro.core.stencil.offset_difference_coeffs`): row ``i < r``
    reads columns ``0..deriv+accuracy−1`` with the evaluation point at
    position ``i``, and symmetrically at the high wall. Offset rows are
    pure interpolation on interior samples — they use no ghost data,
    so the same matrix serves Dirichlet and Neumann walls (the PDE's
    boundary data enters through the solution values, not the weights),
    which is why the non-periodic modes all share one table.

    Rows scale by ``spacing**-deriv``. ``deriv=0`` is the identity.
    Raises ``ValueError`` for grids too small to hold the stencil.
    """
    from repro.core.stencil import (
        central_difference_coeffs,
        offset_difference_coeffs,
    )

    if mode not in _MODES:
        raise ValueError(
            f"unknown boundary mode {mode!r}; want one of {_MODES}"
        )
    if deriv == 0:
        return np.eye(n)
    center = central_difference_coeffs(deriv, accuracy)
    r = (len(center) - 1) // 2
    scale = float(spacing) ** (-deriv)
    D = np.zeros((n, n))
    if mode == "periodic":
        if n < len(center):
            raise ValueError(
                f"periodic grid of {n} points cannot hold the "
                f"{len(center)}-tap centered stencil"
            )
        for i in range(n):
            for k, w in enumerate(center, start=-r):
                D[i, (i + k) % n] += w * scale
        return D
    npts = deriv + accuracy
    if n < npts:
        raise ValueError(
            f"non-periodic grid of {n} points cannot hold the "
            f"{npts}-point offset stencil (deriv={deriv}, "
            f"accuracy={accuracy})"
        )
    for i in range(n):
        if r <= i < n - r:
            for k, w in enumerate(center, start=-r):
                D[i, i + k] += w * scale
        else:
            # Offset row: window pinned inside the domain, evaluation
            # point at `left` within it.
            left = i if i < r else i - (n - npts)
            w = offset_difference_coeffs(deriv, accuracy, left)
            D[i, i - left:i - left + npts] = np.asarray(w) * scale
    return D


def apply_operator_spec(
    f: jnp.ndarray,
    spec,
    mode: str | Sequence[str],
    *,
    spatial_axes: Sequence[int] | None = None,
) -> jnp.ndarray:
    """Evaluate one :class:`~repro.core.stencil.OperatorSpec` on the
    UNPADDED field with boundary-modified weight rows.

    Each term's per-axis derivative is applied as a dense
    :func:`derivative_matrix_1d` contraction along that spatial axis
    (full interior order up to the wall on non-periodic axes), terms
    summed with their coefficients. ``mode`` is scalar or per spatial
    axis; ``spatial_axes`` defaults to all of ``f``'s axes.
    """
    axes = (
        tuple(range(f.ndim))
        if spatial_axes is None
        else tuple(spatial_axes)
    )
    modes = _normalize_modes(mode, len(axes))
    out = None
    for dmi, coeff in spec.terms:
        if len(dmi) != len(axes):
            raise ValueError(
                f"term multi-index {dmi} does not match {len(axes)} "
                "spatial axes"
            )
        term = f
        for a, d in enumerate(dmi):
            if d == 0:
                continue
            if not spec.accuracy:
                raise ValueError(
                    "OperatorSpec with derivative terms must carry a "
                    "nonzero accuracy order for boundary-modified "
                    "evaluation"
                )
            h = float(spec.spacing[a]) if spec.spacing else 1.0
            D = derivative_matrix_1d(
                int(f.shape[axes[a]]), int(d), int(spec.accuracy),
                h, modes[a],
            )
            term = _apply_matrix(term, jnp.asarray(D, dtype=f.dtype), axes[a])
        out = coeff * term if out is None else out + coeff * term
    if out is None:
        raise ValueError("OperatorSpec has no terms")
    return out


def _apply_matrix(f: jnp.ndarray, D: jnp.ndarray, axis: int) -> jnp.ndarray:
    """Contract ``D`` (rows×cols) against ``f`` along ``axis``."""
    g = jnp.tensordot(f, D, axes=[[axis], [1]])
    return jnp.moveaxis(g, -1, axis)


def apply_operator_set_bc(
    f: jnp.ndarray,
    ops,
    mode: str | Sequence[str],
    *,
    spatial_axes: Sequence[int] | None = None,
) -> dict[str, jnp.ndarray]:
    """Boundary-accurate reference evaluation of a whole operator set:
    ``{name: derivative}`` on the UNPADDED field, each member evaluated
    through :func:`apply_operator_spec` (offset rows at non-periodic
    walls, wrap on periodic axes) — the full-order counterpart of
    ``repro.kernels.ref.apply_operator_set``, used by the fusion
    layer's ``boundary_weights`` blend and the MMS harness.

    Every member must carry :class:`OperatorSpec` metadata (generated
    operators do; hand-built tap sets raise).
    """
    out = {}
    for s in ops.ops:
        if s.spec is None:
            raise ValueError(
                f"operator {s.name!r} has no OperatorSpec metadata — "
                "boundary-modified weights need the generated "
                "(derivative, accuracy, spacing) description, not raw "
                "taps; build it with axis_stencil/laplacian_stencil/"
                "derivative_operator_set"
            )
        out[s.name] = apply_operator_spec(
            f, s.spec, mode, spatial_axes=spatial_axes
        )
    return out
