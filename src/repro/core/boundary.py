"""Boundary value functions β(f, i) — paper Eq. 2.

The augmented array f̂ extends the computational domain by the stencil
influence radius. Supported boundary families map to the padding modes
used by the paper's test problems (periodic 2π domains for diffusion/MHD)
plus the usual PDE suspects.
"""
from __future__ import annotations

from typing import Sequence

import jax.numpy as jnp

_MODES = ("periodic", "dirichlet", "neumann", "reflect")


def pad(
    f: jnp.ndarray,
    radius: int | Sequence[int],
    mode: str = "periodic",
    *,
    spatial_axes: Sequence[int] | None = None,
    value: float = 0.0,
) -> jnp.ndarray:
    """Construct f̂ by padding ``f`` with ``radius`` ghost cells per
    spatial axis.

    ``spatial_axes`` defaults to all axes. ``radius`` may be per-axis.
    Modes:
      * ``periodic`` — wrap (the paper's simulation setup);
      * ``dirichlet`` — constant ``value``;
      * ``neumann``   — zero-gradient (edge replicate);
      * ``reflect``   — mirror about the boundary cell.
    """
    if mode not in _MODES:
        raise ValueError(f"unknown boundary mode {mode!r}; want one of {_MODES}")
    axes = tuple(range(f.ndim)) if spatial_axes is None else tuple(spatial_axes)
    if isinstance(radius, int):
        radius = [radius] * len(axes)
    if len(radius) != len(axes):
        raise ValueError("radius/spatial_axes length mismatch")
    pad_width = [(0, 0)] * f.ndim
    for a, r in zip(axes, radius):
        pad_width[a] = (int(r), int(r))
    if mode == "periodic":
        return jnp.pad(f, pad_width, mode="wrap")
    if mode == "dirichlet":
        return jnp.pad(f, pad_width, mode="constant", constant_values=value)
    if mode == "neumann":
        return jnp.pad(f, pad_width, mode="edge")
    return jnp.pad(f, pad_width, mode="reflect")


def unpad(
    f: jnp.ndarray,
    radius: int | Sequence[int],
    *,
    spatial_axes: Sequence[int] | None = None,
) -> jnp.ndarray:
    """Inverse of :func:`pad` — strip ghost cells."""
    axes = tuple(range(f.ndim)) if spatial_axes is None else tuple(spatial_axes)
    if isinstance(radius, int):
        radius = [radius] * len(axes)
    slicer: list[slice] = [slice(None)] * f.ndim
    for a, r in zip(axes, radius):
        slicer[a] = slice(int(r), f.shape[a] - int(r)) if r else slice(None)
    return f[tuple(slicer)]
