"""Deprecated alias — the autotuner was promoted to ``repro.tuning``.

This shim keeps old imports working (``from repro.core.autotune import
enumerate_candidates`` etc.); new code should import from
``repro.tuning`` which adds the persistent cache, the TuningSession
protocol, and the ``block="auto"`` resolvers.
"""
from __future__ import annotations

import warnings

from repro.tuning.costmodel import (  # noqa: F401
    Candidate,
    LANE,
    SUBLANE,
    VMEM_BUDGET,
    autotune,
    enumerate_candidates,
    halo_overhead,
    time_candidate,
    vmem_working_set,
)

warnings.warn(
    "repro.core.autotune moved to repro.tuning (persistent cache + "
    "TuningSession); this alias will be removed",
    DeprecationWarning,
    stacklevel=2,
)
