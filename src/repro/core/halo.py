"""Distributed halo exchange over a device mesh (shard_map + ppermute).

The TPU-native mapping of Astaroth's MPI halo exchange (paper Sec. 4.4 /
ref. 6): each device owns a contiguous block of the computational domain;
before a stencil application it receives the ``r`` boundary planes of its
neighbors along every decomposed axis. On a torus-topology mesh axis,
``jax.lax.ppermute`` with a ring permutation is a single-hop ICI
transfer in each direction — the minimal-traffic exchange.

Overlap note (EXPERIMENTS.md §Perf): the sends depend only on edge
planes, the interior compute depends only on local data. We emit the
permutes FIRST and slice the interior compute so XLA's latency-hiding
scheduler can overlap the collective-permute with interior FLOPs. The
``interior_first`` helper structures that split explicitly.
"""
from __future__ import annotations

from typing import Sequence

import jax
import jax.numpy as jnp
from jax import lax


def axis_size(axis_name: str) -> int:
    """Mesh-axis size inside shard_map, across jax versions (shared by
    the halo exchange and grad_sync)."""
    if hasattr(lax, "axis_size"):  # jax >= 0.6
        return lax.axis_size(axis_name)
    return lax.psum(1, axis_name)


def exchange_halo_1d(
    f: jnp.ndarray, radius: int, axis_name: str, *, axis: int
) -> jnp.ndarray:
    """Exchange ``radius`` planes with both ring neighbors along one
    sharded array axis. Must run inside shard_map with ``axis_name`` in
    scope. Returns the locally-padded array (local + 2·radius).

    Periodic global boundary: the ring wrap supplies the periodic image.
    """
    if radius == 0:
        return f
    n = axis_size(axis_name)
    idx = lax.axis_index(axis_name)
    del idx  # symmetry: same program on every shard

    def take(sl):
        slicer = [slice(None)] * f.ndim
        slicer[axis] = sl
        return f[tuple(slicer)]

    right_edge = take(slice(f.shape[axis] - radius, None))  # goes right
    left_edge = take(slice(0, radius))  # goes left

    fwd = [(i, (i + 1) % n) for i in range(n)]
    bwd = [(i, (i - 1) % n) for i in range(n)]
    # What we receive from the LEFT neighbor is its right edge; it becomes
    # our left ghost zone (and vice versa).
    from_left = lax.ppermute(right_edge, axis_name, fwd)
    from_right = lax.ppermute(left_edge, axis_name, bwd)
    return jnp.concatenate([from_left, f, from_right], axis=axis)


def exchange_halos_nd(
    f: jnp.ndarray,
    radii: Sequence[int],
    mesh_axes: Sequence[str | None],
    *,
    spatial_axes: Sequence[int],
) -> jnp.ndarray:
    """Pad every spatial axis: ppermute where sharded, periodic wrap
    locally where not. Corner/edge regions become correct because the
    exchanges are applied sequentially on the already-padded faces — the
    standard dimension-by-dimension halo factorization.
    """
    if not (len(radii) == len(mesh_axes) == len(spatial_axes)):
        raise ValueError(
            f"radii ({len(radii)}), mesh_axes ({len(mesh_axes)}) and "
            f"spatial_axes ({len(spatial_axes)}) must have one entry per "
            "spatial dimension"
        )
    out = f
    for r, name, ax in zip(radii, mesh_axes, spatial_axes):
        if r == 0:
            continue
        if name is None:
            pad_width = [(0, 0)] * out.ndim
            pad_width[ax] = (r, r)
            out = jnp.pad(out, pad_width, mode="wrap")
        else:
            out = exchange_halo_1d(out, r, name, axis=ax)
    return out


def interior_first(
    f_local: jnp.ndarray,
    radii: Sequence[int],
    spatial_axes: Sequence[int],
) -> tuple[jnp.ndarray, list[tuple[int, slice]]]:
    """Split the local block into interior (computable before any halo
    arrives) and the dependent edge slabs — the compute/communication
    overlap decomposition. Returns the interior view and the edge slab
    slices (axis, slice) for the caller to schedule after the exchange.
    """
    slicer: list[slice] = [slice(None)] * f_local.ndim
    edges: list[tuple[int, slice]] = []
    for r, ax in zip(radii, spatial_axes):
        if r == 0:
            continue
        slicer[ax] = slice(r, f_local.shape[ax] - r)
        edges.append((ax, slice(0, r)))
        edges.append((ax, slice(f_local.shape[ax] - r, None)))
    return f_local[tuple(slicer)], edges
