"""Stencil specifications and finite-difference coefficient generation.

This module implements the paper's Sec. 2.4/3 formalism:

* a stencil is a set of (offset, coefficient) taps around a point of
  interest; the *influence radius* r is the max Chebyshev distance of any
  tap (paper Sec. 2.4);
* a set of n_s linear stencil operators over the same neighborhood is a
  coefficient matrix  A ∈ R^{n_s × n_k}  acting on the flattened
  neighborhood B ∈ R^{n_k × n_f} (paper Sec. 3.3, Eq. 8);
* central-difference coefficients of arbitrary order are generated with
  Fornberg's algorithm, so radius-1..4 (2nd..8th order) stencils used by
  the diffusion/MHD benchmarks all come from one generator.

Everything here is static (numpy) metadata — no jax arrays. Kernels and
the fusion engine consume these specs at trace time, so tap loops unroll
with static offsets (the paper's "stencil point-wise unrolling" is the
default code-generation mode on TPU, where trip counts are static).
"""
from __future__ import annotations

import dataclasses
from functools import lru_cache
from typing import Sequence

import numpy as np

Offset = tuple[int, ...]


def fornberg_weights(z: float, x: Sequence[float], m: int) -> np.ndarray:
    """Fornberg (1988) finite-difference weights.

    Returns ``w`` of shape ``(len(x), m + 1)`` where ``w[:, k]`` are the
    weights approximating the k-th derivative at ``z`` from samples at
    grid locations ``x``.
    """
    x = np.asarray(x, dtype=np.float64)
    n = len(x)
    if m >= n:
        raise ValueError(f"need at least {m + 1} points for derivative {m}")
    w = np.zeros((n, m + 1))
    c1, c4 = 1.0, x[0] - z
    w[0, 0] = 1.0
    for i in range(1, n):
        mn = min(i, m)
        c2, c5, c4 = 1.0, c4, x[i] - z
        for j in range(i):
            c3 = x[i] - x[j]
            c2 *= c3
            if j == i - 1:
                for k in range(mn, 0, -1):
                    w[i, k] = c1 * (k * w[i - 1, k - 1] - c5 * w[i - 1, k]) / c2
                w[i, 0] = -c1 * c5 * w[i - 1, 0] / c2
            for k in range(mn, 0, -1):
                w[j, k] = (c4 * w[j, k] - k * w[j, k - 1]) / c3
            w[j, 0] = c4 * w[j, 0] / c3
        c1 = c2
    return w


@lru_cache(maxsize=None)
def offset_difference_coeffs(
    deriv: int, accuracy: int, left: int
) -> np.ndarray:
    """One-sided/offset finite-difference coefficients (the boundary-
    modified weight rows of ``core.boundary``).

    Weights approximating the ``deriv``-th derivative at a point with
    only ``left`` grid neighbors available toward the low side (a point
    ``left`` cells from a non-periodic wall): the Fornberg window spans
    offsets ``-left .. -left + npts - 1`` with ``npts = deriv +
    accuracy`` samples, which guarantees formal order ≥ ``accuracy``
    for any window placement — fully one-sided rows (``left = 0``) and
    every offset row up to the first centered one use the same point
    count, so the operator order is uniform across the domain.

    Returns coefficients in units of ``h**-deriv``; ``deriv = 0``
    returns the single-tap identity. Raises ``ValueError`` on an odd
    ``accuracy`` (same contract as :func:`central_difference_coeffs`).
    """
    if accuracy % 2 != 0:
        raise ValueError("finite differences here need even accuracy order")
    if left < 0:
        raise ValueError(f"left must be >= 0, got {left}")
    if deriv == 0:
        return np.array([1.0])
    npts = deriv + accuracy
    offsets = np.arange(-left, npts - left, dtype=np.float64)
    w = fornberg_weights(0.0, offsets, deriv)[:, deriv]
    w[np.abs(w) < 1e-12] = 0.0
    return w


@lru_cache(maxsize=None)
def central_difference_coeffs(deriv: int, accuracy: int) -> np.ndarray:
    """1-D central-difference coefficients.

    ``deriv``: derivative order (0 = identity, 1, 2, ...).
    ``accuracy``: even accuracy order (2, 4, 6, 8). Radius is
    ``(deriv + 1) // 2 + accuracy // 2 - 1`` for central stencils; for the
    first/second derivatives used throughout this is ``accuracy // 2``.

    Returns coefficients over offsets ``-r .. r`` (length 2r + 1), in units
    of ``h**-deriv`` (caller scales by grid spacing).
    """
    if accuracy % 2 != 0:
        raise ValueError("central differences need even accuracy order")
    if deriv == 0:
        return np.array([1.0])
    r = (deriv - 1) // 2 + accuracy // 2
    offsets = np.arange(-r, r + 1, dtype=np.float64)
    w = fornberg_weights(0.0, offsets, deriv)[:, deriv]
    # Clean tiny fp noise so symmetric entries are exactly symmetric.
    w[np.abs(w) < 1e-12] = 0.0
    return w


@dataclasses.dataclass(frozen=True)
class OperatorSpec:
    """Analytic identity of a generated stencil operator.

    ``terms`` is the operator as a sum of scaled partial derivatives:
    each entry is ``(deriv, coeff)`` where ``deriv`` is the per-axis
    derivative multi-index (e.g. ``(0, 2)`` for ∂²/∂x² at rank 2) and
    ``coeff`` its scalar weight — so the merged diffusion stencil
    ``1 + Δt·α·∇²`` carries ``((0,…), 1.0)`` plus one ``(2·e_a, Δt·α)``
    term per axis. ``accuracy`` is the even finite-difference order the
    tap weights were generated at (0 = exact/unknown, e.g. the identity)
    and ``spacing`` the per-axis grid spacing baked into the weights.

    This is what lets downstream layers treat the *operator* as a plan
    axis: the accuracy joins strategy ids / tuning keys (``:o{A}``),
    and the boundary module can regenerate order-preserving one-sided
    weight rows (:func:`offset_difference_coeffs`) for the same
    analytic operator near non-periodic walls.
    """

    terms: tuple[tuple[tuple[int, ...], float], ...]
    accuracy: int = 0
    spacing: tuple[float, ...] = ()

    def scaled(self, s: float) -> "OperatorSpec":
        return OperatorSpec(
            tuple((d, c * s) for d, c in self.terms),
            self.accuracy, self.spacing,
        )

    def merged(self, other: "OperatorSpec") -> "OperatorSpec | None":
        """Metadata of the SUM of two operators, or None when their
        identities can't be combined (different spacings, or two
        distinct nonzero accuracies)."""
        if self.spacing and other.spacing and self.spacing != other.spacing:
            return None
        accs = {a for a in (self.accuracy, other.accuracy) if a}
        if len(accs) > 1:
            return None
        taps: dict[tuple[int, ...], float] = {}
        for d, c in self.terms + other.terms:
            taps[d] = taps.get(d, 0.0) + c
        return OperatorSpec(
            tuple(sorted(taps.items())),
            accs.pop() if accs else 0,
            self.spacing or other.spacing,
        )


@dataclasses.dataclass(frozen=True)
class StencilSpec:
    """A single linear stencil operator: taps[offset] = coefficient.

    ``offsets``: (n_taps, ndim) int array. ``coeffs``: (n_taps,) float64.

    ``spec`` optionally carries the operator's analytic identity
    (:class:`OperatorSpec` — derivative terms, generation accuracy,
    spacing). It is metadata: excluded from equality/hash, attached by
    the generator entry points (``axis_stencil`` & friends), and
    propagated through ``pruned``/``scaled``/``__add__``.
    """

    offsets: tuple[Offset, ...]
    coeffs: tuple[float, ...]
    name: str = ""
    spec: OperatorSpec | None = dataclasses.field(
        default=None, compare=False
    )

    def __post_init__(self):
        if len(self.offsets) != len(self.coeffs):
            raise ValueError("offsets/coeffs length mismatch")
        if self.offsets:
            ndims = {len(o) for o in self.offsets}
            if len(ndims) != 1:
                raise ValueError("inconsistent offset dimensionality")

    @property
    def ndim(self) -> int:
        return len(self.offsets[0]) if self.offsets else 0

    @property
    def radius(self) -> int:
        """Chebyshev influence radius (paper Sec. 2.4)."""
        if not self.offsets:
            return 0
        return int(max(max(abs(c) for c in o) for o in self.offsets))

    def radius_per_axis(self) -> tuple[int, ...]:
        if not self.offsets:
            return ()
        return tuple(
            int(max(abs(o[a]) for o in self.offsets)) for a in range(self.ndim)
        )

    def pruned(self, tol: float = 0.0) -> "StencilSpec":
        """Drop zero taps (paper Sec. 4.4: OPTIMIZE_MEM_ACCESSES pruning)."""
        keep = [i for i, c in enumerate(self.coeffs) if abs(c) > tol]
        return StencilSpec(
            tuple(self.offsets[i] for i in keep),
            tuple(self.coeffs[i] for i in keep),
            self.name,
            self.spec,
        )

    def scaled(self, s: float, name: str | None = None) -> "StencilSpec":
        return StencilSpec(
            self.offsets, tuple(float(c) * s for c in self.coeffs),
            self.name if name is None else name,
            None if self.spec is None else self.spec.scaled(s),
        )

    def __add__(self, other: "StencilSpec") -> "StencilSpec":
        taps: dict[Offset, float] = {}
        for o, c in zip(self.offsets, self.coeffs):
            taps[o] = taps.get(o, 0.0) + c
        for o, c in zip(other.offsets, other.coeffs):
            taps[o] = taps.get(o, 0.0) + c
        items = sorted(taps.items())
        spec = None
        if self.spec is not None and other.spec is not None:
            spec = self.spec.merged(other.spec)
        return StencilSpec(
            tuple(o for o, _ in items), tuple(c for _, c in items),
            f"({self.name}+{other.name})",
            spec,
        )

    def compose_outer(self, other: "StencilSpec", name: str = "") -> "StencilSpec":
        """Tensor-product composition (e.g. d/dx ∘ d/dy for mixed partials)."""
        taps: dict[Offset, float] = {}
        for o1, c1 in zip(self.offsets, self.coeffs):
            for o2, c2 in zip(other.offsets, other.coeffs):
                o = tuple(a + b for a, b in zip(o1, o2))
                taps[o] = taps.get(o, 0.0) + c1 * c2
        items = sorted(taps.items())
        return StencilSpec(
            tuple(o for o, _ in items), tuple(c for _, c in items), name
        ).pruned(1e-14)


def axis_stencil(
    ndim: int, axis: int, deriv: int, accuracy: int, spacing: float = 1.0,
    name: str = "",
) -> StencilSpec:
    """A 1-D central-difference stencil embedded along ``axis`` of an
    ``ndim``-dimensional domain, scaled by ``spacing**-deriv``."""
    w = central_difference_coeffs(deriv, accuracy) / spacing**deriv
    r = (len(w) - 1) // 2
    offsets, coeffs = [], []
    for k, c in enumerate(w):
        if c == 0.0 and deriv > 0:
            continue
        o = [0] * ndim
        o[axis] = k - r
        offsets.append(tuple(o))
        coeffs.append(float(c))
    dmi = tuple(deriv if a == axis else 0 for a in range(ndim))
    # Only the differentiated axis's spacing entry is meaningful here
    # (the caller passes a scalar h for this axis alone).
    sp = tuple(float(spacing) if a == axis else 1.0 for a in range(ndim))
    return StencilSpec(
        tuple(offsets), tuple(coeffs), name,
        OperatorSpec(((dmi, 1.0),), accuracy if deriv else 0, sp),
    )


def laplacian_stencil(
    ndim: int, accuracy: int, spacing: Sequence[float] | float = 1.0,
    name: str = "lap",
) -> StencilSpec:
    """∇² as the sum of per-axis second-derivative stencils (paper Eq. 7:
    distributivity of cross-correlation over addition lets the per-axis
    kernels be summed into ONE stencil)."""
    if np.isscalar(spacing):
        spacing = [float(spacing)] * ndim
    out = axis_stencil(ndim, 0, 2, accuracy, spacing[0])
    for a in range(1, ndim):
        out = out + axis_stencil(ndim, a, 2, accuracy, spacing[a])
    spec = OperatorSpec(
        tuple(
            (tuple(2 if b == a else 0 for b in range(ndim)), 1.0)
            for a in range(ndim)
        ),
        accuracy,
        tuple(float(s) for s in spacing),
    )
    return StencilSpec(out.offsets, out.coeffs, name, spec).pruned(0.0)


def mixed_partial_stencil(
    ndim: int, axis_a: int, axis_b: int, accuracy: int,
    spacing: Sequence[float] | float = 1.0, name: str = "",
) -> StencilSpec:
    """∂²/∂a∂b as the outer composition of two first-derivative stencils."""
    if np.isscalar(spacing):
        spacing = [float(spacing)] * ndim
    sa = axis_stencil(ndim, axis_a, 1, accuracy, spacing[axis_a])
    sb = axis_stencil(ndim, axis_b, 1, accuracy, spacing[axis_b])
    out = sa.compose_outer(sb, name)
    dmi = tuple(
        int(a == axis_a) + int(a == axis_b) for a in range(ndim)
    )
    spec = OperatorSpec(
        ((dmi, 1.0),), accuracy, tuple(float(s) for s in spacing)
    )
    return dataclasses.replace(out, spec=spec)


def identity_stencil(ndim: int, name: str = "val") -> StencilSpec:
    return StencilSpec(
        (tuple([0] * ndim),), (1.0,), name,
        OperatorSpec(((tuple([0] * ndim), 1.0),), 0, ()),
    )


@dataclasses.dataclass(frozen=True)
class OperatorSet:
    """A named set of linear stencil operators sharing one neighborhood.

    This is the paper's coefficient matrix A (Eq. 8): ``matrix()`` returns
    A ∈ R^{n_s × n_k} over the union of all tap offsets (columns), pruned
    to offsets used by at least one operator. Kernels either

    * iterate taps (offset-MAC, the VPU-friendly form), or
    * materialize A and run Q = A·B on the MXU (implicit-GEMM form).
    """

    ops: tuple[StencilSpec, ...]

    def __post_init__(self):
        names = [s.name for s in self.ops]
        if len(set(names)) != len(names):
            raise ValueError(f"duplicate operator names: {names}")

    @property
    def names(self) -> tuple[str, ...]:
        return tuple(s.name for s in self.ops)

    @property
    def ndim(self) -> int:
        return self.ops[0].ndim

    @property
    def radius(self) -> int:
        return max(s.radius for s in self.ops)

    def radius_per_axis(self) -> tuple[int, ...]:
        per = [s.radius_per_axis() for s in self.ops]
        return tuple(max(p[a] for p in per) for a in range(self.ndim))

    @property
    def n_s(self) -> int:
        return len(self.ops)

    def tap_union(self) -> tuple[Offset, ...]:
        """Sorted union of offsets used by any operator (pruned n_k)."""
        taps: set[Offset] = set()
        for s in self.ops:
            taps.update(s.offsets)
        return tuple(sorted(taps))

    @property
    def n_k(self) -> int:
        return len(self.tap_union())

    def matrix(self) -> tuple[np.ndarray, tuple[Offset, ...]]:
        """A ∈ R^{n_s × n_k} and the column offset order."""
        cols = self.tap_union()
        col_ix = {o: i for i, o in enumerate(cols)}
        A = np.zeros((self.n_s, len(cols)))
        for si, s in enumerate(self.ops):
            for o, c in zip(s.offsets, s.coeffs):
                A[si, col_ix[o]] = c
        return A, cols

    def by_name(self, name: str) -> StencilSpec:
        for s in self.ops:
            if s.name == name:
                return s
        raise KeyError(name)

    def flops_per_point(self, n_f: int) -> int:
        """Multiply-add FLOPs per grid point for the pruned tap set."""
        return int(2 * n_f * sum(len(s.offsets) for s in self.ops))

    @property
    def taps_per_point(self) -> int:
        """Total taps every grid point evaluates across the set — the
        tap-count input of the cost model's VPU compute term (one
        multiply-add per tap per field)."""
        return int(sum(len(s.offsets) for s in self.ops))

    @property
    def accuracy(self) -> int:
        """The finite-difference accuracy order the set's derivative
        operators were generated at — the ``:o{A}`` plan/tuning-key
        axis. 0 when unknown (hand-built taps without
        :class:`OperatorSpec` metadata, or no derivative operators) or
        mixed (members generated at different orders)."""
        accs = {
            s.spec.accuracy
            for s in self.ops
            if s.spec is not None and s.spec.accuracy
        }
        return accs.pop() if len(accs) == 1 else 0


def derivative_operator_set(
    ndim: int, accuracy: int, spacing: Sequence[float] | float = 1.0,
    include_mixed: bool = True, include_value: bool = True,
) -> OperatorSet:
    """The full derivative-operator set used by the MHD solver:
    {val, d/dxi, d²/dxi², d²/dxi dxj}. With accuracy=6 and ndim=3 this is
    the paper's 10-operator, 127-tap (pruned) configuration.

    Array-axis convention: spatial axes are ordered slowest→fastest as
    (z, y, x) for 3-D, (y, x) for 2-D, (x,) for 1-D — x is always the
    contiguous (lane) dimension. ``spacing`` follows the same order.
    """
    if np.isscalar(spacing):
        spacing = [float(spacing)] * ndim
    axes = {1: ("x",), 2: ("y", "x"), 3: ("z", "y", "x")}[ndim]
    ops: list[StencilSpec] = []
    if include_value:
        ops.append(identity_stencil(ndim))
    for a in range(ndim):
        ops.append(axis_stencil(ndim, a, 1, accuracy, spacing[a], f"d{axes[a]}"))
    for a in range(ndim):
        ops.append(axis_stencil(ndim, a, 2, accuracy, spacing[a], f"d{axes[a]}{axes[a]}"))
    if include_mixed:
        for a in range(ndim):
            for b in range(a + 1, ndim):
                na, nb = sorted([axes[a], axes[b]])
                ops.append(
                    mixed_partial_stencil(
                        ndim, a, b, accuracy, spacing, f"d{na}{nb}"
                    )
                )
    return OperatorSet(tuple(ops))


def xcorr_operator_set(g: np.ndarray, ndim: int = 1) -> OperatorSet:
    """Single cross-correlation operator from a dense 1-D kernel ``g``
    (paper Eq. 3) embedded along the last axis."""
    g = np.asarray(g, dtype=np.float64)
    r = (len(g) - 1) // 2
    offsets = []
    for k in range(len(g)):
        o = [0] * ndim
        o[-1] = k - r
        offsets.append(tuple(o))
    return OperatorSet(
        (StencilSpec(tuple(offsets), tuple(float(c) for c in g), "xcorr"),)
    )


def diffusion_kernel_1d(accuracy: int, dt: float, alpha: float,
                        spacing: float = 1.0) -> np.ndarray:
    """The paper's Eq. 5: g = c^(1) + Δt·α·c^(2) — identity plus scaled
    second-derivative coefficients, as a dense 1-D kernel."""
    c2 = central_difference_coeffs(2, accuracy) / spacing**2
    g = dt * alpha * c2
    g[len(g) // 2] += 1.0
    return g


def diffusion_kernel_nd(ndim: int, accuracy: int, dt: float, alpha: float,
                        spacing: Sequence[float] | float = 1.0) -> StencilSpec:
    """The paper's Eq. 7: one merged stencil for f' = f + Δt·α·∇²f."""
    lap = laplacian_stencil(ndim, accuracy, spacing)
    return (identity_stencil(ndim) + lap.scaled(dt * alpha)).pruned(0.0)
