"""FusedStencilOp — the paper's contribution as a composable JAX module.

A fused stencil operation is the paper's chain φ(γ(ψ(f))) (Sec. 3.3):

  ψ  pad the spatial dimensions (boundary module),
  γ  evaluate ALL linear stencil operators for ALL fields — conceptually
     Q = A·B with A ∈ R^{n_s×n_k}, B ∈ R^{n_k×n_f} per point (Eq. 8),
  φ  nonlinear point-wise map producing the n_out field updates (Eq. 9).

``strategy`` selects the caching regime evaluated by the paper:

  * ``hwc``        — pure jnp; the compiler (XLA) owns on-chip residency
                     (the hardware-managed-cache analogue);
  * ``swc``        — Pallas kernel, VMEM residency owned by us, blocks
                     auto-pipelined (paper Fig. 5a on TPU);
  * ``swc_stream`` — Pallas kernel, explicit z-streaming with carried
                     halo + prefetch DMA (paper Fig. 5b on TPU).

The same object also runs *distributed* over a device mesh: the domain is
decomposed over mesh axes and halos are exchanged with collective
permutes before each application (`apply_sharded`), which is the
shard_map analogue of Astaroth's MPI halo exchange.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Mapping, Sequence

import jax
import jax.numpy as jnp

from repro.core import boundary
from repro.core.halo import exchange_halos_nd
from repro.core.stencil import OperatorSet
from repro.kernels import ops as kops
from repro.kernels import ref as kref

Phi = Callable[[Mapping[str, jnp.ndarray]], jnp.ndarray]

STRATEGIES = ("hwc", "swc", "swc_stream")


@dataclasses.dataclass(frozen=True)
class FusedStencilOp:
    """One fused update step over an (n_f, *spatial) field stack."""

    ops: OperatorSet
    phi: Phi
    n_out: int
    boundary_mode: str = "periodic"
    strategy: str = "hwc"
    # (τz, τy, τx), or "auto" to consult the persistent tuning cache
    # (repro.tuning): cache-hit fast path, rank-and-measure on an eager
    # miss, structural cost-model winner under jit tracing.
    block: tuple[int, int, int] | str = (8, 8, 128)

    def __post_init__(self):
        if self.strategy not in STRATEGIES:
            raise ValueError(
                f"strategy {self.strategy!r} not in {STRATEGIES}"
            )
        if isinstance(self.block, str) and self.block != "auto":
            raise ValueError(
                f"block must be a (τz, τy, τx) tuple or 'auto', "
                f"got {self.block!r}"
            )

    @property
    def radius_per_axis(self) -> tuple[int, ...]:
        return self.ops.radius_per_axis()

    # -- single device ------------------------------------------------------

    def apply_padded(
        self, f_padded: jnp.ndarray, aux: jnp.ndarray | None = None
    ) -> jnp.ndarray:
        """Apply to an already-padded field stack (ghost cells present).

        ``aux`` (n_aux, *interior): extra point-wise inputs forwarded to
        φ (fused axpy / RK carries — beyond-paper extension)."""
        ndim = self.ops.ndim
        if ndim == 3 and self.strategy in ("swc", "swc_stream"):
            return kops.fused_stencil3d(
                f_padded, self.ops, self.phi, self.n_out, aux=aux,
                strategy=self.strategy, block=self.block,
            )
        # hwc path — and the general-rank fallback for 1-D/2-D domains,
        # where XLA's fusion already achieves the paper's HWC behaviour.
        return kref.fused_stencil(f_padded, self.ops, self.phi, aux=aux)

    def __call__(
        self, f: jnp.ndarray, aux: jnp.ndarray | None = None
    ) -> jnp.ndarray:
        """ψ then φ(A·B): pad with the boundary function and apply."""
        rads = self.radius_per_axis
        fp = boundary.pad(
            f, rads, self.boundary_mode,
            spatial_axes=range(1, f.ndim),
        )
        return self.apply_padded(fp, aux=aux)

    # -- distributed --------------------------------------------------------

    def apply_sharded(
        self,
        f_local: jnp.ndarray,
        mesh_axes: Sequence[str | None],
        aux: jnp.ndarray | None = None,
    ) -> jnp.ndarray:
        """Apply inside ``shard_map``: exchange halos over the mesh axes
        assigned to each spatial dimension, then run the local fused
        kernel. ``mesh_axes[a]`` names the mesh axis sharding spatial axis
        ``a`` (None = unsharded → local boundary padding).

        Periodic boundaries compose exactly with the ring permute: the
        wrap-around neighbor IS the periodic image.
        """
        if self.boundary_mode != "periodic":
            raise NotImplementedError(
                "sharded stencils currently support periodic boundaries "
                "(the paper's simulation setup)"
            )
        fp = exchange_halos_nd(
            f_local, self.radius_per_axis, mesh_axes,
            spatial_axes=tuple(range(1, f_local.ndim)),
        )
        return self.apply_padded(fp, aux=aux)


def integrate(
    op: FusedStencilOp, f0: jnp.ndarray, n_steps: int
) -> jnp.ndarray:
    """Iterate f ← φ(A·B(ψ(f))) with lax control flow (paper Fig. 1)."""

    def body(f, _):
        return op(f), None

    out, _ = jax.lax.scan(body, f0, None, length=n_steps)
    return out
