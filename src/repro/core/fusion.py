"""FusedStencilOp — the paper's contribution as a composable JAX module.

A fused stencil operation is the paper's chain φ(γ(ψ(f))) (Sec. 3.3):

  ψ  pad the spatial dimensions (boundary module),
  γ  evaluate ALL linear stencil operators for ALL fields — conceptually
     Q = A·B with A ∈ R^{n_s×n_k}, B ∈ R^{n_k×n_f} per point (Eq. 8),
  φ  nonlinear point-wise map producing the n_out field updates (Eq. 9).

``strategy`` selects the caching regime evaluated by the paper. Every
strategy lowers through the :class:`~repro.kernels.plan.StencilPlan`
pipeline (planner → rank-generic emitter → tuning cache) except
``hwc``, which is pure jnp:

  ============  =========  =====================================================
  strategy      ranks      on-chip residency
  ============  =========  =====================================================
  ``hwc``        1, 2, 3   compiler-managed (XLA fuses the tap loops; the
                           hardware-managed-cache analogue)
  ``swc``        1, 2, 3   Pallas kernel, VMEM residency owned by us, blocks
                           auto-pipelined (paper Fig. 5a on TPU)
  ``swc_stream``    2, 3   Pallas kernel, explicit streaming of the slowest
                           spatial axis (z at rank 3, y at rank 2) with
                           carried halo + prefetch DMA (paper Fig. 5b on
                           TPU); composes with ``fuse_steps``
  ``tc``         1, 2, 3   Pallas kernel, ``swc`` staging but tap evaluation
                           lowered to banded coefficient-matrix contractions
                           on the MXU (f32 accumulation; dtype f32/bf16
                           only); composes with ``fuse_steps`` and the
                           ensemble batch axis
  ============  =========  =====================================================

The same object also runs *distributed* over a device mesh: the domain is
decomposed over mesh axes and halos are exchanged with collective
permutes before each application (`apply_sharded`), which is the
shard_map analogue of Astaroth's MPI halo exchange. With
``overlap=True`` the interior (halo-independent) points are computed
from purely local data so XLA can overlap the collective-permute with
interior FLOPs (the compute/communication overlap decomposition).

``fuse_steps`` adds the temporal dimension to the fusion (the paper's
headline strategy taken one level further): one kernel invocation
advances ``fuse_steps`` time steps on a VMEM-resident block whose halo
is widened to ``radius * fuse_steps``, so intermediate steps never
write the field stack back to HBM — redundant halo compute traded for
memory traffic (classic temporal blocking). ``fuse_steps="auto"``
resolves the depth jointly with the block through the tuning
subsystem's traffic-model-driven search.

``strategy="auto"`` closes the loop over the caching regimes
themselves (the paper's central finding: no single regime wins
everywhere, "necessitating platform-specific tuning"): resolution
consults the tuning subsystem's cross-strategy search, which scores
``hwc`` (the measured XLA baseline, modeled at the compulsory-traffic
floor), ``swc``, and ``swc_stream`` candidates jointly over
``(block, fuse_steps, stream)`` and persists the whole decision —
strategy, stream axis, block, and depth — in one schema-v2 tuning
record, reproduced exactly on warm cache hits and under jit tracing
(structural winner, no measurement). ``strategy="auto"`` owns the
block (``block="auto"``, coerced from ``None``) and composes with
``fuse_steps`` being an int (strategy/block search at that depth) or
``"auto"`` (the full joint search).
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Mapping, Sequence, Union

import jax
import jax.numpy as jnp

from repro.core import boundary
from repro.core.halo import exchange_halos_nd, interior_first
from repro.core.stencil import OperatorSet
from repro.kernels import ops as kops
from repro.kernels import ref as kref

Phi = Callable[[Mapping[str, jnp.ndarray]], jnp.ndarray]
# One callable (applied every fused step) or one per fused step.
PhiLike = Union[Phi, tuple]

STRATEGIES = ("hwc", "swc", "swc_stream", "tc", "auto")


@dataclasses.dataclass(frozen=True)
class FusedStencilOp:
    """One fused update step over an (n_f, *spatial) field stack.

    Args (dataclass fields):
        ops: the :class:`~repro.core.stencil.OperatorSet` of linear
            stencil operators (γ — every A·B product the update needs).
        phi: point-wise map from ``{op_name: (n_f, *spatial)}`` (plus an
            optional aux array) to the (n_out, *spatial) update; may be
            a sequence of ``fuse_steps`` per-sweep callables.
        n_out: number of output fields φ produces.
        boundary_mode: ψ — how ghost cells are filled ("periodic", …);
            scalar or one mode per spatial axis (e.g. a channel flow
            ``("dirichlet", "periodic")`` — walls along y, wrap along
            x).
        boundary_weights: replace the ghost-cell approximation within
            ``r`` cells of every non-periodic face by boundary-MODIFIED
            weight rows (offset/one-sided stencils of the full interior
            order, ``core.boundary.apply_operator_set_bc``), blended
            over the fast padded kernel output as a post-pass — so
            non-periodic domains keep the operator's nominal
            convergence order instead of degrading to the ghost fill's
            (Dirichlet 0th/1st, "neumann" 1st, "neumann2" 2nd).
            Requires generated operators (OperatorSpec metadata) and
            depth 1; a no-op on all-periodic axes.
        strategy: caching regime — "hwc", "swc", "swc_stream", "tc"
            (stencils on the matrix unit; f32/bf16 only), or
            "auto" (the cross-strategy tuning search picks the regime,
            block, depth and stream axis jointly and persists them in
            one record; see the module docstring).
        block: rank-length tile (x last), ``"auto"`` (persistent tuning
            cache), or None (per-rank default; coerced to ``"auto"``
            under ``strategy="auto"``, which owns the block).
        fuse_steps: temporal-fusion depth (int ≥ 1, or ``"auto"`` for
            the joint block/depth search).

    Calling the op applies one (depth-fused) update::

        >>> import jax.numpy as jnp
        >>> from repro.core.fusion import FusedStencilOp
        >>> from repro.core.stencil import derivative_operator_set
        >>> ops = derivative_operator_set(2, 2, spacing=0.5)
        >>> op = FusedStencilOp(
        ...     ops, lambda d: d["val"] + 0.1 * (d["dxx"] + d["dyy"]),
        ...     n_out=1, strategy="swc")
        >>> out = op(jnp.zeros((1, 8, 16)))
        >>> out.shape
        (1, 8, 16)

    Raises:
        ValueError: on an invalid strategy, a strategy/rank mismatch
            (``swc_stream`` needs rank ≥ 2), a non-periodic boundary or
            ``swc_stream`` with depth > 1 prerequisites unmet, or a
            per-step φ sequence whose length disagrees with the depth.
    """

    ops: OperatorSet
    phi: PhiLike
    n_out: int
    # ψ — ghost-fill family, scalar or per spatial axis (x last).
    boundary_mode: str | tuple[str, ...] = "periodic"
    strategy: str = "hwc"
    # Rank-length tile (x last), "auto" to consult the persistent tuning
    # cache (repro.tuning: cache-hit fast path, rank-and-measure on an
    # eager miss, structural cost-model winner under jit tracing), or
    # None for the per-rank default.
    block: tuple[int, ...] | str | None = None
    # Temporal fusion depth: one call advances this many time steps in
    # ONE kernel (halo widened to radius·depth, intermediates VMEM-only).
    # "auto" resolves (block, depth) jointly from the tuning subsystem's
    # traffic-model search; requires strategy="swc"/"swc_stream" and
    # block="auto".
    fuse_steps: int | str = 1
    # Full-order boundary-modified weight rows at non-periodic faces
    # (post-pass blend; see the class docstring).
    boundary_weights: bool = False

    def __post_init__(self):
        if self.strategy not in STRATEGIES:
            raise ValueError(
                f"strategy {self.strategy!r} not in {STRATEGIES}"
            )
        # Validates mode names and the per-axis count up front.
        modes = self.boundary_modes
        if self.boundary_weights:
            missing = [s.name for s in self.ops.ops if s.spec is None]
            if missing:
                raise ValueError(
                    "boundary_weights=True needs OperatorSpec metadata "
                    "(derivative, accuracy, spacing) on every operator "
                    "to build the offset weight rows — missing on "
                    f"{missing}; build the set with axis_stencil/"
                    "laplacian_stencil/derivative_operator_set"
                )
        if self.strategy == "swc_stream" and self.ops.ndim < 2:
            raise ValueError(
                "swc_stream (explicit streaming of the slowest axis) "
                f"requires a 2-D or 3-D operator set; got "
                f"ndim={self.ops.ndim} — use strategy='swc'"
            )
        if self.strategy == "auto":
            # The cross-strategy search owns the block: None is coerced
            # to "auto", an explicit tile is contradictory.
            if self.block is None:
                object.__setattr__(self, "block", "auto")
            elif self.block != "auto":
                raise ValueError(
                    "strategy='auto' resolves the block through the "
                    "cross-strategy tuning search — pass block='auto' "
                    f"(or None), not {self.block!r}"
                )
        if isinstance(self.block, str) and self.block != "auto":
            raise ValueError(
                f"block must be a rank-length tuple, 'auto', or None, "
                f"got {self.block!r}"
            )
        if isinstance(self.fuse_steps, str):
            if self.fuse_steps != "auto":
                raise ValueError(
                    f"fuse_steps must be an int >= 1 or 'auto', got "
                    f"{self.fuse_steps!r}"
                )
            if self.strategy not in (
                "swc", "swc_stream", "tc", "auto"
            ) or (self.block != "auto"):
                raise ValueError(
                    "fuse_steps='auto' resolves through the joint "
                    "(block, depth) tuning search — it requires "
                    "strategy='swc', 'swc_stream', 'tc' or 'auto' and "
                    "block='auto'"
                )
        elif self.fuse_steps < 1:
            raise ValueError(
                f"fuse_steps must be >= 1, got {self.fuse_steps}"
            )
        if self._depth_or_none() != 1:
            if any(m != "periodic" for m in modes):
                raise ValueError(
                    "temporal fusion requires boundary_mode='periodic' "
                    "on every axis: intermediate in-kernel sweeps "
                    "consume pre-padded ghost cells and never re-impose "
                    "the boundary, which only composes exactly for the "
                    f"periodic wrap (got {self.boundary_mode!r})"
                )
        if isinstance(self.phi, (tuple, list)):
            depth = self._depth_or_none()
            if depth is None:
                raise ValueError(
                    "a per-step phi sequence pins the fusion depth to "
                    f"len(phi) = {len(self.phi)} — pass that as "
                    "fuse_steps instead of 'auto'"
                )
            if len(self.phi) != depth:
                raise ValueError(
                    f"phi sequence has {len(self.phi)} entries for "
                    f"fuse_steps={depth}"
                )

    def _depth_or_none(self) -> int | None:
        """Concrete fusion depth, or None when it is tuned ('auto')."""
        return None if self.fuse_steps == "auto" else int(self.fuse_steps)

    @property
    def needs_resolution(self) -> bool:
        """True while any lowering decision (strategy or depth) is still
        ``"auto"`` — ``resolved()`` turns such an op concrete."""
        return self.strategy == "auto" or self.fuse_steps == "auto"

    @property
    def radius_per_axis(self) -> tuple[int, ...]:
        """Per-axis halo radius of the operator set (ghost cells one
        un-fused application consumes on each side)."""
        return self.ops.radius_per_axis()

    @property
    def boundary_modes(self) -> tuple[str, ...]:
        """``boundary_mode`` normalized to one mode per spatial axis
        (x last), names validated."""
        return boundary._normalize_modes(
            self.boundary_mode, self.ops.ndim
        )

    def lowering_plan(
        self,
        interior_shape: Sequence[int],
        *,
        n_aux: int = 0,
        dtype: str = "float32",
    ):
        """The :class:`~repro.kernels.plan.StencilPlan` this op's
        ``apply_padded`` lowers for an (unpadded) ``interior_shape``
        field stack — ``(n_f, *spatial)`` or the batched
        ``(batch, n_f, *spatial)``. ``None`` for the hwc regime (no
        Pallas plan). Requires every lowering decision to be concrete
        (``resolved()`` first) — the static auditor
        (``repro.analysis``) drives this to audit exactly the plan a
        call site will launch, without running it.
        """
        depth = self._depth_or_none()
        if depth is None or self.strategy == "auto":
            raise ValueError(
                "lowering_plan needs a concrete strategy and "
                "fuse_steps — resolve via op.resolved(f) first"
            )
        shape = tuple(interior_shape)
        lead = len(shape) - self.ops.ndim
        radii = self.radius_per_axis
        padded = shape[:lead] + tuple(
            n + 2 * r * depth for n, r in zip(shape[lead:], radii)
        )
        aux_shape = None
        if n_aux:
            aux_shape = shape[: lead - 1] + (n_aux,) + shape[lead:]
        return kops.plan_for_nd(
            self.ops, padded, self.n_out, aux_shape=aux_shape,
            strategy=self.strategy, block=self.block, dtype=dtype,
            fuse_steps=depth,
        )

    # -- single device ------------------------------------------------------

    def resolved(
        self, f: jnp.ndarray, aux: jnp.ndarray | None = None
    ) -> "FusedStencilOp":
        """An equivalent, fully concrete op — the resolution contract.

        A no-op when nothing is ``"auto"``. With ``strategy="auto"``
        the cross-strategy search resolves (strategy, block, depth,
        stream) in one pass for the *unpadded* field stack ``f`` and the
        returned op carries all four (the stream axis is implied by the
        resolved strategy); with only ``fuse_steps="auto"`` the
        per-strategy joint (block, depth) search runs. Either way:
        measured on a cache miss when eager, replayed from the
        persistent record on a warm hit, the traffic-model winner under
        jit tracing — so the returned op is bit-identical across a
        cold-measure → cache-write → warm-hit cycle.
        """
        if self.strategy == "auto":
            from repro.tuning.session import auto_strategy_nd

            strategy, block, depth = auto_strategy_nd(
                f, self.ops, self.phi, self.n_out, aux=aux,
                fuse_steps=self.fuse_steps,
            )
            return dataclasses.replace(
                self, strategy=strategy, block=tuple(block),
                fuse_steps=int(depth),
            )
        if self.fuse_steps != "auto":
            return self
        from repro.tuning.session import auto_fuse_nd

        block, depth = auto_fuse_nd(
            f, self.ops, self.phi, self.n_out, aux=aux,
            strategy=self.strategy,
        )
        return dataclasses.replace(
            self, block=tuple(block), fuse_steps=int(depth)
        )

    def apply_padded(
        self, f_padded: jnp.ndarray, aux: jnp.ndarray | None = None
    ) -> jnp.ndarray:
        """Apply to an already-padded field stack (ghost cells present:
        ``radius * fuse_steps`` per axis — one radius per fused sweep).

        ``aux``: extra point-wise inputs forwarded to φ (fused axpy /
        RK carries — beyond-paper extension); (n_aux, *interior) at
        depth 1, padded by ``radius * (fuse_steps - 1)`` at depth > 1 so
        intermediate sweeps see an aligned carry.

        A batched (batch, n_f, *padded) ensemble stack is accepted
        wherever an (n_f, *padded) stack is — detected by rank and
        lowered through the member-major batched kernel (hwc uses the
        ``vmap`` oracles)."""
        depth = self._depth_or_none()
        if depth is None or self.strategy == "auto":
            raise ValueError(
                "apply_padded needs a concrete strategy and fuse_steps "
                "(the kernel and its ghost-cell width depend on them) "
                "— resolve via op.resolved(f)(f) or __call__"
            )
        if self.strategy in ("swc", "swc_stream", "tc"):
            return kops.fused_stencil_nd(
                f_padded, self.ops, self.phi, self.n_out, aux=aux,
                strategy=self.strategy, block=self.block,
                fuse_steps=depth,
            )
        # hwc — XLA owns on-chip residency (the paper's compiler-managed
        # caching regime). A (batch, n_f, *spatial) ensemble stack
        # dispatches to the vmap'd oracles.
        if f_padded.ndim == self.ops.ndim + 2:
            if depth == 1:
                return kref.fused_stencil_batched(
                    f_padded, self.ops, self.phi, aux=aux
                )
            return kref.fused_stencil_steps_batched(
                f_padded, self.ops, self.phi, depth, aux=aux
            )
        if depth == 1:
            return kref.fused_stencil(
                f_padded, self.ops, self.phi, aux=aux
            )
        return kref.fused_stencil_steps(
            f_padded, self.ops, self.phi, depth, aux=aux
        )

    def __call__(
        self, f: jnp.ndarray, aux: jnp.ndarray | None = None
    ) -> jnp.ndarray:
        """ψ then φ(A·B): pad with the boundary function and apply —
        advancing ``fuse_steps`` time steps per call.

        ``f`` is (n_f, *spatial), or (batch, n_f, *spatial) for an
        ensemble stack — the extra leading axis is detected by rank and
        threaded through padding and the batched kernel lowering
        (``aux`` then carries the same leading axis)."""
        if self.needs_resolution:
            return self.resolved(f, aux)(f, aux)
        depth = int(self.fuse_steps)
        rads = self.radius_per_axis
        modes = self.boundary_modes
        lead = 2 if f.ndim == self.ops.ndim + 2 else 1
        fp = boundary.pad(
            f, [r * depth for r in rads], modes,
            spatial_axes=range(lead, f.ndim),
        )
        if aux is not None and depth > 1:
            aux = boundary.pad(
                aux, [r * (depth - 1) for r in rads], modes,
                spatial_axes=range(lead, aux.ndim),
            )
        out = self.apply_padded(fp, aux=aux)
        if self.boundary_weights and any(m != "periodic" for m in modes):
            out = self._blend_boundary_weights(f, out, aux, lead)
        return out

    def _blend_boundary_weights(
        self,
        f: jnp.ndarray,
        out: jnp.ndarray,
        aux: jnp.ndarray | None,
        lead: int,
    ) -> jnp.ndarray:
        """Overwrite the wall-adjacent cells of the kernel output with
        the boundary-accurate evaluation (post-pass of
        ``boundary_weights=True``, depth 1 only — guaranteed by
        ``__post_init__``, which pins non-periodic ops to depth 1).

        The interior (every point ≥ r from all non-periodic faces)
        keeps the kernel's value bit-for-bit: the centered stencil
        there never reads a ghost cell, so the two evaluations agree
        and only the contaminated shell is replaced — the blend adds a
        dense-matrix evaluation of a thin O(r · surface) region, not a
        second full-domain pass of compute semantics.
        """
        modes = self.boundary_modes
        rads = self.radius_per_axis
        phi = self.phi[0] if isinstance(self.phi, (tuple, list)) else self.phi

        def bc_output(fm, auxm):
            derivs = boundary.apply_operator_set_bc(
                fm, self.ops, modes,
                spatial_axes=tuple(range(1, fm.ndim)),
            )
            return phi(derivs) if auxm is None else phi(derivs, auxm)

        if lead == 2:  # batched ensemble stack: member-wise oracle
            if aux is None:
                bc = jax.vmap(lambda fm: bc_output(fm, None))(f)
            else:
                bc = jax.vmap(bc_output)(f, aux)
        else:
            bc = bc_output(f, aux)
        spatial = f.shape[lead:]
        mask = jnp.zeros(spatial, dtype=bool)
        for a, (n, r, m) in enumerate(zip(spatial, rads, modes)):
            if m == "periodic" or r == 0:
                continue
            idx = jnp.arange(n)
            near = (idx < r) | (idx >= n - r)
            shape = [1] * len(spatial)
            shape[a] = n
            mask = mask | near.reshape(shape)
        return jnp.where(mask, bc.astype(out.dtype), out)

    # -- distributed --------------------------------------------------------

    def apply_sharded(
        self,
        f_local: jnp.ndarray,
        mesh_axes: Sequence[str | None],
        aux: jnp.ndarray | None = None,
        *,
        overlap: bool = False,
    ) -> jnp.ndarray:
        """Apply inside ``shard_map``: exchange halos over the mesh axes
        assigned to each spatial dimension, then run the local fused
        kernel.

        Args:
            f_local: this shard's (n_f, *local_spatial) field block.
            mesh_axes: one entry per spatial dimension — the mesh-axis
                name sharding that dimension, or None for unsharded
                (local boundary padding).
            aux: optional (n_aux, *local_spatial) point-wise inputs
                forwarded to φ (exchanged at ``radius·(fuse_steps-1)``
                when depth > 1).
            overlap: emit the compute/communication overlap
                decomposition (below); numerics are unchanged.

        Returns:
            The (n_out, *local_spatial) update for this shard.

        Raises:
            ValueError: when ``mesh_axes`` does not have exactly one
                entry per spatial dimension.
            NotImplementedError: for non-periodic boundary modes.

        Example (2 shards on a "data" mesh axis over y)::

            jax.shard_map(
                lambda fl: op.apply_sharded(fl, (None, "data", None)),
                mesh=mesh,
                in_specs=P(None, None, "data", None),
                out_specs=P(None, None, "data", None),
            )(f)

        Periodic boundaries compose exactly with the ring permute: the
        wrap-around neighbor IS the periodic image.

        ``overlap=True`` emits the halo exchange first and computes the
        halo-independent interior from purely local data, so XLA's
        latency-hiding scheduler can overlap the collective-permute with
        interior FLOPs; the dependent edge slabs are computed from the
        exchanged array afterwards. Numerics are unchanged.

        With ``fuse_steps > 1`` the exchanged halo widens to
        ``radius * fuse_steps`` per sharded axis (and the carry ``aux``
        is exchanged at ``radius * (fuse_steps - 1)``): one exchange
        buys ``fuse_steps`` time steps, cutting ICI message count the
        same way the kernel cuts HBM round trips. The overlap
        decomposition composes with any depth: the halo-independent
        interior shrinks by ``radius * fuse_steps`` per sharded axis and
        the dependent edge slabs (with their ``radius * (fuse_steps-1)``
        aux windows) are computed from the exchanged array afterwards.
        """
        if self.needs_resolution:
            return self.resolved(f_local, aux).apply_sharded(
                f_local, mesh_axes, aux, overlap=overlap
            )
        depth = int(self.fuse_steps)
        n_spatial = f_local.ndim - 1
        if len(mesh_axes) != n_spatial:
            raise ValueError(
                f"mesh_axes has {len(mesh_axes)} entries but the field "
                f"stack has {n_spatial} spatial dims — pass one mesh-axis "
                "name (or None) per spatial dimension"
            )
        if any(m != "periodic" for m in self.boundary_modes):
            raise NotImplementedError(
                "sharded stencils currently support periodic boundaries "
                "(the paper's simulation setup)"
            )
        if overlap:
            out = self._apply_sharded_overlap(f_local, mesh_axes, aux)
            if out is not None:
                return out
        spatial_axes = tuple(range(1, f_local.ndim))
        fp = exchange_halos_nd(
            f_local, [r * depth for r in self.radius_per_axis],
            mesh_axes, spatial_axes=spatial_axes,
        )
        if aux is not None and depth > 1:
            aux = exchange_halos_nd(
                aux, [r * (depth - 1) for r in self.radius_per_axis],
                mesh_axes, spatial_axes=tuple(range(1, aux.ndim)),
            )
        return self.apply_padded(fp, aux=aux)

    def _apply_sharded_overlap(
        self,
        f_local: jnp.ndarray,
        mesh_axes: Sequence[str | None],
        aux: jnp.ndarray | None,
    ) -> jnp.ndarray | None:
        """Compute/communication overlap decomposition (module docstring).

        Generalized over the temporal-fusion depth ``S = fuse_steps``:
        the exchange (and the halo every output point consumes) widens
        to ``radius·S`` per sharded axis, so the halo-independent
        interior shrinks by ``radius·S`` per side and the dependent edge
        slabs are ``radius·S`` wide. The carry ``aux`` is consumed at
        ``radius·(S-1)`` ghost cells per sweep boundary, so it is
        exchanged at that width and every sub-computation slices its
        aligned aux window from the exchanged array.

        Returns None when the decomposition doesn't apply (no sharded
        axis, or a local extent too small to hold an interior) — the
        caller falls back to the plain exchange-then-apply path.
        """
        depth = int(self.fuse_steps)
        rads = self.radius_per_axis
        wrads = [r * depth for r in rads]  # halo consumed per output
        arads = [r * (depth - 1) for r in rads]  # aux ghost width
        spatial_axes = tuple(range(1, f_local.ndim))
        sharded = [
            (ax, w)
            for ax, w, name in zip(spatial_axes, wrads, mesh_axes)
            if name is not None and w > 0
        ]
        if not sharded:
            return None  # nothing to overlap with
        if any(f_local.shape[ax] <= 2 * w for ax, w in sharded):
            return None  # no interior: every point depends on halos

        # Emit the exchange FIRST: the permutes depend only on edge
        # planes, the interior compute below only on local data, so the
        # scheduler can run them concurrently.
        fp = exchange_halos_nd(
            f_local, wrads, mesh_axes, spatial_axes=spatial_axes,
        )
        # The carry is exchanged at its own (narrower) width; at depth 1
        # that width is zero and aux_p is aux itself. Unsharded axes get
        # the local periodic wrap inside exchange_halos_nd.
        aux_p = None
        if aux is not None:
            aux_p = exchange_halos_nd(
                aux, arads, mesh_axes,
                spatial_axes=tuple(range(1, aux.ndim)),
            )

        # Interior: along each sharded axis the local block IS the
        # interior plus its (not-yet-arrived) halo, so it only needs
        # local periodic padding on the unsharded axes.
        pad_width = [(0, 0)] * f_local.ndim
        for ax, w, name in zip(spatial_axes, wrads, mesh_axes):
            if name is None and w > 0:
                pad_width[ax] = (w, w)
        f_interior_padded = jnp.pad(f_local, pad_width, mode="wrap")
        interior_view, edges = interior_first(
            f_local, [w for _, w in sharded], [ax for ax, _ in sharded]
        )
        int_sl = [slice(None)] * f_local.ndim
        aux_sl = [slice(None)] * f_local.ndim
        for ax, w in sharded:
            int_sl[ax] = slice(w, f_local.shape[ax] - w)
            # aux_p leads local coords by arads; the interior's aux
            # window spans interior ± arads on every sharded axis.
            a = arads[ax - 1]
            aux_sl[ax] = slice(w, f_local.shape[ax] - w + 2 * a)
        aux_int = aux_p[tuple(aux_sl)] if aux_p is not None else None
        out_interior = self.apply_padded(f_interior_padded, aux=aux_int)
        assert out_interior.shape[1:] == interior_view.shape[1:]

        out = jnp.zeros(
            (self.n_out,) + f_local.shape[1:], out_interior.dtype
        )
        out = out.at[tuple(int_sl)].set(out_interior)

        # Edge slabs depend on the exchanged halos: recompute each slab
        # from the padded array. Slabs span the full extent of the other
        # axes, so corner regions are (idempotently) covered.
        for ax, sl in edges:
            n_ax = f_local.shape[ax]
            s = sl.start or 0
            e = n_ax if sl.stop is None else sl.stop
            w_ax = wrads[ax - 1]
            a_ax = arads[ax - 1]
            w_sl = [slice(None)] * fp.ndim
            w_sl[ax] = slice(s, e + 2 * w_ax)
            slab_out = self.apply_padded(
                fp[tuple(w_sl)],
                aux=None if aux_p is None else aux_p[
                    tuple(
                        slice(s, e + 2 * a_ax) if a == ax else slice(None)
                        for a in range(aux_p.ndim)
                    )
                ],
            )
            o_sl = [slice(None)] * out.ndim
            o_sl[ax] = slice(s, e)
            out = out.at[tuple(o_sl)].set(slab_out)
        return out


def integrate(
    op: FusedStencilOp, f0: jnp.ndarray, n_steps: int
) -> jnp.ndarray:
    """Iterate f ← φ(A·B(ψ(f))) for ``n_steps`` TIME steps with lax
    control flow (paper Fig. 1).

    With temporal fusion each scan iteration advances ``op.fuse_steps``
    steps in one kernel; a remainder ``n_steps % fuse_steps`` is
    finished with a shallower op so the step count is exact.
    ``fuse_steps="auto"`` (and ``strategy="auto"``) is resolved once,
    up front, against ``f0`` — except the remainder launch, which does
    NOT reuse the block tuned for the full depth: when the caller asked
    for ``block="auto"``, the depth-``rem`` op resolves through its own
    tuning key (a depth-``S`` winner is generally mistuned at depth
    ``rem`` — the halo, VMEM window, and traffic model all change with
    the depth). An explicit block is reused as given.

    Args:
        op: the fused update to iterate (one uniform φ — per-step φ
            sequences are driven by their solver, not ``integrate``).
        f0: initial (n_f, *spatial) field stack.
        n_steps: exact number of TIME steps to advance.

    Returns:
        The (n_f, *spatial) field stack after ``n_steps`` steps.

    Raises:
        ValueError: when ``op.phi`` is a per-step sequence.

    Example::

        >>> from repro.physics.diffusion import DiffusionProblem
        >>> from repro.core.fusion import integrate
        >>> p = DiffusionProblem((16, 32), accuracy=6)
        >>> op = p.step_op("swc", fuse_steps=2)
        >>> out = integrate(op, p.init_field(), 7)  # 3 fused + 1 plain
        >>> out.shape
        (1, 16, 32)
    """
    requested_block = op.block  # before resolution concretizes it
    op = op.resolved(f0)
    depth = int(op.fuse_steps)
    if depth > 1 and isinstance(op.phi, (tuple, list)):
        raise ValueError(
            "integrate() iterates one uniform map — per-step phi "
            "sequences (RK substep fusion) are driven by their solver"
        )
    full, rem = divmod(n_steps, depth)

    def body(f, _):
        """One fused launch: advance ``depth`` time steps."""
        return op(f), None

    out, _ = jax.lax.scan(body, f0, None, length=full)
    if rem:
        # The remainder runs at depth `rem`, not depth `S`: give it back
        # the caller's "auto" block so it resolves under its own
        # depth-`rem` tuning key instead of inheriting the depth-`S`
        # winner (an explicit block is reused as documented above).
        rem_block = "auto" if requested_block == "auto" else op.block
        out = dataclasses.replace(
            op, fuse_steps=rem, block=rem_block
        )(out)
    return out
