"""Roofline analysis from compiled HLO — the dry-run "profiler".

This container has no TPU, so the profile is structural (per the brief):

  compute term    = HLO_FLOPs   / (chips × peak_FLOP/s)
  memory term     = HLO_bytes   / (chips × HBM_bw)
  collective term = coll_bytes  / (chips × link_bw)

FLOPs/bytes come from ``compiled.cost_analysis()``; collective bytes are
parsed out of the HLO text (cost_analysis does not attribute them).

The machine-balance framing mirrors the paper's Sec. 2.1/Table 1: TPU
v5e-class constants give balance = 197e12 / 819e9 ≈ 240 bf16 FLOP per
byte — stencil kernels sit far below it (memory-bound), dense matmul
training sits near or above it (compute-bound), which is exactly the
regime split the paper studies on GPUs.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Any

# --- hardware constants (brief-specified, TPU v5e class) -------------------


@dataclasses.dataclass(frozen=True)
class HardwareSpec:
    name: str
    peak_flops_bf16: float  # FLOP/s per chip
    peak_flops_f32: float
    hbm_bw: float  # bytes/s per chip
    ici_bw: float  # bytes/s per link (per direction)
    vmem_bytes: int
    hbm_bytes: int
    tdp_watts: float

    def machine_balance(self, dtype_bytes: int = 2) -> float:
        peak = self.peak_flops_bf16 if dtype_bytes == 2 else self.peak_flops_f32
        return peak / self.hbm_bw


TPU_V5E = HardwareSpec(
    name="tpu-v5e",
    peak_flops_bf16=197e12,
    peak_flops_f32=98.5e12,  # half-rate fp32 on the MXU
    hbm_bw=819e9,
    ici_bw=50e9,
    vmem_bytes=128 * 1024 * 1024,
    hbm_bytes=16 * 1024 * 1024 * 1024,
    tdp_watts=200.0,
)

# --- HLO collective parsing -------------------------------------------------

_COLLECTIVES = (
    "all-gather",
    "all-reduce",
    "reduce-scatter",
    "all-to-all",
    "collective-permute",
)

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16, "s4": 1, "u4": 1, "f8e4m3fn": 1, "f8e5m2": 1,
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_GROUPS_RE = re.compile(r"replica_groups=\{(\{[^}]*\}(?:,\{[^}]*\})*)\}")
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")


def _shape_bytes(type_str: str) -> int:
    """Total bytes of a (possibly tuple) HLO type string."""
    total = 0
    for dtype, dims in _SHAPE_RE.findall(type_str):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                if d:
                    n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


def _group_size(line: str) -> int | None:
    m = _GROUPS_IOTA_RE.search(line)
    if m:  # replica_groups=[G,S] iota form: G groups of size S
        return int(m.group(2))
    m = _GROUPS_RE.search(line)
    if m:
        first = m.group(1).split("},")[0].strip("{}")
        if first:
            return len(first.split(","))
    return None


@dataclasses.dataclass
class CollectiveStats:
    """Per-collective byte totals parsed from an HLO module."""

    result_bytes: dict[str, int]
    wire_bytes: dict[str, int]  # ring-model per-chip wire traffic
    counts: dict[str, int]

    @property
    def total_result_bytes(self) -> int:
        return sum(self.result_bytes.values())

    @property
    def total_wire_bytes(self) -> int:
        return sum(self.wire_bytes.values())


def parse_collectives(hlo_text: str) -> CollectiveStats:
    """Sum collective operand/result sizes from HLO text.

    Wire model (per participating chip, bidirectional ring):
      all-gather:        out × (g-1)/g        (out = gathered result)
      reduce-scatter:    in  × (g-1)/g  =  out × (g-1)
      all-reduce:        2 × size × (g-1)/g
      all-to-all:        size × (g-1)/g
      collective-permute: size
    """
    result_bytes: dict[str, int] = {c: 0 for c in _COLLECTIVES}
    wire_bytes: dict[str, int] = {c: 0 for c in _COLLECTIVES}
    counts: dict[str, int] = {c: 0 for c in _COLLECTIVES}
    for line in hlo_text.splitlines():
        line = line.strip()
        if " = " not in line:
            continue
        lhs, rhs = line.split(" = ", 1)
        del lhs
        m = re.match(r"((?:\([^)]*\)|\S+))\s+([\w-]+)", rhs)
        if not m:
            continue
        type_str, opname = m.group(1), m.group(2)
        op = None
        for c in _COLLECTIVES:
            if opname == c or opname == c + "-start" or opname.startswith(c + "."):
                op = c
                break
        if op is None:
            continue
        nbytes = _shape_bytes(type_str)
        g = _group_size(line) or 1
        counts[op] += 1
        result_bytes[op] += nbytes
        if op == "all-gather":
            wire_bytes[op] += int(nbytes * (g - 1) / max(g, 1))
        elif op == "reduce-scatter":
            wire_bytes[op] += int(nbytes * (g - 1))
        elif op == "all-reduce":
            wire_bytes[op] += int(2 * nbytes * (g - 1) / max(g, 1))
        elif op == "all-to-all":
            wire_bytes[op] += int(nbytes * (g - 1) / max(g, 1))
        else:  # collective-permute
            wire_bytes[op] += nbytes
    return CollectiveStats(result_bytes, wire_bytes, counts)


# --- roofline terms ----------------------------------------------------------


@dataclasses.dataclass
class Roofline:
    flops: float  # per-device HLO FLOPs (SPMD program)
    hbm_bytes: float  # per-device HLO bytes accessed
    collective_result_bytes: float
    collective_wire_bytes: float
    chips: int
    hw: HardwareSpec
    dtype_bytes: int = 2

    @property
    def compute_s(self) -> float:
        peak = (
            self.hw.peak_flops_bf16
            if self.dtype_bytes == 2
            else self.hw.peak_flops_f32
        )
        return self.flops / peak

    @property
    def memory_s(self) -> float:
        return self.hbm_bytes / self.hw.hbm_bw

    @property
    def collective_s(self) -> float:
        # Brief formula: collective_bytes / (chips × link_bw), evaluated
        # with the per-chip wire model (each chip drives its own links;
        # per-chip wire bytes over per-link bandwidth).
        return self.collective_wire_bytes / self.hw.ici_bw

    @property
    def dominant(self) -> str:
        terms = {
            "compute": self.compute_s,
            "memory": self.memory_s,
            "collective": self.collective_s,
        }
        return max(terms, key=terms.get)

    @property
    def bound_s(self) -> float:
        return max(self.compute_s, self.memory_s, self.collective_s)

    def useful_flops_fraction(self, model_flops_per_chip: float) -> float:
        """MODEL_FLOPS / HLO_FLOPs — catches remat/redundancy waste."""
        return model_flops_per_chip / max(self.flops, 1.0)

    def roofline_fraction(self, model_flops_per_chip: float) -> float:
        """Useful-FLOP throughput vs peak if the step ran at its bound:
        (model FLOPs / bound-time) / peak — the MFU-style score."""
        peak = (
            self.hw.peak_flops_bf16
            if self.dtype_bytes == 2
            else self.hw.peak_flops_f32
        )
        return model_flops_per_chip / (self.bound_s * peak)

    def summary(self) -> dict[str, Any]:
        return {
            "flops": self.flops,
            "hbm_bytes": self.hbm_bytes,
            "coll_result_bytes": self.collective_result_bytes,
            "coll_wire_bytes": self.collective_wire_bytes,
            "compute_s": self.compute_s,
            "memory_s": self.memory_s,
            "collective_s": self.collective_s,
            "dominant": self.dominant,
        }


def from_compiled(
    compiled,
    hlo_text: str,
    *,
    chips: int,
    hw: HardwareSpec = TPU_V5E,
    dtype_bytes: int = 2,
) -> Roofline:
    """Build roofline terms from a compiled executable + its HLO text."""
    ca = compiled.cost_analysis()
    if isinstance(ca, (list, tuple)):
        ca = ca[0]
    flops = float(ca.get("flops", 0.0))
    nbytes = float(ca.get("bytes accessed", 0.0))
    coll = parse_collectives(hlo_text)
    return Roofline(
        flops=flops,
        hbm_bytes=nbytes,
        collective_result_bytes=float(coll.total_result_bytes),
        collective_wire_bytes=float(coll.total_wire_bytes),
        chips=chips,
        hw=hw,
        dtype_bytes=dtype_bytes,
    )


def model_flops_train(n_params: float, n_tokens: float) -> float:
    """6·N·D (fwd 2ND + bwd 4ND) — dense; pass active params for MoE."""
    return 6.0 * n_params * n_tokens


def model_flops_decode(n_params: float, n_tokens: float) -> float:
    """2·N per generated token (fwd only)."""
    return 2.0 * n_params * n_tokens


def stencil_ideal_bytes(
    n_points: float, n_f: int, n_out: int, dtype_bytes: int
) -> float:
    """The paper's 'ideal performance' bound (Sec. 5.4): the domain is
    read and written exactly once at peak bandwidth."""
    return n_points * (n_f + n_out) * dtype_bytes


def stencil_mxu_roof_s(
    flops: float, dtype_bytes: int = 4, hw: HardwareSpec = TPU_V5E
) -> float:
    """Compute roof next to the bandwidth roof: seconds the strategy
    ``"tc"`` matmul lowering needs at peak MXU rate for ``flops``
    contraction FLOPs (``stencil_mxu_flops_per_step``). bf16 inputs run
    the MXU at double the f32-accumulate rate, mirroring
    ``trafficmodel.peak_mxu_flops``."""
    peak = hw.peak_flops_bf16 if dtype_bytes == 2 else hw.peak_flops_f32
    return flops / peak
