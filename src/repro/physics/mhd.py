"""Compressible non-ideal magnetohydrodynamics (paper Sec. 3.3, App. A).

Eight coupled fields — log-density lnρ, velocity u (3), specific entropy
s, magnetic vector potential A (3) — advanced with explicit third-order
2N-storage Runge-Kutta (Williamson), spatial derivatives from 6th-order
central differences (radius-3 stencils): exactly the paper's setup, with
the ideal-gas law closing the thermodynamics.

The whole right-hand side is ONE fused stencil operation (paper Eq. 9):
the 10-operator derivative set is evaluated for all 8 fields (Q = A·B,
n_s = 10, n_f = 8, pruned n_k = 127) and the nonlinear map φ below turns
Q into the 8 time derivatives without any intermediate HBM round-trip.

Equations (App. A, non-conservative form):

  D lnρ/Dt = −∇·u
  D u/Dt   = −c_s²∇(s/c_p + lnρ) + j×B/ρ
             + ν[∇²u + ⅓∇(∇·u) + 2S·∇lnρ] + ζ∇(∇·u)
  ρT Ds/Dt = H − C + ∇·(K∇T) + ημ₀j² + 2ρν S⊗S + ζρ(∇·u)²
  ∂A/∂t    = u×B + η∇²A

with B = ∇×A, j = μ₀⁻¹∇×B = μ₀⁻¹(∇(∇·A) − ∇²A), S the traceless
rate-of-shear tensor, and ideal-gas closure
  c_s² = c_s0² · exp(γ s/c_p + (γ−1)(lnρ − lnρ₀)),
  ln T = ln T₀ + γ s/c_p + (γ−1)(lnρ − lnρ₀).
"""
from __future__ import annotations

import dataclasses
from typing import Mapping

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.fusion import FusedStencilOp
from repro.core.stencil import OperatorSet, derivative_operator_set

# Field indices in the (8, z, y, x) stack.
LNRHO = 0
UX, UY, UZ = 1, 2, 3
SS = 4
AX, AY, AZ = 5, 6, 7
N_FIELDS = 8
FIELD_NAMES = ("lnrho", "ux", "uy", "uz", "ss", "ax", "ay", "az")

# Williamson 2N-storage RK3 (the Astaroth/Pencil integrator).
RK3_ALPHA = (0.0, -5.0 / 9.0, -153.0 / 128.0)
RK3_BETA = (1.0 / 3.0, 15.0 / 16.0, 8.0 / 15.0)


@dataclasses.dataclass(frozen=True)
class MHDParams:
    nu: float = 5e-3  # kinematic viscosity
    zeta: float = 0.0  # bulk viscosity
    eta: float = 5e-3  # magnetic diffusivity
    mu0: float = 1.0  # vacuum permeability
    cp: float = 1.0  # specific heat, constant pressure
    gamma: float = 5.0 / 3.0  # adiabatic index
    cs0: float = 1.0  # sound speed at reference state
    lnrho0: float = 0.0  # reference log density
    kappa: float = 1e-3  # radiative conductivity K
    heat: float = 0.0  # explicit heating H
    cool: float = 0.0  # explicit cooling C

    @property
    def cv(self) -> float:
        return self.cp / self.gamma

    @property
    def lnT0(self) -> float:
        # c_s0² = (γ−1)·c_p·T0
        T0 = self.cs0**2 / ((self.gamma - 1.0) * self.cp)
        return float(np.log(T0))


def mhd_rhs_phi(params: MHDParams):
    """Build φ: derivative tensor Q → the 8 field time-derivatives.

    ``derivs[name]`` has shape (8, *tile); returns (8, *tile). Pure
    point-wise jnp — runs identically inside the Pallas block kernel and
    the XLA-managed reference path.
    """
    p = params
    g = p.gamma

    def phi(d: Mapping[str, jnp.ndarray]) -> jnp.ndarray:
        val = d["val"]
        dx, dy, dz = d["dx"], d["dy"], d["dz"]
        dxx, dyy, dzz = d["dxx"], d["dyy"], d["dzz"]
        dxy, dxz, dyz = d["dxy"], d["dxz"], d["dyz"]
        dtype = val.dtype

        def c(x):
            return jnp.asarray(x, dtype=dtype)

        lnrho = val[LNRHO]
        u = val[UX : UZ + 1]  # (3, *tile)
        ss = val[SS]

        # First derivatives, indexed [component][axis].
        grad = lambda i: jnp.stack([dx[i], dy[i], dz[i]])  # noqa: E731
        grad_lnrho = grad(LNRHO)
        grad_ss = grad(SS)
        div_u = dx[UX] + dy[UY] + dz[UZ]
        lap = lambda i: dxx[i] + dyy[i] + dzz[i]  # noqa: E731

        # u advection helper: (u·∇)q.
        def advect(gq):
            return u[0] * gq[0] + u[1] * gq[1] + u[2] * gq[2]

        # --- magnetic quantities ------------------------------------------
        B = jnp.stack(
            [
                dy[AZ] - dz[AY],
                dz[AX] - dx[AZ],
                dx[AY] - dy[AX],
            ]
        )
        # j = μ0⁻¹ (∇(∇·A) − ∇²A)
        grad_div_a = jnp.stack(
            [
                dxx[AX] + dxy[AY] + dxz[AZ],
                dxy[AX] + dyy[AY] + dyz[AZ],
                dxz[AX] + dyz[AY] + dzz[AZ],
            ]
        )
        lap_a = jnp.stack([lap(AX), lap(AY), lap(AZ)])
        jj = (grad_div_a - lap_a) / c(p.mu0)
        j2 = jj[0] ** 2 + jj[1] ** 2 + jj[2] ** 2

        # --- thermodynamics (ideal gas closure) ---------------------------
        s_over_cp = ss / c(p.cp)
        cs2 = c(p.cs0**2) * jnp.exp(
            c(g) * s_over_cp + c(g - 1.0) * (lnrho - c(p.lnrho0))
        )
        rho = jnp.exp(lnrho)
        lnT = c(p.lnT0) + c(g) * s_over_cp + c(g - 1.0) * (
            lnrho - c(p.lnrho0)
        )
        T = jnp.exp(lnT)

        # --- rate-of-shear tensor S (traceless, symmetric) ----------------
        du = [
            [dx[UX], dy[UX], dz[UX]],
            [dx[UY], dy[UY], dz[UY]],
            [dx[UZ], dy[UZ], dz[UZ]],
        ]  # du[i][j] = ∂u_i/∂x_j
        third_div = div_u / c(3.0)
        S = [[None] * 3 for _ in range(3)]
        for i in range(3):
            for jx in range(3):
                S[i][jx] = c(0.5) * (du[i][jx] + du[jx][i])
            S[i][i] = S[i][i] - third_div
        SS_contract = sum(S[i][jx] ** 2 for i in range(3) for jx in range(3))
        # 2 S·∇lnρ (vector)
        S_dot_glnrho = jnp.stack(
            [
                sum(S[i][jx] * grad_lnrho[jx] for jx in range(3))
                for i in range(3)
            ]
        )

        # --- continuity -----------------------------------------------------
        dlnrho_dt = -advect(grad_lnrho) - div_u

        # --- momentum -------------------------------------------------------
        grad_div_u = jnp.stack(
            [
                dxx[UX] + dxy[UY] + dxz[UZ],
                dxy[UX] + dyy[UY] + dyz[UZ],
                dxz[UX] + dyz[UY] + dzz[UZ],
            ]
        )
        lap_u = jnp.stack([lap(UX), lap(UY), lap(UZ)])
        jxB = jnp.stack(
            [
                jj[1] * B[2] - jj[2] * B[1],
                jj[2] * B[0] - jj[0] * B[2],
                jj[0] * B[1] - jj[1] * B[0],
            ]
        )
        adv_u = jnp.stack([advect(grad(UX + i)) for i in range(3)])
        pressure = cs2 * (grad_ss / c(p.cp) + grad_lnrho)
        viscous = c(p.nu) * (
            lap_u + grad_div_u / c(3.0) + c(2.0) * S_dot_glnrho
        ) + c(p.zeta) * grad_div_u
        du_dt = -adv_u - pressure + jxB / rho + viscous

        # --- entropy --------------------------------------------------------
        # ∇·(K∇T) = K·T·(∇²lnT + |∇lnT|²), constant K.
        grad_lnT = c(g / p.cp) * grad_ss + c(g - 1.0) * grad_lnrho
        lap_lnT = c(g / p.cp) * lap(SS) + c(g - 1.0) * lap(LNRHO)
        div_K_gradT = c(p.kappa) * T * (
            lap_lnT
            + grad_lnT[0] ** 2
            + grad_lnT[1] ** 2
            + grad_lnT[2] ** 2
        )
        heating = (
            c(p.heat - p.cool)
            + div_K_gradT
            + c(p.eta * p.mu0) * j2
            + c(2.0 * p.nu) * rho * SS_contract
            + c(p.zeta) * rho * div_u**2
        )
        dss_dt = -advect(grad_ss) + heating / (rho * T)

        # --- induction ------------------------------------------------------
        uxB = jnp.stack(
            [
                u[1] * B[2] - u[2] * B[1],
                u[2] * B[0] - u[0] * B[2],
                u[0] * B[1] - u[1] * B[0],
            ]
        )
        dA_dt = uxB + c(p.eta) * lap_a

        return jnp.concatenate(
            [dlnrho_dt[None], du_dt, dss_dt[None], dA_dt]
        )

    return phi


@dataclasses.dataclass(frozen=True)
class MHDSolver:
    """Fused-stencil MHD integrator over a periodic (n, n, n) box of
    extent 2π (paper Table B2: Δs = 2π, one full period per axis).

    ``strategy="auto"`` hands the caching-regime choice (hwc, swc,
    swc_stream, or the MXU ``tc`` lowering) to the cross-strategy
    tuning search (the ``block`` default is then ignored
    — the search owns the block). The RHS op is a shape-level self-map
    (n_out == n_f) but NOT a time-step, so depth stays pinned at 1:
    only strategy and block are searched.
    """

    shape: tuple[int, int, int]
    params: MHDParams = MHDParams()
    accuracy: int = 6
    strategy: str = "hwc"
    block: tuple[int, int, int] | str = (8, 8, 128)  # or "auto"
    fuse_rk_axpy: bool = False  # beyond-paper: fold the RK update into φ
    # Temporal fusion of the RK3 substeps: substeps 1+2 run as ONE
    # depth-2 kernel (per-substep φ with the w carry threaded through
    # VMEM), substep 3 as a depth-1 fused-axpy kernel — two launches
    # per RK3 step instead of three, one fewer full-stack HBM round
    # trip. Implies the fused-axpy formulation.
    fuse_rk_pairs: bool = False

    @property
    def spacing(self) -> tuple[float, float, float]:
        return tuple(2.0 * np.pi / n for n in self.shape)

    @property
    def operator_set(self) -> OperatorSet:
        return derivative_operator_set(3, self.accuracy, self.spacing)

    @property
    def op_block(self) -> tuple[int, int, int] | str:
        """Block forwarded to the fused ops: ``strategy="auto"`` owns
        the block (the cross-strategy search resolves it), so the
        class-default tile is overridden to ``"auto"`` there."""
        return "auto" if self.strategy == "auto" else self.block

    def rhs_op(self) -> FusedStencilOp:
        return FusedStencilOp(
            ops=self.operator_set,
            phi=mhd_rhs_phi(self.params),
            n_out=N_FIELDS,
            boundary_mode="periodic",
            strategy=self.strategy,
            block=self.op_block,
        )

    def _substep_phi(self, alpha: float, beta: float, dt):
        """φ for one fused-axpy RK substep: w' = αw + Δt·RHS(f),
        f' = f + βw' (aux = w). Output rows 0..7 = f', 8..15 = w' — a
        self-map over (f, w), which is exactly what temporal fusion
        needs to chain substeps in one kernel."""
        rhs_phi = mhd_rhs_phi(self.params)

        def phi(d, aux):
            rhs = rhs_phi(d)
            w_new = jnp.asarray(alpha, rhs.dtype) * aux + jnp.asarray(
                dt, rhs.dtype
            ) * rhs
            f_new = d["val"] + jnp.asarray(beta, rhs.dtype) * w_new
            return jnp.concatenate([f_new, w_new])

        return phi

    def _fused_substep_op(self, alpha: float, beta: float, dt) -> FusedStencilOp:
        """One kernel running one fused-axpy RK substep."""
        return FusedStencilOp(
            ops=self.operator_set,
            phi=self._substep_phi(alpha, beta, dt),
            n_out=2 * N_FIELDS,
            boundary_mode="periodic",
            strategy=self.strategy,
            block=self.op_block,
        )

    def _fused_pair_op(self, dt) -> FusedStencilOp:
        """RK3 substeps 1+2 as ONE temporal-depth-2 kernel: per-substep
        φs applied back to back on a halo-widened VMEM block, the (f, w)
        intermediate never touching HBM."""
        return FusedStencilOp(
            ops=self.operator_set,
            phi=(
                self._substep_phi(RK3_ALPHA[0], RK3_BETA[0], dt),
                self._substep_phi(RK3_ALPHA[1], RK3_BETA[1], dt),
            ),
            n_out=2 * N_FIELDS,
            boundary_mode="periodic",
            strategy=self.strategy,
            block=self.op_block,
            fuse_steps=2,
        )

    def rhs(self, f: jnp.ndarray) -> jnp.ndarray:
        """Time derivatives of all fields: one fused φ(A·B) application."""
        return self.rhs_op()(f)

    def step(self, f: jnp.ndarray, dt: float) -> jnp.ndarray:
        """One full RK3 step (three fused substeps — paper Sec. 3.3;
        two kernel launches with ``fuse_rk_pairs``)."""
        if self.fuse_rk_pairs:
            w = jnp.zeros_like(f)
            out = self._fused_pair_op(dt)(f, aux=w)
            f, w = out[:N_FIELDS], out[N_FIELDS:]
            out = self._fused_substep_op(
                RK3_ALPHA[2], RK3_BETA[2], dt
            )(f, aux=w)
            return out[:N_FIELDS]
        if self.fuse_rk_axpy:
            w = jnp.zeros_like(f)
            for a, b in zip(RK3_ALPHA, RK3_BETA):
                out = self._fused_substep_op(a, b, dt)(f, aux=w)
                f, w = out[:N_FIELDS], out[N_FIELDS:]
            return f
        op = self.rhs_op()
        w = jnp.zeros_like(f)
        for a, b in zip(RK3_ALPHA, RK3_BETA):
            w = jnp.asarray(a, f.dtype) * w + jnp.asarray(dt, f.dtype) * op(f)
            f = f + jnp.asarray(b, f.dtype) * w
        return f

    def cfl_dt(self, f: jnp.ndarray, cdt: float = 0.4, cdtv: float = 0.3):
        """Advective + diffusive CFL bound (Brandenburg 2003 form)."""
        p = self.params
        h = min(self.spacing)
        u = f[UX : UZ + 1]
        umax = jnp.max(jnp.sqrt(jnp.sum(u * u, axis=0)))
        cs2_max = jnp.max(
            p.cs0**2
            * jnp.exp(
                p.gamma * f[SS] / p.cp
                + (p.gamma - 1.0) * (f[LNRHO] - p.lnrho0)
            )
        )
        v_signal = umax + jnp.sqrt(cs2_max)
        dt_adv = cdt * h / jnp.maximum(v_signal, 1e-30)
        diff_max = max(p.nu, p.eta, p.kappa / p.cp)
        dt_diff = cdtv * h * h / max(diff_max, 1e-30)
        return jnp.minimum(dt_adv, dt_diff)

    def simulate(
        self, f0: jnp.ndarray, n_steps: int, dt: float
    ) -> jnp.ndarray:
        step = self.step

        @jax.jit
        def run(f):
            def body(fc, _):
                return step(fc, dt), None

            out, _ = jax.lax.scan(body, f, None, length=n_steps)
            return out

        return run(f0)

    def init_fields(
        self, seed: int = 0, amplitude: float = 1e-5, dtype=jnp.float32
    ) -> jnp.ndarray:
        """Paper Table B2 benchmark init: uniform in (−amplitude, amplitude]."""
        rng = np.random.default_rng(seed)
        f = rng.uniform(-amplitude, amplitude, size=(N_FIELDS,) + self.shape)
        return jnp.asarray(f, dtype=dtype)

    def init_smooth(self, seed: int = 0, amplitude: float = 1e-3,
                    kmax: int = 2, dtype=jnp.float64) -> jnp.ndarray:
        """Band-limited random init (low-k Fourier modes) — smooth enough
        that 6th-order FD and the spectral oracle agree tightly."""
        rng = np.random.default_rng(seed)
        nz, ny, nx = self.shape
        zz, yy, xx = np.meshgrid(
            *(np.linspace(0, 2 * np.pi, n, endpoint=False) for n in self.shape),
            indexing="ij",
        )
        f = np.zeros((N_FIELDS,) + self.shape)
        for fi in range(N_FIELDS):
            for _ in range(3):
                k = rng.integers(-kmax, kmax + 1, size=3)
                ph = rng.uniform(0, 2 * np.pi)
                amp = rng.uniform(0.3, 1.0) * amplitude
                f[fi] += amp * np.cos(k[0] * zz + k[1] * yy + k[2] * xx + ph)
        return jnp.asarray(f, dtype=dtype)
