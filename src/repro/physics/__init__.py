"""The paper's test problems: cross-correlation baselines, the diffusion
equation (Sec. 3.2), and compressible non-ideal MHD (Sec. 3.3 / App. A),
all built on the fused stencil engine in :mod:`repro.core`."""
