"""Spectral (FFT) derivative oracle for validating the FD stencil engine.

On a periodic band-limited field, spectral derivatives are exact; the
6th-order FD derivatives must agree to their truncation error. Feeding
the SAME φ both derivative sets validates the entire fused pipeline's
calculus independently of the stencil machinery — the analogue of the
paper's model-solution verification (Sec. 5.1) where closed-form answers
don't exist (MHD).
"""
from __future__ import annotations

from typing import Mapping

import jax.numpy as jnp
import numpy as np


def _wavenumbers(shape: tuple[int, ...], spacing: tuple[float, ...]):
    return [
        2.0 * np.pi * np.fft.fftfreq(n, d=h)
        for n, h in zip(shape, spacing)
    ]


def spectral_derivatives(
    f: np.ndarray, spacing: tuple[float, ...]
) -> dict[str, np.ndarray]:
    """All 10 derivative operators of the MHD set, spectrally.

    ``f``: (n_f, z, y, x) float64. Returns {name: (n_f, z, y, x)}.
    """
    f = np.asarray(f, dtype=np.float64)
    shape = f.shape[1:]
    kz, ky, kx = _wavenumbers(shape, spacing)
    KZ = kz[:, None, None]
    KY = ky[None, :, None]
    KX = kx[None, None, :]
    fh = np.fft.fftn(f, axes=(1, 2, 3))

    def inv(spec):
        return np.real(np.fft.ifftn(spec, axes=(1, 2, 3)))

    out: dict[str, np.ndarray] = {"val": f.copy()}
    out["dx"] = inv(1j * KX * fh)
    out["dy"] = inv(1j * KY * fh)
    out["dz"] = inv(1j * KZ * fh)
    out["dxx"] = inv(-(KX**2) * fh)
    out["dyy"] = inv(-(KY**2) * fh)
    out["dzz"] = inv(-(KZ**2) * fh)
    out["dxy"] = inv(-(KX * KY) * fh)
    out["dxz"] = inv(-(KX * KZ) * fh)
    out["dyz"] = inv(-(KY * KZ) * fh)
    return out


def spectral_rhs(
    f: np.ndarray, spacing: tuple[float, ...], phi
) -> np.ndarray:
    """Evaluate a φ on spectrally-exact derivatives (float64)."""
    derivs = spectral_derivatives(f, spacing)
    derivs_j: Mapping[str, jnp.ndarray] = {
        k: jnp.asarray(v) for k, v in derivs.items()
    }
    return np.asarray(phi(derivs_j))
