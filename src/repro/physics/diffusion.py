"""Diffusion equation ∂f/∂t = α∇²f as a linear stencil computation
(paper Sec. 3.2, Figs. 10-12).

Forward-Euler time integration folds into a SINGLE merged cross-
correlation kernel g = c^(1) + Δt·α·c^(2) (paper Eqs. 5-7): one stencil
application per step, any dimensionality, any even accuracy order.
"""
from __future__ import annotations

import dataclasses
from typing import Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.fusion import FusedStencilOp
from repro.core.stencil import (
    OperatorSet,
    diffusion_kernel_1d,
    diffusion_kernel_nd,
)
from repro.kernels import ops as kops


@dataclasses.dataclass(frozen=True)
class DiffusionProblem:
    """Numerical setup following the paper's App. B (Table B2): periodic
    domain of extent 2π per axis, Δs_i = 2π/n_i."""

    shape: tuple[int, ...]  # grid points per axis (z, y, x ordering)
    accuracy: int = 6  # FD accuracy order (radius = accuracy // 2)
    alpha: float = 1.0
    safety: float = 0.2  # dt = safety · min(Δs)² / (2·d·α)

    @property
    def ndim(self) -> int:
        return len(self.shape)

    @property
    def spacing(self) -> tuple[float, ...]:
        return tuple(2.0 * np.pi / n for n in self.shape)

    @property
    def dt(self) -> float:
        d = self.ndim
        h = min(self.spacing)
        return self.safety * h * h / (2.0 * d * self.alpha)

    @property
    def radius(self) -> int:
        return self.accuracy // 2

    def merged_stencil(self):
        """Paper Eq. 7: identity + Δt·α·∇² as one stencil."""
        return diffusion_kernel_nd(
            self.ndim, self.accuracy, self.dt, self.alpha, self.spacing
        )

    def step_op(
        self,
        strategy: str = "hwc",
        block: tuple[int, ...] | str | None = None,
        fuse_steps: int | str = 1,
    ) -> FusedStencilOp:
        """One forward-Euler step as a fused op. ``strategy="swc"``
        lowers through the rank-generic engine at any dimensionality
        (1-D/2-D/3-D), ``strategy="swc_stream"`` through the
        explicit-streaming kernel (2-D/3-D), and ``strategy="tc"``
        through the MXU matmul lowering (any rank; f32/bf16 fields);
        ``strategy="auto"`` lets the cross-strategy tuning search pick
        the caching regime itself (hwc vs swc vs swc_stream vs tc,
        jointly with block/depth/stream —
        ``block`` defaults to ``"auto"`` in that case). ``block`` is a
        rank-length tile, ``"auto"`` for the persistent tuning cache,
        or None for the per-rank default. ``fuse_steps`` is the
        temporal-fusion depth (each op call then advances that many
        Euler steps in one kernel, streamed or pipelined); ``"auto"``
        resolves block and depth jointly from the traffic model.
        """
        spec = dataclasses.replace(self.merged_stencil(), name="step")  # type: ignore[arg-type]
        ops = OperatorSet((spec,))
        return FusedStencilOp(
            ops=ops,
            phi=lambda d: d["step"],
            n_out=1,
            boundary_mode="periodic",
            strategy=strategy,
            block=block,
            fuse_steps=fuse_steps,
        )

    def init_field(self, seed: int = 0, amplitude: float = 1e-5) -> jnp.ndarray:
        """Benchmark initialization (paper Table B2: random in
        (-1e-5, 1e-5] for benchmarks)."""
        rng = np.random.default_rng(seed)
        f = rng.uniform(-amplitude, amplitude, size=self.shape)
        return jnp.asarray(f[None], dtype=jnp.float32)  # (n_f=1, *shape)

    def fourier_mode(self, k: Sequence[int]) -> jnp.ndarray:
        """sin(k·x) eigenmode — decays analytically as exp(-α|k|²t)."""
        axes = [
            np.linspace(0.0, 2.0 * np.pi, n, endpoint=False)
            for n in self.shape
        ]
        grids = np.meshgrid(*axes, indexing="ij")
        phase = sum(ki * gi for ki, gi in zip(k, grids))
        return jnp.asarray(np.sin(phase)[None], dtype=jnp.float64)

    def analytic_decay(self, k: Sequence[int], t: float) -> float:
        return float(np.exp(-self.alpha * sum(ki * ki for ki in k) * t))


def step_1d_xcorr(
    f: jnp.ndarray,
    problem: DiffusionProblem,
    *,
    strategy: str = "hwc",
    block_size: int = 2048,
) -> jnp.ndarray:
    """1-D diffusion step via the cross-correlation kernel path (the
    paper's cuDNN/MIOpen-comparable formulation): pad periodically, then
    f' = g ⋆ f̂ with the merged kernel of Eq. 5."""
    g = jnp.asarray(
        diffusion_kernel_1d(
            problem.accuracy, problem.dt, problem.alpha, problem.spacing[0]
        ),
        f.dtype,
    )
    r = problem.radius
    fp = jnp.concatenate([f[-r:], f, f[:r]])
    return kops.xcorr1d(fp, g, strategy=strategy, block_size=block_size)


def simulate(
    problem: DiffusionProblem,
    f0: jnp.ndarray,
    n_steps: int,
    *,
    strategy: str = "hwc",
    block: tuple[int, ...] | str | None = None,
    fuse_steps: int | str = 1,
) -> jnp.ndarray:
    """Run ``n_steps`` of forward-Euler diffusion with the fused engine.

    ``fuse_steps > 1`` advances that many steps per kernel launch
    (temporal fusion; a remainder is finished at shallower depth so the
    step count stays exact)."""
    from repro.core.fusion import integrate

    op = problem.step_op(strategy, block, fuse_steps)

    @jax.jit
    def run(f):
        return integrate(op, f, n_steps)

    return run(f0)
