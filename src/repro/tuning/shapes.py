"""Registered benchmark shapes for cache pre-warming.

``python -m repro.tuning warm`` drives every entry through the real
``block="auto"`` code paths (eager, so measurement runs), which both
populates the persistent cache for the benchmark suite and exercises the
exact key derivation the hot paths use — warm once, every later
``FusedStencilOp``/kernel call cache-hits.
"""
from __future__ import annotations

import dataclasses
from typing import Callable

import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class WarmEntry:
    name: str
    run: Callable[[bool], None]  # run(full): eager auto-tuned call(s)


def _warm_diffusion3d(full: bool) -> None:
    from repro.physics.diffusion import DiffusionProblem

    shape = (256, 256, 256) if full else (32, 32, 64)
    for acc in (2, 6):
        p = DiffusionProblem(shape, accuracy=acc)
        f0 = p.init_field()
        op = p.step_op("swc", block="auto")
        op(f0)


def _warm_diffusion_lowdim(full: bool) -> None:
    """Rank-1/2 fused plans (the engine's new dimensionalities)."""
    from repro.physics.diffusion import DiffusionProblem

    shapes = [
        ((1 << 22,) if full else (1 << 14,)),
        ((2048, 2048) if full else (64, 128)),
    ]
    for shape in shapes:
        for acc in (2, 6):
            p = DiffusionProblem(shape, accuracy=acc)
            op = p.step_op("swc", block="auto")
            op(p.init_field())


def _warm_diffusion_stream(full: bool) -> None:
    """Explicit-streaming plans at both ranks (y-stream at rank 2,
    z-stream at rank 3), including a fused depth-2 streaming plan —
    the stream axis and depth are part of the cache key."""
    from repro.physics.diffusion import DiffusionProblem

    shapes = [
        ((2048, 2048) if full else (64, 128)),
        ((128, 128, 128) if full else (16, 16, 64)),
    ]
    for shape in shapes:
        p = DiffusionProblem(shape, accuracy=6)
        f0 = p.init_field()
        p.step_op("swc_stream", block="auto")(f0)
        p.step_op("swc_stream", block="auto", fuse_steps=2)(f0)


def _warm_diffusion_tc(full: bool) -> None:
    """MXU (``tc``) plans at rank 2 and 3, plus one 4-member batched
    ensemble shape — the ``tc`` marker and the ``:b{B}`` batch extent
    are both part of the cache key (``tc:b4`` never replays a ``swc``
    winner), so each needs its own warmed record."""
    from repro.physics.diffusion import DiffusionProblem

    shapes = [
        ((2048, 2048) if full else (64, 128)),
        ((128, 128, 128) if full else (16, 16, 64)),
    ]
    for shape in shapes:
        p = DiffusionProblem(shape, accuracy=6)
        f0 = p.init_field()
        p.step_op("tc", block="auto")(f0)
    p2 = DiffusionProblem(shapes[0], accuracy=6)
    stack = jnp.stack([p2.init_field(seed=s) for s in range(4)])
    p2.step_op("tc", block="auto")(stack)


def _warm_diffusion_auto(full: bool) -> None:
    """Cross-strategy ``strategy="auto"`` records (one ``auto:sauto``
    key per shape holding the resolved strategy/block/depth/stream), so
    jitted ``"auto"`` call sites replay the measured cross-strategy
    winner instead of the structural one."""
    from repro.physics.diffusion import DiffusionProblem

    shapes = [
        ((2048, 2048) if full else (64, 128)),
        ((128, 128, 128) if full else (16, 16, 64)),
    ]
    for shape in shapes:
        p = DiffusionProblem(shape, accuracy=6)
        f0 = p.init_field()
        p.step_op("auto", fuse_steps="auto").resolved(f0)


def _warm_mhd(full: bool) -> None:
    from repro.physics.mhd import MHDSolver

    n = 64 if full else 16
    solver = MHDSolver((n, n, n), strategy="swc", block="auto")
    f0 = solver.init_fields()
    solver.rhs(f0)


def _warm_mhd_stream(full: bool) -> None:
    from repro.physics.mhd import MHDSolver

    n = 64 if full else 16
    solver = MHDSolver((n, n, n), strategy="swc_stream", block="auto")
    f0 = solver.init_fields()
    solver.rhs(f0)


def _warm_xcorr1d(full: bool) -> None:
    from repro.kernels import ops as kops

    n = 1 << (22 if full else 16)
    rng = np.random.default_rng(0)
    for radius in (1, 32):
        f = jnp.asarray(
            rng.standard_normal(n + 2 * radius), jnp.float32
        )
        g = jnp.asarray(rng.standard_normal(2 * radius + 1), jnp.float32)
        kops.xcorr1d(f, g, strategy="baseline", block_size="auto")


def _warm_conv1d(full: bool) -> None:
    from repro.kernels import ops as kops

    rng = np.random.default_rng(0)
    b, s, c, k = (4, 2048, 256, 4) if full else (2, 512, 64, 4)
    x = jnp.asarray(rng.standard_normal((b, s, c)), jnp.float32)
    w = jnp.asarray(rng.standard_normal((k, c)), jnp.float32)
    kops.conv1d_depthwise(x, w, block_seq="auto")


def warm_model_kernels(cfg, batch: int, seq_len: int, dtype=None) -> int:
    """Eagerly pre-measure the kernel blocks a model's hot path will
    request under ``--auto-tune`` (today: the mamba2 depthwise-conv
    frontend; transformers have no Pallas stencil). Returns the number of
    shapes warmed. Called by the train/serve drivers so the later jitted
    step traces resolve ``"auto"`` from the cache instead of the cost
    model. ``dtype`` defaults to the model compute dtype (``cfg.dtype``)
    — the tuning key is dtype-specific, so warming in any other dtype
    would never be replayed by the jitted step."""
    if cfg.family != "ssm":
        return 0
    from repro.kernels import ops as kops
    from repro.models.ssm import _dims

    if dtype is None:
        dtype = jnp.dtype(getattr(cfg, "dtype", "float32"))
    conv_ch = _dims(cfg)[-1]
    k = cfg.ssm_conv_kernel
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.standard_normal((batch, seq_len, conv_ch)), dtype)
    w = jnp.asarray(rng.standard_normal((k, conv_ch)), dtype)
    kops.conv1d_depthwise(x, w, block_seq="auto")
    return 1


# ---------------------------------------------------------------------------
# Static-audit shapes (repro.analysis)
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class AuditShape:
    """One declarative entry of the static-audit registry: the auditor
    builds every valid plan in the cross product of the listed axes
    (strategies × fuse × unroll × batch) over this problem identity and
    proves bounds/coverage/VMEM/key obligations for each — no kernels
    run. ``smoke`` mirrors the warm registry's smoke extents;
    ``full`` its benchmark extents (``python -m repro.analysis
    --full``). ``accuracy=0`` selects the hand-built cross-correlation
    tap set instead of generated central differences."""

    name: str
    ndim: int
    accuracy: int
    smoke: tuple[int, ...]
    full: tuple[int, ...]
    n_f: int = 1
    n_out: int = 1
    n_aux: int = 0
    dtype: str = "float32"
    strategies: tuple[str, ...] = ("swc",)
    fuse: tuple[int, ...] = (1,)
    unroll: tuple[int, ...] = (1,)
    batch: tuple[int, ...] = (1,)

    def operator_set(self):
        import numpy as _np

        from repro.core.stencil import (
            derivative_operator_set,
            xcorr_operator_set,
        )

        if self.accuracy == 0:
            g = _np.arange(1, 2 * 32 + 2, dtype=_np.float64)
            return xcorr_operator_set(g, self.ndim)
        return derivative_operator_set(self.ndim, self.accuracy)

    def plans(self, domain: tuple[int, ...]):
        """Yield every valid (plan, ops) over this entry's axis
        product at the given interior extents (invalid combinations —
        the same constraints ``StencilPlan`` enforces — are skipped,
        not errors)."""
        import itertools

        from repro.kernels.plan import plan_stencil

        ops = self.operator_set()
        radii = ops.radius_per_axis()
        for s, f, u, b in itertools.product(
            self.strategies, self.fuse, self.unroll, self.batch
        ):
            if s == "swc_stream" and (
                self.ndim == 1 or self.n_aux or u != 1
            ):
                continue
            if s == "tc" and (
                u != 1 or self.dtype not in ("float32", "bfloat16")
            ):
                continue
            if u != 1 and f != 1:
                continue
            if f > 1 and self.n_out != self.n_f + self.n_aux:
                continue
            if b > 1 and self.n_aux and f > 1:
                continue
            padded = tuple(
                n + 2 * r * f for n, r in zip(domain, radii)
            )
            lead = (b,) if b > 1 else ()
            yield plan_stencil(
                ops, lead + (self.n_f,) + padded, self.n_out,
                strategy=s, dtype=self.dtype, n_aux=self.n_aux,
                unroll=u, fuse_steps=f,
            ), ops


# Mirrors the warm registry above (same figures, same smoke/full
# extents) plus the axes only the auditor sweeps today (unroll > 1,
# aux carries, batch 2). Every lowerable strategy appears at every
# rank it supports.
AUDIT_SHAPES: tuple[AuditShape, ...] = (
    AuditShape(
        "fig11/diffusion3d", 3, 2, (32, 32, 64), (256, 256, 256),
        strategies=("swc", "swc_stream", "tc"), fuse=(1, 2),
    ),
    AuditShape(
        "fig11/diffusion3d_o6", 3, 6, (32, 32, 64), (256, 256, 256),
        strategies=("swc", "swc_stream", "tc"), fuse=(1, 2),
    ),
    AuditShape(
        "fig11/diffusion1d", 1, 6, (1 << 14,), (1 << 22,),
        strategies=("swc", "tc"), fuse=(1, 2), unroll=(1, 2),
    ),
    AuditShape(
        "fig11/diffusion2d", 2, 6, (64, 128), (2048, 2048),
        strategies=("swc", "swc_stream", "tc"), fuse=(1, 2, 3),
        unroll=(1, 2), batch=(1, 2, 4),
    ),
    AuditShape(
        "fig13-14/mhd8f", 3, 6, (16, 16, 64), (64, 64, 64),
        n_f=8, n_out=8, strategies=("swc", "swc_stream", "tc"),
    ),
    AuditShape(
        "engine/rk-aux-carry", 2, 6, (64, 128), (2048, 2048),
        n_f=1, n_out=2, n_aux=1, strategies=("swc", "tc"),
        fuse=(1, 2),
    ),
    AuditShape(
        "fig07-09/xcorr1d-r32", 1, 0, (1 << 14,), (1 << 22,),
        strategies=("swc",), unroll=(1, 2),
    ),
    AuditShape(
        "fig11/diffusion2d_bf16", 2, 6, (64, 128), (2048, 2048),
        dtype="bfloat16", strategies=("swc", "tc"),
    ),
)


REGISTRY: tuple[WarmEntry, ...] = (
    WarmEntry("fig11/diffusion3d_swc", _warm_diffusion3d),
    WarmEntry("fig11/diffusion1d2d_swc", _warm_diffusion_lowdim),
    WarmEntry("fig11/diffusion_swc_stream", _warm_diffusion_stream),
    WarmEntry("fig11/diffusion_tc", _warm_diffusion_tc),
    WarmEntry("fig11/diffusion_auto", _warm_diffusion_auto),
    WarmEntry("fig13-14/mhd_swc", _warm_mhd),
    WarmEntry("fig13/mhd_swc_stream", _warm_mhd_stream),
    WarmEntry("fig07-09/xcorr1d", _warm_xcorr1d),
    WarmEntry("mamba2/conv1d_depthwise", _warm_conv1d),
)
