"""TuningSession — structural-rank → measure-top-k → record.

The paper's Sec. 5.1 protocol, made persistent: the structural cost
model prunes the block-shape space, the top-k survivors are timed on
hardware (warm-up + median of timed calls), and the winner is recorded
in the per-platform cache so every later process — and every
``block="auto"`` call site — reuses it without re-measurement.

Under ``jax.jit`` tracing no measurement is possible (there is no
concrete operand to time), so the session falls back to the structural
winner and records it as ``source="model"``; a later eager call or
``python -m repro.tuning warm`` upgrades the record to ``"measured"``.
"""
from __future__ import annotations

import dataclasses
import logging
from typing import Any, Callable, Sequence

import jax

from repro.ft import faults as ftfaults
from repro.tuning.cache import (
    TuningCache,
    TuningKey,
    TuningRecord,
    candidate_label,
    current_backend,
)
from repro.tuning.costmodel import (
    Candidate,
    VMEM_BUDGET,
    enumerate_candidates_1d,
    enumerate_candidates_nd,
    enumerate_cross_strategy_nd,
    time_candidate,
)

log = logging.getLogger("repro.tuning")

# Total hardware measurements taken by sessions in this process. Tests
# (and the acceptance criterion) assert a second process replays from the
# persisted record with this still at zero.
MEASURE_COUNT = 0

# Global opt-in: when True, kernel call sites that pass no explicit block
# (the model hot paths, e.g. mamba2's conv frontend) resolve as "auto".
# Flipped by the train/serve drivers' --auto-tune flag.
AUTO_ENABLED = False


def enable_auto(on: bool = True) -> None:
    """Globally opt model call sites with no explicit block into
    ``"auto"`` resolution (the train/serve drivers' ``--auto-tune``)."""
    global AUTO_ENABLED
    AUTO_ENABLED = on


@dataclasses.dataclass
class TuningSession:
    """One tuning context: a cache plus the measurement protocol knobs
    (paper: 3 timed iterations after warm-up).

    Args (dataclass fields):
        cache: the persistent per-platform :class:`TuningCache` records
            are read from / written to.
        top_k: how many structurally-ranked candidates are measured.
        warmup / iters: per-candidate timing protocol (warm-up calls,
            then the median of ``iters`` timed calls).
        record_source: source stamped on measured records ("measured",
            or "smoke" for degraded single-iteration protocols, which
            later full-protocol callers are allowed to upgrade).

    Most callers never construct one — ``default_session()`` provides
    the process-wide instance every ``block="auto"`` site shares.

    Example (an isolated session against a throwaway cache)::

        >>> from repro.tuning.cache import TuningCache
        >>> from repro.tuning.session import TuningSession
        >>> sess = TuningSession(cache=TuningCache("/tmp/tune-doc"))
        >>> sess.top_k
        4
    """

    cache: TuningCache = dataclasses.field(default_factory=TuningCache)
    top_k: int = 4
    warmup: int = 1
    iters: int = 3
    # Source stamped on measured records. Degraded protocols (e.g. a
    # --smoke benchmark's single-iteration timing) pass "smoke" so the
    # record is treated as upgradeable, like "model", by full-protocol
    # callers instead of replayed forever.
    record_source: str = "measured"

    def tune(
        self,
        key: TuningKey,
        candidates: Sequence[Any],
        measure: Callable[[Any], float] | None = None,
        *,
        force: bool = False,
    ) -> TuningRecord:
        """Resolve ``key``: cache-hit fast path, else rank/measure/record.

        ``candidates`` are structurally ranked (best first) and must each
        expose a ``.block`` attribute (and optionally ``.fuse_steps``
        for joint block/temporal-depth searches).
        ``measure(candidate) -> seconds`` may raise to signal a
        discarded launch — the failure is recorded as a ``failed`` row
        of the persisted timing table (label → error summary), and
        later re-tunes of the same key skip those known-bad candidates
        instead of re-launching them; ``None`` (e.g. under tracing)
        selects the structural winner without hardware.
        """
        if not force:
            hit = self.cache.get(key)
            if hit is not None and not (
                hit.source in ("model", "smoke") and measure is not None
            ):
                # Fast path — except an upgradeable record (cost model
                # under jit tracing, or a degraded smoke-mode timing) is
                # re-tuned as soon as a caller CAN measure.
                return hit
        if not candidates:
            raise ValueError(f"no tuning candidates for {key.cache_id}")

        # Known-bad candidates from a prior record's failed rows are
        # carried forward and never re-launched (a compile failure or
        # RESOURCE_EXHAUSTED is not going to heal between processes).
        prior = self.cache.get(key)
        known_bad = dict(prior.failed) if prior is not None else {}
        failed: dict[str, str] = {
            label: err
            for label, err in known_bad.items()
            if any(_timing_label(c) == label for c in candidates)
        }
        pool = [
            c for c in candidates if _timing_label(c) not in known_bad
        ]

        record: TuningRecord | None = None
        if measure is not None:
            global MEASURE_COUNT
            timings: dict[str, float] = {}
            best: tuple[float, Any] | None = None
            for cand in pool[: self.top_k]:
                label = _timing_label(cand)
                try:
                    ftfaults.maybe_fail_candidate(label)
                    t = measure(cand)
                except Exception as e:
                    # The paper's discarded launch (not counted as a
                    # measurement) — but persisted, so warm re-tunes
                    # skip the candidate instead of rediscovering it.
                    failed[label] = f"{type(e).__name__}: {e}"
                    log.warning(
                        "tuning candidate %s failed for %s: %s",
                        label, key.cache_id, failed[label],
                    )
                    continue
                MEASURE_COUNT += 1
                timings[label] = t * 1e6
                if best is None or t < best[0]:
                    best = (t, cand)
            if best is not None:
                record = _candidate_record(
                    best[1], timings, self.record_source
                )
        if record is None:
            # No measure fn, or every attempted candidate was discarded:
            # fall back to the structural winner among the not-known-bad
            # pool (source="model" keeps the record upgradeable).
            fallback = pool[0] if pool else candidates[0]
            record = _candidate_record(fallback, {}, "model")
        record.failed = failed
        self.cache.put(key, record)
        return record


def _candidate_record(
    cand: Any, timings: dict[str, float], source: str
) -> TuningRecord:
    """Record for a winning candidate — persisting the temporal depth,
    the explicit-streaming flag, and (for cross-strategy searches) the
    resolved strategy, so a warm cache hit reproduces the whole lowering
    decision."""
    return TuningRecord(
        block=cand.block, timings_us=timings, source=source,
        fuse_steps=getattr(cand, "fuse_steps", 1),
        stream=getattr(cand, "stream", False),
        strategy_resolved=getattr(cand, "strategy", ""),
        unroll=getattr(cand, "unroll", 1),
    )


def _timing_label(cand: Any) -> str:
    """Timing-table key for one candidate — the shared
    :func:`repro.tuning.cache.candidate_label` derivation, which
    ``TuningRecord.winner_label`` mirrors for display code."""
    return candidate_label(
        cand.block,
        getattr(cand, "fuse_steps", 1),
        getattr(cand, "stream", False),
        getattr(cand, "strategy", ""),
    )


# One process-wide session so all `block="auto"` call sites share a
# cache view. Rebuilt if REPRO_TUNE_CACHE is re-pointed (tests do this).
_DEFAULT: TuningSession | None = None


def default_session() -> TuningSession:
    """The process-wide session every ``"auto"`` call site shares;
    rebuilt when $REPRO_TUNE_CACHE is re-pointed (tests do this)."""
    global _DEFAULT
    from repro.tuning.cache import default_cache_dir

    if _DEFAULT is None or _DEFAULT.cache.dir != default_cache_dir():
        _DEFAULT = TuningSession()
    return _DEFAULT


def _is_concrete(x) -> bool:
    return not isinstance(x, jax.core.Tracer)


# ---------------------------------------------------------------------------
# Fused stencil engine glue (`block="auto"` at any rank). Cache keys are
# the serialized plan identity (StencilPlan.tuning_key), so rank-1/2/3
# problems share one persistent cache with distinct, stable keys.
# ---------------------------------------------------------------------------


def fused_nd_key(
    domain: tuple[int, ...],
    radii: tuple[int, ...],
    n_f: int,
    n_out: int,
    dtype: str,
    strategy: str,
    backend: str | None = None,
    unroll: int = 1,
    fuse_steps: int | str = 1,
    batch: int = 1,
    accuracy: int = 0,
    n_aux: int = 0,
) -> TuningKey:
    """Plan-identity tuning key (mirrors ``StencilPlan.tuning_key``).

    The strategy id — stream axis (``swc_stream`` → ``:sz`` at rank 3,
    ``:sy`` at rank 2), unroll, ``fuse_steps``, ensemble ``batch``,
    aux-operand (``:a{N}``, aux-carrying plans only) and operator-order
    (``:o{A}``, non-default accuracy only) suffixes —
    comes from the plan layer's canonical ``strategy_sid``
    derivation, so this mirror can never diverge from
    ``StencilPlan.strategy_id``; depth-1 and depth-2 problems cache
    separately, the joint block/depth search keys as ``:fauto``, and a
    B-member ensemble problem keys as ``:b{B}``. The plan→key
    injectivity of the whole derivation is audited by
    ``repro.analysis.keys``.
    """
    from repro.kernels.plan import strategy_sid

    rank = len(domain)
    sid = strategy_sid(
        strategy, rank, unroll, fuse_steps, batch, accuracy, n_aux
    )
    return TuningKey(
        kernel=f"fused_stencil{rank}d",
        strategy=sid,
        domain=tuple(domain),
        radii=tuple(radii),
        n_f=n_f,
        n_out=n_out,
        dtype=str(dtype),
        backend=backend if backend is not None else current_backend(),
    )


def fused3d_key(
    domain: tuple[int, int, int],
    radii: tuple[int, int, int],
    n_f: int,
    n_out: int,
    dtype: str,
    strategy: str,
    backend: str | None = None,
) -> TuningKey:
    """Historical rank-3 alias.

    .. deprecated::
        ``fused3d_key`` is deprecated; use :func:`fused_nd_key`.
    """
    import warnings

    warnings.warn(
        "fused3d_key is deprecated; use fused_nd_key",
        DeprecationWarning,
        stacklevel=2,
    )
    return fused_nd_key(domain, radii, n_f, n_out, dtype, strategy, backend)


def fused_nd_candidates(
    domain: tuple[int, ...],
    radii: tuple[int, ...],
    n_f: int,
    n_out: int,
    itemsize: int,
    *,
    vmem_budget: int = VMEM_BUDGET,
    fuse_steps_options: Sequence[int] = (1,),
    stream: bool = False,
    tc: bool = False,
    tc_groups: Sequence[int] | None = None,
    batch: int = 1,
    flops_per_point: float | None = None,
) -> list[Candidate]:
    """Structurally-ranked (block, fuse_steps) configurations for a
    rank-1/2/3 domain (``stream=True`` scores every candidate with the
    explicit-streaming traffic/VMEM model — the ``swc_stream`` search
    space; ``tc=True`` enumerates only matrix-unit candidates scored on
    ``max(traffic, mxu)`` with ``tc_groups`` contraction groups per
    axis; ``batch > 1`` with the batched per-member VMEM/traffic
    model), with graceful degradation: if nothing fits the VMEM budget,
    re-enumerate without the filter and keep only the smallest-footprint
    shape so ``auto`` still resolves (marked ``fallback`` by the
    caller)."""
    stream_options = () if tc else (stream,)
    tc_options = (tc,)
    backend = current_backend()
    cands = enumerate_candidates_nd(
        domain, radii, n_f, n_out, itemsize, vmem_budget=vmem_budget,
        fuse_steps_options=fuse_steps_options,
        stream_options=stream_options, tc_options=tc_options,
        tc_groups=tc_groups, backend=backend, batch=batch,
        flops_per_point=flops_per_point,
    )
    if cands:
        return cands
    unfiltered = enumerate_candidates_nd(
        domain, radii, n_f, n_out, itemsize, vmem_budget=2**63,
        fuse_steps_options=fuse_steps_options,
        stream_options=stream_options, tc_options=tc_options,
        tc_groups=tc_groups, backend=backend, batch=batch,
        flops_per_point=flops_per_point,
    )
    if not unfiltered:
        return []
    smallest = min(unfiltered, key=lambda c: c.vmem_bytes)
    return [smallest]


def fused3d_candidates(
    domain: tuple[int, int, int],
    radii: tuple[int, int, int],
    n_f: int,
    n_out: int,
    itemsize: int,
    *,
    vmem_budget: int = VMEM_BUDGET,
) -> list[Candidate]:
    """Historical rank-3 alias.

    .. deprecated::
        ``fused3d_candidates`` is deprecated; use
        :func:`fused_nd_candidates`.
    """
    import warnings

    warnings.warn(
        "fused3d_candidates is deprecated; use fused_nd_candidates",
        DeprecationWarning,
        stacklevel=2,
    )
    return fused_nd_candidates(
        domain, radii, n_f, n_out, itemsize, vmem_budget=vmem_budget
    )


def auto_block_nd(
    f_padded,
    ops,
    phi,
    n_out: int,
    *,
    aux=None,
    strategy: str = "swc",
    unroll: int = 1,
    fuse_steps: int = 1,
    interpret: bool = False,
    session: TuningSession | None = None,
    vmem_budget: int = VMEM_BUDGET,
) -> tuple[int, ...]:
    """Resolve ``block="auto"`` for the fused engine at any rank (the
    temporal depth is FIXED here — ``auto_fuse_nd`` runs the joint
    block/depth search).

    Eager call sites get the full protocol (measure top-k on the actual
    operand, persist); traced call sites get the cache or the structural
    winner. Returns a concrete rank-length block (x last).

    The cache key is derived from an actual planned ``StencilPlan`` (a
    probe lowering with the default block), so it always reflects the
    configuration the kernel will execute — e.g. an unroll factor the
    planner degrades to 1 is keyed as 1. A batched
    (batch, n_f, *padded) ensemble operand keys as ``:b{B}`` and ranks
    candidates with the batched VMEM/per-member traffic model."""
    from repro.kernels.plan import (
        DEFAULT_BLOCKS,
        plan_stencil,
        tc_groups_per_axis,
    )

    sess = session if session is not None else default_session()
    batched = f_padded.ndim == ops.ndim + 2
    n_aux = 0
    if aux is not None:
        n_aux = aux.shape[1] if batched else aux.shape[0]
    probe = plan_stencil(
        ops, f_padded.shape, n_out, strategy=strategy,
        dtype=str(f_padded.dtype), n_aux=n_aux,
        unroll=unroll, fuse_steps=fuse_steps,
    )
    rank, domain, radii = probe.rank, probe.interior, probe.radii
    n_f = probe.n_f
    itemsize = f_padded.dtype.itemsize
    key = probe.tuning_key()
    cands = fused_nd_candidates(
        domain, radii, n_f, n_out, itemsize, vmem_budget=vmem_budget,
        fuse_steps_options=(fuse_steps,),
        stream=probe.strategy == "swc_stream",
        tc=probe.strategy == "tc",
        tc_groups=tc_groups_per_axis(ops),
        batch=probe.batch,
        flops_per_point=ops.flops_per_point(n_f),
    )
    if not cands:  # degenerate domain: let the planner clamp a default
        return DEFAULT_BLOCKS[rank]
    if cands[0].vmem_bytes > vmem_budget:
        # Nothing fits VMEM: degrade to the smallest-footprint shape
        # without measuring (a real launch could OOM), and persist it so
        # the decision is visible in `repro.tuning show`.
        rec = sess.cache.get(key)
        if rec is None:
            rec = TuningRecord(
                block=cands[0].block, timings_us={}, source="fallback",
                fuse_steps=fuse_steps, unroll=probe.unroll,
            )
            sess.cache.put(key, rec)
        return tuple(rec.block)

    measure = None
    if _is_concrete(f_padded):
        from repro.kernels import ops as kops

        def measure(cand):
            """Median seconds for one candidate block (paper protocol)."""
            def fn():
                """One timed fused-stencil launch at ``cand.block``."""
                return kops.fused_stencil_nd(
                    f_padded, ops, phi, n_out, aux=aux,
                    block=cand.block, strategy=strategy,
                    unroll=probe.unroll, fuse_steps=fuse_steps,
                    interpret=interpret,
                )

            return time_candidate(
                fn, warmup=sess.warmup, iters=sess.iters
            )

    record = sess.tune(key, cands, measure)
    if record.unroll != probe.unroll:
        # Candidate objects don't carry the (fixed) unroll factor of
        # this search; stamp the planner-degraded value on the record
        # so ``plan_from_record`` round-trips ``:u{N}``-keyed records
        # (the repro.analysis left-inverse audit).
        record.unroll = probe.unroll
        sess.cache.put(key, record)
    return tuple(record.block)


def auto_fuse_nd(
    f_interior,
    ops,
    phi,
    n_out: int,
    *,
    aux=None,
    strategy: str = "swc",
    interpret: bool | None = None,
    session: TuningSession | None = None,
    vmem_budget: int = VMEM_BUDGET,
    depth_options: Sequence[int] = (1, 2, 3, 4),
) -> tuple[tuple[int, ...], int]:
    """Resolve ``fuse_steps="auto"``: the JOINT (block, temporal depth)
    search over an UNPADDED field stack (n_f, *spatial).

    Candidates are every (block, depth) pair the traffic-model-driven
    cost model admits (per-depth VMEM filter, tiny-block guard), ranked
    by modeled per-step HBM traffic plus weighted redundant-halo
    compute; with ``strategy="swc_stream"`` every candidate is scored
    with the streaming traffic model, so the search can pick a fused
    streaming configuration. Eager call sites measure the top-k —
    padding the operand by ``radius · depth`` per candidate so each
    depth times the kernel it would actually run — and persist the
    winner under one ``:fauto`` key (stream axis included for streaming
    plans); traced call sites take the cached or structural winner.
    Returns ``(block, fuse_steps)``.

    Depths that don't self-map (``n_out != n_f + n_aux``) can't fuse;
    only depth 1 is enumerated for them. A batched
    (batch, n_f, *spatial) ensemble stack keys as ``:b{B}`` and ranks
    with the batched VMEM/per-member traffic model.
    """
    sess = session if session is not None else default_session()
    batched = f_interior.ndim == ops.ndim + 2
    batch = int(f_interior.shape[0]) if batched else 1
    lead = 2 if batched else 1
    domain = tuple(f_interior.shape[lead:])
    radii = ops.radius_per_axis()
    n_f = f_interior.shape[lead - 1]
    n_aux = aux.shape[lead - 1] if aux is not None else 0
    itemsize = f_interior.dtype.itemsize
    if isinstance(phi, (tuple, list)):
        depth_options = (len(phi),)  # a φ sequence pins the depth
    if n_out != n_f + n_aux:
        depth_options = (1,)
    if batch > 1 and n_aux:
        # Mirrors StencilPlan: batched temporal fusion can't carry aux.
        depth_options = (1,)
    key = fused_nd_key(
        domain, radii, n_f, n_out, str(f_interior.dtype), strategy,
        fuse_steps="auto", batch=batch,
        accuracy=getattr(ops, "accuracy", 0), n_aux=n_aux,
    )
    from repro.kernels.plan import tc_groups_per_axis

    cands = fused_nd_candidates(
        domain, radii, n_f, n_out, itemsize, vmem_budget=vmem_budget,
        fuse_steps_options=tuple(depth_options),
        stream=strategy == "swc_stream",
        tc=strategy == "tc",
        tc_groups=tc_groups_per_axis(ops),
        batch=batch,
        flops_per_point=ops.flops_per_point(n_f),
    )
    if not cands:
        from repro.kernels.plan import DEFAULT_BLOCKS

        return DEFAULT_BLOCKS[len(domain)], 1
    if cands[0].vmem_bytes > vmem_budget:
        rec = sess.cache.get(key)
        if rec is None:
            rec = TuningRecord(
                block=cands[0].block, timings_us={}, source="fallback",
                fuse_steps=cands[0].fuse_steps,
            )
            sess.cache.put(key, rec)
        return tuple(rec.block), int(rec.fuse_steps)

    measure = None
    if _is_concrete(f_interior) and (aux is None or _is_concrete(aux)):
        measure = _interior_measure_fn(
            sess, f_interior, ops, phi, n_out, aux, radii,
            default_strategy=strategy, interpret=interpret,
        )

    record = sess.tune(key, cands, measure)
    return tuple(record.block), int(record.fuse_steps)


def _interior_measure_fn(
    sess: TuningSession,
    f_interior,
    ops,
    phi,
    n_out: int,
    aux,
    radii: tuple[int, ...],
    *,
    default_strategy: str = "swc",
    interpret: bool | None = None,
):
    """Measurement closure shared by the joint-depth and cross-strategy
    resolvers: median PER-STEP seconds for one candidate, on an UNPADDED
    operand padded per candidate (``radius · depth`` ghost cells, so
    each depth times the kernel it would actually run).

    Candidates carrying a ``strategy`` attribute are dispatched per
    strategy — ``hwc`` times the jitted XLA-managed reference (the
    measured baseline of the cross-strategy search), everything else
    the Pallas kernel at the candidate's block/depth/stream config.
    Works for plain (n_f, *spatial) and batched (batch, n_f, *spatial)
    operands alike — non-spatial leading axes are never padded, and the
    hwc baseline times the vmap'd batched oracle.
    """
    import jax as _jax
    import jax.numpy as jnp

    from repro.kernels import ops as kops
    from repro.kernels import ref as kref

    lead = f_interior.ndim - len(radii)  # 1, or 2 when batched

    def measure(cand):
        """Median per-step seconds for one candidate configuration."""
        depth = getattr(cand, "fuse_steps", 1)
        strategy = getattr(cand, "strategy", default_strategy) or (
            default_strategy
        )
        pad = [(0, 0)] * lead + [(r * depth,) * 2 for r in radii]
        fp = jnp.pad(f_interior, pad, mode="wrap")
        aux_p = aux
        if aux is not None and depth > 1:
            apad = [(0, 0)] * lead + [
                (r * (depth - 1),) * 2 for r in radii
            ]
            aux_p = jnp.pad(aux, apad, mode="wrap")

        if strategy == "hwc":
            # The XLA-managed path is always jitted when benchmarked —
            # time what the compiler-managed regime actually runs.
            if lead == 2:
                if depth == 1:
                    hwc = _jax.jit(
                        lambda f, a: kref.fused_stencil_batched(
                            f, ops, phi, aux=a
                        )
                    )
                else:
                    hwc = _jax.jit(
                        lambda f, a: kref.fused_stencil_steps_batched(
                            f, ops, phi, depth, aux=a
                        )
                    )
            elif depth == 1:
                hwc = _jax.jit(
                    lambda f, a: kref.fused_stencil(f, ops, phi, aux=a)
                )
            else:
                hwc = _jax.jit(
                    lambda f, a: kref.fused_stencil_steps(
                        f, ops, phi, depth, aux=a
                    )
                )

            def fn():
                """One timed XLA-managed (hwc) application."""
                return hwc(fp, aux_p)

        else:

            def fn():
                """One timed depth-``depth`` launch at ``cand.block``."""
                return kops.fused_stencil_nd(
                    fp, ops, phi, n_out, aux=aux_p, block=cand.block,
                    strategy=strategy, fuse_steps=depth,
                    interpret=interpret,
                )

        # One launch advances ``depth`` steps — candidates compete on
        # per-step time, not per-launch time.
        return time_candidate(
            fn, warmup=sess.warmup, iters=sess.iters
        ) / depth

    return measure


def auto_strategy_nd(
    f_interior,
    ops,
    phi,
    n_out: int,
    *,
    aux=None,
    fuse_steps: int | str = "auto",
    interpret: bool | None = None,
    session: TuningSession | None = None,
    vmem_budget: int = VMEM_BUDGET,
    depth_options: Sequence[int] = (1, 2, 3, 4),
) -> tuple[str, tuple[int, ...], int]:
    """Resolve ``strategy="auto"``: the CROSS-STRATEGY joint
    ``(strategy, block, fuse_steps, stream)`` search over an UNPADDED
    field stack (n_f, *spatial) — the paper's "no single caching regime
    wins everywhere" finding closed into one tuning loop.

    The candidate space is every ``swc``, ``swc_stream`` and ``tc``
    configuration the joint enumeration admits plus the ``hwc``
    baseline at the modeled-traffic floor
    (:func:`repro.tuning.costmodel.enumerate_cross_strategy_nd`);
    streaming candidates are enumerated only at rank ≥ 2 with no aux
    operand (the streaming kernel rejects carries), and matrix-unit
    (``tc``) candidates only for f32/bf16 operands — mirroring plan
    validation, so a structurally-winning regime is always lowerable. Eager call sites
    measure the top-k — the hwc candidate as the jitted XLA reference,
    the Pallas candidates padded per depth — and persist the winner
    under ONE ``auto:sauto`` key whose schema-v2 record carries the
    resolved strategy, block, depth, and stream flag; traced call sites
    take the cached or structural winner (no measurement). Returns
    ``(strategy, block, fuse_steps)`` — the stream decision is implied
    by the strategy (``swc_stream`` streams axis 0 by construction).

    ``fuse_steps``: ``"auto"`` sweeps ``depth_options`` jointly (keyed
    ``:fauto``); an int pins the search to that depth. A per-step φ
    sequence pins it to ``len(phi)``; ops that don't self-map
    (``n_out != n_f + n_aux``) only enumerate depth 1.
    """
    sess = session if session is not None else default_session()
    batched = f_interior.ndim == ops.ndim + 2
    batch = int(f_interior.shape[0]) if batched else 1
    lead = 2 if batched else 1
    domain = tuple(f_interior.shape[lead:])
    radii = ops.radius_per_axis()
    n_f = f_interior.shape[lead - 1]
    n_aux = aux.shape[lead - 1] if aux is not None else 0
    itemsize = f_interior.dtype.itemsize
    pinned = None  # explicitly requested depth (φ sequence or int)
    if isinstance(phi, (tuple, list)):
        pinned = len(phi)
    elif fuse_steps != "auto":
        pinned = int(fuse_steps)
    if pinned is not None:
        depth_options = (pinned,)
    if n_out != n_f + n_aux:
        if pinned is not None and pinned > 1:
            # Mirror StencilPlan validation instead of silently
            # clamping a depth the caller explicitly asked for.
            raise ValueError(
                "fuse_steps > 1 requires a self-map op with "
                f"n_out == n_f + n_aux (got n_out={n_out}, n_f={n_f}, "
                f"n_aux={n_aux}) — the cross-strategy search cannot "
                f"honor the pinned depth {pinned}"
            )
        depth_options = (1,)
    if batch > 1 and n_aux and tuple(depth_options) != (1,):
        # Mirrors StencilPlan: batched temporal fusion can't carry aux.
        depth_options = (1,)
    key = fused_nd_key(
        domain, radii, n_f, n_out, str(f_interior.dtype), "auto",
        fuse_steps=fuse_steps if fuse_steps == "auto" else depth_options[0],
        batch=batch,
        accuracy=getattr(ops, "accuracy", 0), n_aux=n_aux,
    )

    from repro.kernels.plan import tc_groups_per_axis

    cands = enumerate_cross_strategy_nd(
        domain, radii, n_f, n_out, itemsize, vmem_budget=vmem_budget,
        fuse_steps_options=tuple(depth_options),
        stream_ok=len(domain) >= 2 and n_aux == 0,
        tc_ok=str(f_interior.dtype) in ("float32", "bfloat16"),
        tc_groups=tc_groups_per_axis(ops),
        backend=current_backend(),
        batch=batch,
        flops_per_point=ops.flops_per_point(n_f),
    )
    measure = None
    if _is_concrete(f_interior) and (aux is None or _is_concrete(aux)):
        measure = _interior_measure_fn(
            sess, f_interior, ops, phi, n_out, aux, radii,
            interpret=interpret,
        )
        # The hwc baseline must ALWAYS be measured, not just modeled:
        # fused candidates model sub-compulsory traffic and can rank it
        # out of the top-k window, but the whole point of the cross-
        # strategy search is that the compiler-managed regime competes
        # on real time. Pull it into the measured window (keeping the
        # structural winner at index 0 — the traced/model fallback).
        if sess.top_k > 1:
            ih = next(
                i for i, c in enumerate(cands) if c.strategy == "hwc"
            )
            if ih >= sess.top_k:
                cands.insert(sess.top_k - 1, cands.pop(ih))
    record = sess.tune(key, cands, measure)
    return (
        record.resolved_strategy,
        tuple(record.block),
        int(record.fuse_steps),
    )


def auto_block_3d(
    f_padded,
    ops,
    phi,
    n_out: int,
    *,
    aux=None,
    strategy: str = "swc",
    interpret: bool = False,
    session: TuningSession | None = None,
    vmem_budget: int = VMEM_BUDGET,
) -> tuple[int, int, int]:
    """Historical rank-3 alias.

    .. deprecated::
        ``auto_block_3d`` is deprecated; use :func:`auto_block_nd`.
    """
    import warnings

    warnings.warn(
        "auto_block_3d is deprecated; use auto_block_nd",
        DeprecationWarning,
        stacklevel=2,
    )
    return auto_block_nd(
        f_padded, ops, phi, n_out, aux=aux, strategy=strategy,
        interpret=interpret, session=session, vmem_budget=vmem_budget,
    )


def lookup_fused_nd(
    f_interior,
    ops,
    n_out: int,
    strategy: str,
    session: TuningSession | None = None,
    unroll: int = 1,
    fuse_steps: int | str = 1,
    n_aux: int = 0,
) -> TuningRecord | None:
    """Cached record for a fused stencil call on an UNPADDED field
    stack (n_f, *spatial) — or batched (batch, n_f, *spatial), keying
    as ``:b{B}`` — the read-only mirror of the key derivation in
    ``auto_block_nd``/``auto_fuse_nd``, for benchmarks/examples that
    want to report which configuration ``"auto"`` resolved to. Pass
    ``fuse_steps="auto"`` to look up a joint block/depth record, and
    ``n_aux`` for an aux-carrying (``:a{N}``-keyed) call."""
    sess = session if session is not None else default_session()
    batched = f_interior.ndim == ops.ndim + 2
    lead = 2 if batched else 1
    key = fused_nd_key(
        tuple(f_interior.shape[lead:]),
        ops.radius_per_axis(),
        f_interior.shape[lead - 1],
        n_out,
        str(f_interior.dtype),
        strategy,
        unroll=unroll,
        fuse_steps=fuse_steps,
        batch=int(f_interior.shape[0]) if batched else 1,
        accuracy=getattr(ops, "accuracy", 0),
        n_aux=n_aux,
    )
    return sess.cache.get(key)


def lookup_fused3d(
    f_interior,
    ops,
    n_out: int,
    strategy: str,
    session: TuningSession | None = None,
) -> TuningRecord | None:
    """Historical rank-3 alias.

    .. deprecated::
        ``lookup_fused3d`` is deprecated; use :func:`lookup_fused_nd`.
    """
    import warnings

    warnings.warn(
        "lookup_fused3d is deprecated; use lookup_fused_nd",
        DeprecationWarning,
        stacklevel=2,
    )
    return lookup_fused_nd(
        f_interior, ops, n_out, strategy, session=session
    )


# ---------------------------------------------------------------------------
# 1-D kernel glue (xcorr1d block_size="auto", conv1d block_seq="auto").
# ---------------------------------------------------------------------------


def auto_block_xcorr1d(
    f_padded,
    g,
    *,
    strategy: str,
    unroll: int,
    interpret: bool,
    session: TuningSession | None = None,
) -> int:
    """Resolve ``block_size="auto"`` for the 1-D cross-correlation."""
    sess = session if session is not None else default_session()
    n_taps = g.shape[0]
    halo = n_taps - 1
    n = f_padded.shape[0] - halo
    key = TuningKey(
        kernel="xcorr1d",
        strategy=f"{strategy}:u{unroll}",
        domain=(n,),
        radii=(halo,),
        n_f=1,
        n_out=1,
        dtype=str(f_padded.dtype),
        backend=current_backend(),
    )
    cands = enumerate_candidates_1d(
        n, halo, itemsize=f_padded.dtype.itemsize
    )
    if not cands:
        return 2048

    measure = None
    if _is_concrete(f_padded) and _is_concrete(g):

        def measure(cand):
            """Median seconds for one candidate block length."""
            from repro.kernels import ops as kops

            def fn():
                """One timed xcorr1d call at ``cand.block``."""
                return kops.xcorr1d(
                    f_padded, g, strategy=strategy,
                    block_size=int(cand.block), unroll=unroll,
                    interpret=interpret,
                )

            return time_candidate(
                fn, warmup=sess.warmup, iters=sess.iters
            )

    return int(sess.tune(key, cands, measure).block)


def auto_block_conv1d(
    x,
    w,
    *,
    activation: str,
    interpret: bool,
    session: TuningSession | None = None,
) -> int:
    """Resolve ``block_seq="auto"`` for the depthwise causal conv."""
    sess = session if session is not None else default_session()
    b, s, c = x.shape
    k = w.shape[0]
    key = TuningKey(
        kernel="conv1d_depthwise",
        strategy=activation or "none",
        domain=(b, s, c),
        radii=(k - 1,),
        n_f=1,
        n_out=1,
        dtype=str(x.dtype),
        backend=current_backend(),
    )
    cands = enumerate_candidates_1d(
        s, k - 1, width=c, itemsize=x.dtype.itemsize,
        options=(128, 256, 512, 1024, 2048),
    )
    if not cands:
        return 512

    measure = None
    if _is_concrete(x) and _is_concrete(w):

        def measure(cand):
            """Median seconds for one candidate block length."""
            from repro.kernels import ops as kops

            def fn():
                """One timed depthwise-conv call at ``cand.block``."""
                return kops.conv1d_depthwise(
                    x, w, activation=activation,
                    block_seq=int(cand.block), interpret=interpret,
                )

            return time_candidate(
                fn, warmup=sess.warmup, iters=sess.iters
            )

    return int(sess.tune(key, cands, measure).block)
