"""Tuning-cache CLI.

    python -m repro.tuning warm [--full] [--force]   # pre-tune registered shapes
    python -m repro.tuning show                      # dump cached timing tables
    python -m repro.tuning clear                     # drop the cache

``warm`` drives every registered benchmark shape (repro.tuning.shapes)
through the eager ``block="auto"`` paths, so the measurement protocol
runs and winners persist; already-cached shapes are fast no-ops unless
``--force`` clears the cache first.
"""
from __future__ import annotations

import argparse
import sys
import time


def _cache():
    from repro.tuning.cache import TuningCache

    return TuningCache()


def cmd_warm(args: argparse.Namespace) -> int:
    from repro.tuning import session as sess_mod
    from repro.tuning.shapes import REGISTRY

    cache = _cache()
    if args.force:
        cache.clear()
    print(f"tuning cache: {cache.file}")
    before = set(cache.items())
    for entry in REGISTRY:
        t0 = time.perf_counter()
        try:
            entry.run(args.full)
        except Exception as e:  # keep warming the rest
            print(f"  {entry.name:32s} FAILED: {type(e).__name__}: {e}")
            continue
        dt = time.perf_counter() - t0
        print(f"  {entry.name:32s} ok ({dt:.1f}s)")
    fresh = {
        k: r for k, r in _cache().items().items() if k not in before
    }
    print(
        f"{len(fresh)} new record(s), "
        f"{sess_mod.MEASURE_COUNT} measurement(s) taken"
    )
    _show_records(fresh or _cache().items())
    return 0


def _show_records(records) -> None:
    from repro.tuning.cache import format_block

    for key in sorted(records):
        rec = records[key]
        print(f"\n{key}")
        depth = f" @f{rec.fuse_steps}" if rec.fuse_steps != 1 else ""
        strat = (
            f" -> {rec.strategy_resolved}" if rec.strategy_resolved else ""
        )
        print(
            f"  best block: {format_block(rec.block)}{depth}{strat}  "
            f"[{rec.source}]"
        )
        winner = rec.winner_label
        for blk, us in sorted(rec.timings_us.items(), key=lambda kv: kv[1]):
            mark = " <-- winner" if blk == winner else ""
            print(f"    {blk:>16s}  {us:12.1f} us{mark}")
        for blk, err in sorted(rec.failed.items()):
            print(f"    {blk:>16s}  FAILED: {err}")


def cmd_show(args: argparse.Namespace) -> int:
    cache = _cache()
    records = cache.items()
    print(f"tuning cache: {cache.file} ({len(records)} record(s))")
    _show_records(records)
    return 0


def cmd_clear(args: argparse.Namespace) -> int:
    cache = _cache()
    n = len(cache.items())
    cache.clear()
    print(f"cleared {n} record(s) from {cache.file}")
    return 0


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.tuning", description=__doc__,
        formatter_class=argparse.RawDescriptionHelpFormatter,
    )
    sub = ap.add_subparsers(dest="cmd", required=True)
    warm = sub.add_parser("warm", help="pre-tune registered benchmark shapes")
    warm.add_argument("--full", action="store_true",
                      help="paper-sized shapes (slow)")
    warm.add_argument("--force", action="store_true",
                      help="clear the cache first (re-measure everything)")
    warm.set_defaults(fn=cmd_warm)
    show = sub.add_parser("show", help="dump cached timing tables")
    show.set_defaults(fn=cmd_show)
    clear = sub.add_parser("clear", help="delete the cache")
    clear.set_defaults(fn=cmd_clear)
    args = ap.parse_args(argv)
    return args.fn(args)


if __name__ == "__main__":
    sys.exit(main())
