import signal

from repro.tuning.cli import main

# Die silently on a closed pipe (`... | head`) like standard unix tools.
try:
    signal.signal(signal.SIGPIPE, signal.SIG_DFL)
except (AttributeError, ValueError):  # pragma: no cover - non-posix
    pass

raise SystemExit(main())
