"""Persistent on-disk tuning cache.

The paper's central tuning result (Sec. 5.1, Fig. 14) is that block
shapes must be re-tuned per platform — so winners are cached *per device
kind* and reused across processes: tune once, run tuned forever after.

Layout: one JSON file (``cache.json``) under ``$REPRO_TUNE_CACHE`` or
``~/.cache/repro-tune/``, mapping a stable key string → record. Records
carry a schema version; bumping ``SCHEMA_VERSION`` invalidates every old
record (they are dropped at load). Writes are atomic (tmp + rename) and
hold an advisory file lock around the read-merge-write, so concurrent
processes on the same host compose instead of clobbering each other
(on platforms without ``fcntl`` the merge still bounds the race: a lost
record is simply re-measured later). A corrupt ``cache.json``
(truncated or garbled by a crashed writer) is quarantined — renamed
aside to ``cache.json.corrupt*`` with a warning — so the bad bytes
stay inspectable while every record re-tunes from a clean file.
"""
from __future__ import annotations

import contextlib
import dataclasses
import json
import logging
import os
import tempfile
import time
from pathlib import Path
from typing import Iterator, Union

log = logging.getLogger("repro.tuning")

try:
    import fcntl
except ImportError:  # non-posix: fall back to lock-free merge
    fcntl = None  # type: ignore[assignment]

# v2: records gained ``stream`` + ``strategy_resolved`` (the explicit-
# streaming flag and the strategy a cross-strategy "auto" search picked
# were previously dropped on the warm-cache path). Migration is by
# invalidation: v1 records are dropped at load and re-tuned.
SCHEMA_VERSION = 2
ENV_VAR = "REPRO_TUNE_CACHE"

Block = Union[int, tuple]


def default_cache_dir() -> Path:
    env = os.environ.get(ENV_VAR)
    if env:
        return Path(env)
    base = os.environ.get("XDG_CACHE_HOME")
    root = Path(base) if base else Path.home() / ".cache"
    return root / "repro-tune"


def current_backend() -> str:
    """Device kind of the default device (e.g. ``cpu``, ``TPU v5e``) —
    the per-platform component of every tuning key."""
    import jax

    dev = jax.devices()[0]
    kind = getattr(dev, "device_kind", "") or jax.default_backend()
    return str(kind).replace("|", "/")


@dataclasses.dataclass(frozen=True)
class TuningKey:
    """Everything that changes the optimal block shape."""

    # Kernel family: the rank-generic plan layer keys as
    # "fused_stencil1d" / "fused_stencil2d" / "fused_stencil3d"
    # (StencilPlan.kernel_name); the standalone 1-D kernels as
    # "xcorr1d" and "conv1d_depthwise".
    kernel: str
    # Strategy id from the plan layer's strategy_sid derivation — e.g.
    # "swc", "swc_stream:sy", "tc:f2:b4", "auto:sauto:fauto" — or
    # "baseline"/"pointwise"/"elementwise" for the 1-D kernels.
    strategy: str
    domain: tuple[int, ...]  # interior extents
    radii: tuple[int, ...]  # stencil radii (halo widths) per axis
    n_f: int  # input fields
    n_out: int  # output fields
    dtype: str  # e.g. "float32"
    backend: str  # device kind (per-platform tuning, the paper's point)

    @property
    def cache_id(self) -> str:
        return "|".join(
            (
                self.kernel,
                self.strategy,
                "x".join(map(str, self.domain)),
                "x".join(map(str, self.radii)),
                str(self.n_f),
                str(self.n_out),
                self.dtype,
                self.backend,
            )
        )


@dataclasses.dataclass
class TuningRecord:
    """One tuning outcome: the winning block (plus, for joint searches,
    the winning temporal-fusion depth, explicit-streaming flag, and —
    for cross-strategy ``"auto"`` keys — the resolved strategy) and the
    full timing table (µs per call, keyed by the candidate's string
    form) for inspection.

    ``stream``/``strategy_resolved`` are what a warm cache hit needs to
    reproduce the full lowering decision without re-measuring: before
    schema v2 the streaming flag lived only in the candidate object and
    was silently dropped on the persisted path.
    """

    block: Block
    timings_us: dict[str, float]
    source: str  # "measured" | "model" | "fallback"
    schema: int = SCHEMA_VERSION
    created: float = 0.0  # unix timestamp
    fuse_steps: int = 1  # winning temporal depth (1 for pure-block keys)
    stream: bool = False  # winning explicit-streaming flag (swc_stream)
    # Strategy the winning candidate lowers through ("hwc" | "swc" |
    # "swc_stream" | "tc") — load-bearing for cross-strategy "auto" keys,
    # informational for per-strategy keys (where the key pins it), and
    # empty for the 1-D kernels whose candidates carry no strategy.
    strategy_resolved: str = ""
    # Failed rows of the timing table: candidate label → error summary
    # for every candidate whose measurement raised (injected compile
    # failure, RESOURCE_EXHAUSTED, non-finite output). Re-tunes skip
    # these known-bad candidates instead of re-launching them; the
    # field is additive, so pre-existing records parse with no failures.
    failed: dict[str, str] = dataclasses.field(default_factory=dict)
    # Element-wise unroll factor of the winning configuration. Additive
    # like ``failed``: the unroll axis always joined the KEY (:u{N}),
    # but the record dropped it — so ``plan_from_record`` could not be
    # a left inverse of ``StencilPlan.tuning_key`` for unrolled plans
    # (the repro.analysis round-trip audit). Pre-existing records parse
    # as unroll=1, matching their unmarked keys.
    unroll: int = 1

    def to_json(self) -> dict:
        blk = list(self.block) if isinstance(self.block, tuple) else self.block
        return {
            "block": blk,
            "timings_us": self.timings_us,
            "source": self.source,
            "schema": self.schema,
            "created": self.created,
            "fuse_steps": self.fuse_steps,
            "stream": self.stream,
            "strategy_resolved": self.strategy_resolved,
            "failed": self.failed,
            "unroll": self.unroll,
        }

    @classmethod
    def from_json(cls, d: dict) -> "TuningRecord":
        blk = d["block"]
        if isinstance(blk, list):
            blk = tuple(blk)
        return cls(
            block=blk,
            timings_us=dict(d.get("timings_us", {})),
            source=d.get("source", "measured"),
            schema=int(d.get("schema", -1)),
            created=float(d.get("created", 0.0)),
            fuse_steps=int(d.get("fuse_steps", 1)),
            stream=bool(d.get("stream", False)),
            strategy_resolved=str(d.get("strategy_resolved", "")),
            failed=dict(d.get("failed", {})),
            unroll=int(d.get("unroll", 1)),
        )

    @property
    def resolved_strategy(self) -> str:
        """Concrete strategy this record lowers to — the ONE place the
        empty-``strategy_resolved`` fallback lives (records written by
        strategy-less searches imply ``swc``/``swc_stream`` from the
        stream flag)."""
        return self.strategy_resolved or (
            "swc_stream" if self.stream else "swc"
        )

    @property
    def winner_label(self) -> str:
        """Label of the winning candidate in :attr:`timings_us` —
        derived by the same :func:`candidate_label` the measurement
        loop writes, so display code can mark the winner row."""
        return candidate_label(
            self.block, self.fuse_steps, self.stream,
            self.strategy_resolved,
        )


def format_block(block: Block) -> str:
    if isinstance(block, tuple):
        return "x".join(map(str, block))
    return str(block)


def candidate_label(
    block: Block,
    fuse_steps: int = 1,
    stream: bool = False,
    strategy: str = "",
) -> str:
    """Timing-table label for one tuning candidate/record: the block,
    suffixed with the temporal depth when a joint search mixes depths
    and a strategy marker when it mixes strategies (a pipelined, a
    streamed and a matrix-unit candidate may share a block): ``:s`` for
    streaming, ``:tc`` for the MXU regime; ``hwc`` for the compiler-
    managed baseline, which has no meaningful block."""
    if strategy == "hwc":
        return "hwc"
    label = format_block(block)
    if fuse_steps != 1:
        label += f"@f{fuse_steps}"
    if stream:
        label += ":s"
    if strategy == "tc":
        label += ":tc"
    return label


class TuningCache:
    """In-memory view over the persistent JSON store."""

    def __init__(self, path: str | os.PathLike | None = None):
        self.dir = Path(path) if path is not None else default_cache_dir()
        self.file = self.dir / "cache.json"
        self._mem: dict[str, TuningRecord] | None = None

    # -- persistence --------------------------------------------------------

    def _read_disk(self) -> dict[str, TuningRecord]:
        try:
            text = self.file.read_text()
        except OSError:
            return {}  # no cache yet: cold start, not corruption
        try:
            raw = json.loads(text)
        except ValueError:
            self._quarantine_corrupt("unparseable JSON")
            return {}
        records = raw.get("records") if isinstance(raw, dict) else None
        if not isinstance(records, dict):
            # Parseable JSON but not our layout (foreign or truncated-
            # then-valid content): same quarantine, same re-tune.
            self._quarantine_corrupt("not a tuning-cache document")
            return {}
        out: dict[str, TuningRecord] = {}
        for key, rec in records.items():
            try:
                parsed = TuningRecord.from_json(rec)
            except (KeyError, TypeError, ValueError, AttributeError):
                continue
            if parsed.schema != SCHEMA_VERSION:
                continue  # schema bump invalidates old records
            out[key] = parsed
        return out

    def _quarantine_corrupt(self, reason: str) -> None:
        """Move a corrupt ``cache.json`` aside (``cache.json.corrupt``,
        numbered if that exists) instead of silently shadowing it with
        an empty view: the bad bytes stay inspectable, the next write
        starts from a clean file, and every record is re-tuned rather
        than half-trusted. Losing the rename race to a concurrent
        process is fine — someone quarantined it."""
        for n in range(100):
            suffix = ".corrupt" if n == 0 else f".corrupt.{n}"
            target = self.file.with_name(self.file.name + suffix)
            if target.exists():
                continue
            try:
                os.replace(self.file, target)
            except OSError:
                return  # already quarantined (or unlinked) by a peer
            log.warning(
                "quarantined corrupt tuning cache %s -> %s (%s); "
                "records will be re-tuned", self.file, target.name, reason,
            )
            return
        # 100 corpses: stop hoarding, drop the bytes.
        try:
            os.unlink(self.file)
        except OSError:
            pass

    def _write_disk(self, records: dict[str, TuningRecord]) -> None:
        self.dir.mkdir(parents=True, exist_ok=True)
        payload = json.dumps(
            {
                "schema": SCHEMA_VERSION,
                "records": {k: r.to_json() for k, r in records.items()},
            },
            indent=1,
            sort_keys=True,
        )
        fd, tmp = tempfile.mkstemp(dir=self.dir, suffix=".tmp")
        try:
            with os.fdopen(fd, "w") as fh:
                fh.write(payload)
            os.replace(tmp, self.file)
        # repolint: allow[broad-except] — tmp-file cleanup, re-raised below
        except BaseException:
            try:
                os.unlink(tmp)
            except OSError:
                pass
            raise

    def _records(self) -> dict[str, TuningRecord]:
        if self._mem is None:
            self._mem = self._read_disk()
        return self._mem

    # -- public API ---------------------------------------------------------

    def get(self, key: TuningKey) -> TuningRecord | None:
        return self._records().get(key.cache_id)

    @contextlib.contextmanager
    def _locked(self) -> Iterator[None]:
        """Advisory exclusive lock serializing read-merge-write cycles
        across processes (posix only; elsewhere the merge alone bounds
        the race to a re-measure)."""
        if fcntl is None:
            yield
            return
        self.dir.mkdir(parents=True, exist_ok=True)
        with open(self.dir / "cache.lock", "w") as fh:
            fcntl.flock(fh, fcntl.LOCK_EX)
            try:
                yield
            finally:
                fcntl.flock(fh, fcntl.LOCK_UN)

    def put(self, key: TuningKey, record: TuningRecord) -> None:
        if not record.created:
            record.created = time.time()
        # Under the lock, disk wins for every key except the one being
        # written now: every earlier put already wrote through, and our
        # in-memory view may be staler than another process's upgrade.
        with self._locked():
            merged = self._read_disk()
            merged[key.cache_id] = record
            self._mem = merged
            self._write_disk(merged)

    def items(self) -> dict[str, TuningRecord]:
        return dict(self._records())

    def clear(self) -> None:
        self._mem = {}
        try:
            self.file.unlink()
        except OSError:
            pass
