"""Persistent block-shape autotuning (paper Sec. 5.1, Fig. 14).

Layers:

* ``costmodel`` — structural candidate enumeration (rank-generic) + the
  measurement protocol;
* ``cache``     — persistent per-platform JSON store
  (``$REPRO_TUNE_CACHE`` or ``~/.cache/repro-tune/``), schema-versioned;
* ``session``   — structural-rank → measure-top-k → record, with a
  cache-hit fast path; the ``block="auto"`` resolvers live here. The
  fused-engine resolver (``auto_block_nd``) keys the cache on the
  serialized ``StencilPlan`` identity, so rank-1/2/3 problems share one
  persistent cache with distinct, stable keys;
* ``cli``       — ``python -m repro.tuning warm|show|clear``.
"""
from repro.tuning.cache import (  # noqa: F401
    SCHEMA_VERSION,
    TuningCache,
    TuningKey,
    TuningRecord,
    candidate_label,
    current_backend,
    default_cache_dir,
    format_block,
)
from repro.tuning.costmodel import (  # noqa: F401
    Candidate,
    Candidate1D,
    LANE,
    SUBLANE,
    VMEM_BUDGET,
    autotune,
    axis_tile_options,
    domain_axis_options,
    enumerate_candidates,
    enumerate_candidates_1d,
    enumerate_candidates_nd,
    enumerate_cross_strategy_nd,
    halo_overhead,
    hwc_candidate,
    time_candidate,
    vmem_working_set,
)
from repro.tuning.shapes import warm_model_kernels  # noqa: F401
from repro.tuning.session import (  # noqa: F401
    TuningSession,
    auto_block_3d,
    auto_block_conv1d,
    auto_block_nd,
    auto_block_xcorr1d,
    auto_fuse_nd,
    auto_strategy_nd,
    default_session,
    enable_auto,
    fused3d_candidates,
    fused3d_key,
    fused_nd_candidates,
    fused_nd_key,
    lookup_fused3d,
    lookup_fused_nd,
)
