"""Structural cost model + measurement protocol — the paper's Sec. 5.1
tuning search on TPU terms.

The paper tunes thread-block dimensions (τx, τy, τz) with a pruned
heuristic search: τx a multiple of the L2-line/word ratio, total threads
a multiple of warp size, invalid launches discarded, 3-iteration timing,
best picked. The TPU analogues (DESIGN.md §2):

* τx multiple of the 128-wide lane dimension (vector register width),
* the VMEM working set must fit the per-core VMEM budget (invalid
  "launches" = blocks that exceed VMEM → discarded *statically*),
* per-candidate timing = warm-up + median of k timed calls.

Additionally a *structural* cost model ranks candidates without hardware
— used on CPU-only containers and as a search-space pruner on real TPUs
(napkin math first, measurement second). The persistent layer on top of
this module lives in ``repro.tuning.cache`` / ``repro.tuning.session``.
"""
from __future__ import annotations

import dataclasses
import itertools
import logging
import math
import time
from typing import Callable, Iterable, Sequence

import jax
import numpy as np

from repro.core.trafficmodel import (
    peak_hbm_bw,
    peak_mxu_flops,
    peak_vpu_flops,
    stencil_batched_hbm_bytes_per_member_step,
    stencil_hbm_bytes_per_step,
    stencil_mxu_flops_per_step,
    stencil_redundant_compute_fraction,
    stencil_stream_hbm_bytes_per_step,
)
from repro.kernels.plan import TC_MAX_TILE

log = logging.getLogger("repro.tuning")

# Conservative per-core VMEM budget (bytes). v4/v5 expose ~16 MiB per
# core to Pallas; we leave headroom for the output block + spills.
VMEM_BUDGET = 12 * 1024 * 1024
LANE = 128
SUBLANE = 8


@dataclasses.dataclass(frozen=True)
class Candidate:
    block: tuple[int, ...]  # rank-length tile, x last
    vmem_bytes: int
    halo_overhead: float  # redundant-fetch fraction vs perfect reuse
    score: float  # structural cost-model score (lower = better)
    fuse_steps: int = 1  # temporal fusion depth of this candidate
    # True for explicit-streaming (swc_stream) configurations: the
    # slowest axis is streamed with carried halo planes, so the traffic
    # and VMEM terms use the streaming model.
    stream: bool = False
    # Caching regime this candidate lowers through ("hwc" | "swc" |
    # "swc_stream" | "tc") — the cross-strategy "auto" search mixes
    # them in one ranked space, and the tuning record persists the
    # winner.
    strategy: str = "swc"


# Weight of redundant halo *compute* against saved HBM traffic in the
# temporal score. Stencils are bandwidth-bound on both paper targets
# (and on TPU: ~1 FLOP/byte stencil intensity vs ~100 machine balance),
# so recomputed halo points cost far less than re-fetched ones; the
# weight is the modeled compute-time share of a balanced fused kernel.
# Calibration: the paper's 3-D order-6 diffusion step (38 flops/point,
# 8 compulsory bytes/point, v5e peaks) gives
# (38/24.625e12)/(8/819e9) ≈ 0.158 — which is what
# :func:`temporal_compute_weight` reproduces from first principles for
# any tap count when the caller supplies ``flops_per_point``; this
# constant is the fixed fallback for hand-built operator sets that
# don't report one.
TEMPORAL_COMPUTE_WEIGHT = 0.15


def temporal_compute_weight(
    flops_per_point: float | None,
    n_f: int,
    n_out: int,
    itemsize: int,
    backend: str | None = None,
) -> float:
    """Per-order compute weight of the temporal score: the ratio of a
    point's VPU time (``flops_per_point / peak_vpu``) to its compulsory
    HBM time (``(n_f + n_out)·itemsize / peak_bw``) — the fraction of
    the bandwidth roof one redundantly recomputed point costs.

    This is how the operator's accuracy order reaches the strategy
    ranking: an order-2 set (few taps) weighs halo recompute lightly
    and fuses deep, an order-8 set (≈4× the taps) pays ≈4× more per
    recomputed point and the model backs off the depth. Falls back to
    :data:`TEMPORAL_COMPUTE_WEIGHT` when ``flops_per_point`` is None
    (hand-built taps with no operator metadata).
    """
    if flops_per_point is None:
        return TEMPORAL_COMPUTE_WEIGHT
    hbm_time = (n_f + n_out) * itemsize / peak_hbm_bw(backend)
    return (flops_per_point / peak_vpu_flops(backend)) / hbm_time


def vmem_working_set(
    block: Sequence[int],
    radii: Sequence[int],
    n_f: int,
    n_out: int,
    itemsize: int,
    fuse_steps: int = 1,
    stream: bool = False,
    *,
    batch: int = 1,
    unroll: int = 1,
    n_aux: int = 0,
) -> int:
    """VMEM footprint of one block, any rank. Temporal fusion widens
    the staged window to ``radii * fuse_steps`` and holds one
    intermediate field generation on-chip between sweeps.

    ``stream=True`` models the explicit-streaming kernel's scratch
    instead: the working buffer (tile + widened halo on every axis),
    two prefetch buffers (τ₀ fresh planes × the cross window), and the
    output staging tile — the shapes ``emit._fused_stream`` allocates.

    ``batch`` is the ensemble extent of a batched launch: the member-
    major lowering stages all B members' field rows in one window, so
    every field-count term scales by B — which is why the batched
    candidate enumeration picks smaller blocks at larger B.

    ``unroll`` is the element-wise unroll factor of a pipelined plan:
    the staged window and output tile span all ``unroll`` x sub-tiles
    per grid step (``τx·unroll + 2r`` / ``τx·unroll``), so an unrolled
    block is NOT the footprint of its base block — before this term
    the model under-counted unrolled plans by nearly ``unroll``×.
    ``n_aux`` counts point-wise aux operands, staged (and, like every
    pipelined input, double-buffered) as a halo-free tile at depth 1
    and an ``r·(S-1)``-widened window at temporal depth S. Streaming
    plans reject both (plan validation), so the kwargs are ignored for
    ``stream=True``. The shapes here mirror
    ``emit.lowering_windows``/``emit.stream_extents`` — the fidelity
    contract ``repro.analysis.vmem`` checks per lowerable plan.
    """
    if batch < 1:
        raise ValueError(f"batch must be >= 1, got {batch}")
    n_f = n_f * batch
    n_out = n_out * batch
    n_aux = n_aux * batch
    if stream:
        work, pf, mid, out = n_f, n_f, n_f if fuse_steps > 1 else 0, n_out
        for a, (t, r) in enumerate(zip(block, radii)):
            work *= t + 2 * r * fuse_steps
            pf *= t if a == 0 else t + 2 * r * fuse_steps
            mid *= t + 2 * r * (fuse_steps - 1)
            out *= t
        return (work + 2 * pf + mid + out) * itemsize
    last = len(tuple(block)) - 1
    inp = n_f
    mid = n_f if fuse_steps > 1 else 0
    aux = n_aux
    out = n_out
    for a, (t, r) in enumerate(zip(block, radii)):
        step = t * unroll if a == last else t
        inp *= step + 2 * r * fuse_steps
        mid *= t + 2 * r * (fuse_steps - 1)
        aux *= step if fuse_steps == 1 else t + 2 * r * (fuse_steps - 1)
        out *= step
    # Pallas double-buffers pipelined input blocks: 2x input (and aux).
    return (2 * inp + 2 * aux + mid + out) * itemsize


def halo_overhead(
    block: Sequence[int],
    radii: Sequence[int],
    fuse_steps: int = 1,
) -> float:
    """Redundant-fetch fraction of one staged block vs perfect reuse.

    Guard (tiny blocks × anisotropic radii, fused depths only): when a
    fused sweep's valid region — which shrinks by one radius per step —
    would hit zero/negative interior volume on some axis
    (``t <= 2·r·fuse_steps`` with ``fuse_steps > 1``), the
    configuration is all overhead, so the score is ``inf`` and
    enumeration excludes the candidate instead of ranking it on a
    misleading finite value. At depth 1 nothing shrinks, so small tiles
    keep their (finite, merely large) overhead.
    """
    fetched, useful = 1, 1
    for t, r in zip(block, radii):
        if fuse_steps > 1 and t <= 2 * r * fuse_steps:
            return math.inf
        fetched *= t + 2 * r * fuse_steps
        useful *= t
    return fetched / useful - 1.0


def enumerate_candidates_nd(
    domain: Sequence[int],
    radii: Sequence[int],
    n_f: int,
    n_out: int,
    itemsize: int = 4,
    *,
    vmem_budget: int = VMEM_BUDGET,
    axis_options: Sequence[Sequence[int]] | None = None,
    fuse_steps_options: Sequence[int] = (1,),
    stream_options: Sequence[bool] = (False,),
    tc_options: Sequence[bool] = (False,),
    tc_groups: Sequence[int] | None = None,
    backend: str | None = None,
    batch: int = 1,
    flops_per_point: float | None = None,
) -> list[Candidate]:
    """Generate, filter (divisibility + VMEM + the tiny-block guard),
    and rank (block, fuse_steps, stream) configurations for a
    rank-1/2/3 domain (the planner's search space — blocks are listed
    in axis order, x last). ``axis_options`` overrides the per-axis
    tile bases (same order); ``fuse_steps_options`` widens the sweep to
    temporal fusion depths, and ``stream_options`` to the explicit-
    streaming kernel (rank ≥ 2 only — the entry is skipped at rank 1),
    all scored jointly.

    The score is a roofline-flavored sum of the modeled per-step HBM
    traffic (via ``core.trafficmodel.stencil_hbm_bytes_per_step``, or
    its ``stencil_stream_hbm_bytes_per_step`` sibling for streaming
    candidates — the carried halo planes eliminate the stream-axis halo
    re-fetch, which is why a streaming candidate can out-score every
    pipelined block), normalized to the compulsory read+write of the
    interior, plus the weighted redundant-halo compute a fused depth
    re-evaluates, with mild penalties for lane-misaligned x tiles, very
    small stream-axis tiles (per-chunk/pipeline bubble), and — at rank
    1, where the grid-step count is the only parallel axis — short
    blocks that don't amortize the per-step pipeline overhead. Lower is
    better.

    ``batch > 1`` models a batched ensemble launch: the VMEM filter
    scales every field-count term by B (so larger ensembles admit only
    smaller blocks) and the traffic term switches to the per-member
    batched model, which amortizes the fixed per-launch overhead over
    B·fuse_steps — different B therefore rank (and admit) different
    blocks/depths, which is why ``batch`` joins the tuning key.

    ``tc_options`` adds matrix-unit (``tc``) candidates: same staging
    and traffic model as pipelined ``swc``, but scored on
    ``max(traffic_time, mxu_time)`` — a genuine two-resource roofline
    instead of the scalar :data:`TEMPORAL_COMPUTE_WEIGHT` hack, because
    the MXU work of a banded contraction grows with the tile extent and
    really can dominate. The MXU term normalizes the modeled FLOPs
    (``stencil_mxu_flops_per_step`` with ``tc_groups`` matmul groups
    per axis, peak rates looked up for ``backend``) against the same
    ideal-traffic denominator the traffic score uses, so the two sides
    of the ``max`` are in the same unit. tc candidates are skipped for
    8-byte dtypes (no f64 MXU path) and for tiles beyond
    ``TC_MAX_TILE`` on any axis (the contraction extent — and with it
    the per-point FLOPs — grows with the tile).

    ``flops_per_point`` is the operator set's VPU work per grid point
    (``OperatorSet.flops_per_point(n_f)`` — 2 FLOPs per tap per field):
    when given, the temporal redundancy weight is derived from it per
    order via :func:`temporal_compute_weight`, so ``strategy="auto"``
    re-ranks depths as the tap count grows with the accuracy order;
    when None the fixed :data:`TEMPORAL_COMPUTE_WEIGHT` applies.
    """
    domain = tuple(domain)
    compute_weight = temporal_compute_weight(
        flops_per_point, n_f, n_out, itemsize, backend
    )
    rank = len(domain)
    if axis_options is None:
        axis_options = axis_tile_options(domain)
    points = 1
    for n in domain:
        points *= n
    ideal_bytes = (n_f + n_out) * points * itemsize  # compulsory traffic
    out: list[Candidate] = []
    regimes: list[str] = []
    for stream in stream_options:
        if stream and rank < 2:
            continue  # streaming needs a cross-stream tile axis
        regimes.append("swc_stream" if stream else "swc")
    for tc in tc_options:
        # No MXU path for 8-byte dtypes (f32/bf16-input-f32-accumulate
        # only — mirrors StencilPlan validation).
        if tc and itemsize in (2, 4) and "tc" not in regimes:
            regimes.append("tc")
    for regime in regimes:
        stream = regime == "swc_stream"
        tc = regime == "tc"
        for fuse in fuse_steps_options:
            for raw in itertools.product(*axis_options):
                blk = []
                ok = True
                for n, t in zip(domain, raw):
                    if n % t and t != n:
                        ok = False
                        break
                    blk.append(min(t, n))
                if not ok:
                    continue
                blk = tuple(blk)
                if tc and any(t > TC_MAX_TILE for t in blk):
                    continue  # contraction extent (→ FLOPs) unbounded
                if stream and fuse > 1 and (
                    domain[0] < 2 * radii[0] * fuse + blk[0]
                ):
                    # The fused stream walk needs the stream-axis extent
                    # to hold the carried halo (2·r·S planes) plus one
                    # chunk — the same bound StencilPlan validates.
                    continue
                ho = halo_overhead(blk, radii, fuse)
                if not math.isfinite(ho):
                    continue  # tile swallowed by its widened halo
                vm = vmem_working_set(
                    blk, radii, n_f, n_out, itemsize, fuse, stream,
                    batch=batch,
                )
                if vm > vmem_budget:
                    continue  # the "failed launch" discard
                if batch == 1:
                    traffic_fn = (
                        stencil_stream_hbm_bytes_per_step
                        if stream
                        else stencil_hbm_bytes_per_step
                    )
                    traffic = traffic_fn(
                        domain, blk, radii, n_f, n_out, itemsize, fuse
                    ) / ideal_bytes
                else:
                    traffic = stencil_batched_hbm_bytes_per_member_step(
                        domain, blk, radii, n_f, n_out, itemsize,
                        batch=batch, fuse_steps=fuse, stream=stream,
                    ) / ideal_bytes
                redundancy = stencil_redundant_compute_fraction(
                    blk, radii, fuse
                )
                align_pen = 0.0 if blk[-1] % LANE == 0 else 0.15
                bubble_pen = (
                    0.05
                    if (rank == 3 or stream) and rank > 1 and blk[0] < 4
                    else 0.0
                )
                step_pen = LANE / blk[-1] if rank == 1 else 0.0
                pens = 1.0 + align_pen + bubble_pen + step_pen
                if tc:
                    # Two-resource roofline: the launch takes the
                    # slower of its HBM walk and its MXU contractions.
                    # Halo recompute is already inside the FLOPs term
                    # (sub-windows include the shrinking margins), so
                    # no separate redundancy weight.
                    mxu = (
                        stencil_mxu_flops_per_step(
                            domain, blk, radii, n_f, fuse,
                            groups_per_axis=tc_groups,
                        )
                        / peak_mxu_flops(backend, itemsize)
                    ) / (ideal_bytes / peak_hbm_bw(backend))
                    score = max(traffic, mxu) * pens
                else:
                    score = (
                        traffic * pens
                        + compute_weight * redundancy
                    )
                out.append(
                    Candidate(
                        blk, vm, ho, score, fuse, stream,
                        strategy=regime,
                    )
                )
    # Tie-break equal modeled scores on the smaller VMEM working set
    # (e.g. a full-extent pipelined tile vs the streaming kernel, whose
    # carried planes make the same traffic with less residency).
    out.sort(key=lambda c: (c.score, c.vmem_bytes))
    return out


def hwc_candidate(
    domain: Sequence[int],
    fuse_steps: int = 1,
) -> Candidate:
    """The hardware-managed-caching baseline as a tuning candidate.

    ``hwc`` stages nothing itself — XLA owns on-chip residency — so it
    is modeled at the compulsory-traffic *floor*: one read of every
    input field plus one write of every output per step, normalized
    score exactly 1.0 with no VMEM footprint. A ``swc``/``swc_stream``
    candidate therefore only out-ranks it structurally when temporal
    fusion (or streaming) models *less* than the compulsory per-step
    traffic; on an eager resolution the measured XLA baseline competes
    on real time instead. The block is the per-rank default clamped to
    the domain — the hwc path ignores it, but the record round-trips a
    concrete value.
    """
    from repro.kernels.plan import DEFAULT_BLOCKS

    block = tuple(
        min(t, n) for t, n in zip(DEFAULT_BLOCKS[len(domain)], domain)
    )
    return Candidate(
        block=block, vmem_bytes=0, halo_overhead=0.0, score=1.0,
        fuse_steps=fuse_steps, stream=False, strategy="hwc",
    )


def enumerate_cross_strategy_nd(
    domain: Sequence[int],
    radii: Sequence[int],
    n_f: int,
    n_out: int,
    itemsize: int = 4,
    *,
    vmem_budget: int = VMEM_BUDGET,
    fuse_steps_options: Sequence[int] = (1,),
    stream_ok: bool = True,
    tc_ok: bool = True,
    tc_groups: Sequence[int] | None = None,
    backend: str | None = None,
    batch: int = 1,
    flops_per_point: float | None = None,
) -> list[Candidate]:
    """The ``strategy="auto"`` candidate space: every ``swc``, (rank
    ≥ 2, ``stream_ok``) ``swc_stream`` and (f32/bf16, ``tc_ok``) ``tc``
    configuration the joint ``(strategy, block, fuse_steps, stream)``
    enumeration admits, plus the ``hwc`` baseline as the modeled-
    traffic floor, ranked in ONE ordered list — the space in which
    ``strategy="auto"`` discovers the VPU/MXU crossover.

    The hwc entry is always present, so the cross-strategy search can
    never come back empty or VMEM-degenerate — a domain too small to
    block or stream profitably resolves to the compiler-managed path
    instead of a fallback record. Its depth is the smallest enumerated
    depth (1 unless a per-step φ sequence pins the search deeper).
    """
    cands = enumerate_candidates_nd(
        domain, radii, n_f, n_out, itemsize, vmem_budget=vmem_budget,
        fuse_steps_options=fuse_steps_options,
        stream_options=(False, True) if stream_ok else (False,),
        tc_options=(False, True) if tc_ok else (False,),
        tc_groups=tc_groups, backend=backend,
        batch=batch, flops_per_point=flops_per_point,
    )
    out = [hwc_candidate(domain, min(fuse_steps_options))] + cands
    out.sort(key=lambda c: (c.score, c.vmem_bytes))
    return out


def enumerate_candidates(
    domain: tuple[int, int, int],
    radii: tuple[int, int, int],
    n_f: int,
    n_out: int,
    itemsize: int = 4,
    *,
    vmem_budget: int = VMEM_BUDGET,
    tx_options: Sequence[int] = (128, 256, 512),
    ty_options: Sequence[int] = (4, 8, 16, 32),
    tz_options: Sequence[int] = (2, 4, 8, 16, 32),
) -> list[Candidate]:
    """Rank-3 enumeration (historical signature).

    .. deprecated::
        ``enumerate_candidates`` is deprecated; use
        :func:`enumerate_candidates_nd` (rank-generic, with
        ``axis_options`` in axis order, x last).
    """
    import warnings

    warnings.warn(
        "enumerate_candidates is deprecated; use enumerate_candidates_nd",
        DeprecationWarning,
        stacklevel=2,
    )
    return enumerate_candidates_nd(
        domain, radii, n_f, n_out, itemsize, vmem_budget=vmem_budget,
        axis_options=(tz_options, ty_options, tx_options),
    )


# Per-axis tile bases: x spans the 128-wide lane dimension; at rank 1 it
# is the only axis, so long blocks dominate. y/z use the paper's
# TPU-friendly sublane/streaming bases.
X_BASE_1D = (512, 1024, 2048, 4096, 8192)
X_BASE = (64, 128, 256, 512)
Y_BASE = (4, 8, 16, 32)
Z_BASE = (2, 4, 8, 16, 32)


def axis_tile_options(
    domain: Sequence[int],
) -> tuple[tuple[int, ...], ...]:
    """Per-axis tile options adapted to the actual extents, any rank:
    the TPU-friendly bases, each capped at the axis extent (so small
    research domains like 16³ still enumerate valid candidates), plus
    the full extent itself."""
    rank = len(domain)

    def opts(n: int, base: Sequence[int]) -> tuple[int, ...]:
        kept = [o for o in base if o <= n] + [n]
        return tuple(dict.fromkeys(kept))

    bases = {
        1: (X_BASE_1D,),
        2: (Y_BASE, X_BASE),
        3: (Z_BASE, Y_BASE, X_BASE),
    }[rank]
    return tuple(opts(n, b) for n, b in zip(domain, bases))


def domain_axis_options(
    domain: tuple[int, int, int],
    *,
    tx_base: Sequence[int] = X_BASE,
    ty_base: Sequence[int] = Y_BASE,
    tz_base: Sequence[int] = Z_BASE,
) -> tuple[tuple[int, ...], tuple[int, ...], tuple[int, ...]]:
    """Rank-3 per-axis options (historical signature).

    .. deprecated::
        ``domain_axis_options`` is deprecated; use
        :func:`axis_tile_options` (rank-generic).
    """
    import warnings

    warnings.warn(
        "domain_axis_options is deprecated; use axis_tile_options",
        DeprecationWarning,
        stacklevel=2,
    )
    nz, ny, nx = domain

    def opts(n: int, base: Sequence[int]) -> tuple[int, ...]:
        kept = [o for o in base if o <= n] + [n]
        return tuple(dict.fromkeys(kept))

    return opts(nz, tz_base), opts(ny, ty_base), opts(nx, tx_base)


@dataclasses.dataclass(frozen=True)
class Candidate1D:
    """Block-length candidate for the 1-D kernels (xcorr, depthwise
    conv): ``block`` elements per grid step along the streamed axis."""

    block: int
    vmem_bytes: int
    score: float


def enumerate_candidates_1d(
    n: int,
    halo: int,
    *,
    width: int = 1,
    itemsize: int = 4,
    vmem_budget: int = VMEM_BUDGET,
    options: Sequence[int] = (256, 512, 1024, 2048, 4096, 8192),
) -> list[Candidate1D]:
    """Rank 1-D block lengths: VMEM filter, then a structural score that
    trades halo refetch + per-step pipeline overhead (favoring long
    blocks) against tail-padding waste (favoring blocks near a divisor
    of ``n``). ``width`` is the per-element row width (channels for the
    depthwise conv)."""
    out: list[Candidate1D] = []
    for b in options:
        if b > max(n, LANE):
            continue
        vm = (2 * (b + halo) + b) * width * itemsize
        if vm > vmem_budget:
            continue
        waste = (-(-n // b) * b - n) / n
        score = (1.0 + halo / b) * (1.0 + waste) * (1.0 + LANE / b)
        out.append(Candidate1D(b, vm, score))
    out.sort(key=lambda c: c.score)
    return out


def time_candidate(
    fn: Callable[[], jax.Array],
    *,
    warmup: int = 2,
    iters: int = 5,
    validate: bool = True,
) -> float:
    """Median wall-clock seconds (paper: warm-up then median of timed
    iterations, block_until_ready for proper synchronization).

    ``validate`` checks the first warm-up output for NaN/inf and raises
    ``ValueError`` on corruption — a mis-lowered candidate that blows
    up numerically must be discarded as a failed launch (and recorded
    as a ``failed`` row by the session), not timed into a cache winner.
    """
    for i in range(warmup):
        out = jax.block_until_ready(fn())
        if validate and i == 0:
            _check_finite(out)
    ts = []
    for i in range(iters):
        t0 = time.perf_counter()
        out = jax.block_until_ready(fn())
        ts.append(time.perf_counter() - t0)
        if validate and warmup == 0 and i == 0:
            _check_finite(out)
    return float(np.median(ts))


def _check_finite(out: object) -> None:
    """Raise ``ValueError`` if any floating leaf of ``out`` contains
    NaN/inf (the candidate-output validation gate of
    :func:`time_candidate`)."""
    for leaf in jax.tree_util.tree_leaves(out):
        arr = np.asarray(leaf)
        # bfloat16 (ml_dtypes) reports numpy kind "V", not "f" — catch
        # it by name so low-precision candidates are validated too.
        if arr.dtype.kind not in "fc" and "float" not in arr.dtype.name:
            continue
        try:
            finite = bool(np.isfinite(arr).all())
        except TypeError:  # exotic float dtypes (e.g. bfloat16)
            finite = bool(np.isfinite(arr.astype(np.float32)).all())
        if not finite:
            raise ValueError(
                "candidate produced non-finite output "
                f"(shape {arr.shape}, dtype {arr.dtype})"
            )


def autotune(
    make_fn: Callable[[tuple[int, int, int]], Callable[[], jax.Array]],
    candidates: Iterable[Candidate],
    *,
    top_k: int = 4,
    warmup: int = 2,
    iters: int = 5,
) -> tuple[Candidate, dict[tuple[int, int, int], float]]:
    """Measure the ``top_k`` structurally-ranked candidates and return the
    winner plus the full timing table (the paper's search, with the cost
    model as the pruner)."""
    timings: dict[tuple[int, int, int], float] = {}
    best: tuple[float, Candidate] | None = None
    for cand in list(candidates)[:top_k]:
        try:
            fn = make_fn(cand.block)
            t = time_candidate(fn, warmup=warmup, iters=iters)
        except Exception as e:
            # The paper's discarded launch: log which candidate died
            # and why, then keep ranking the rest.
            log.debug("autotune candidate %s discarded: %s", cand.block, e)
            continue
        timings[cand.block] = t
        if best is None or t < best[0]:
            best = (t, cand)
    if best is None:
        raise RuntimeError("no candidate ran successfully")
    return best[1], timings
