"""Back-compat entry point for the fused 3-D kernel.

The kernel bodies moved to the rank-generic engine
(``repro.kernels.plan`` + ``repro.kernels.emit``): one pipelined
emitter now serves ranks 1-3 and the explicit z-streaming variant is a
rank-3 plan attribute. This module keeps the historical
``fused_stencil3d_pallas`` signature for existing callers and tests.
"""
from __future__ import annotations

from typing import Callable

import jax.numpy as jnp

from repro.core.stencil import OperatorSet
from repro.kernels.emit import fused_stencil_pallas
from repro.kernels.plan import plan_stencil


def fused_stencil3d_pallas(
    f_padded: jnp.ndarray,
    ops: OperatorSet,
    phi: Callable[..., jnp.ndarray],
    n_out: int,
    *,
    aux: jnp.ndarray | None = None,
    block: tuple[int, int, int] = (8, 8, 128),
    strategy: str = "swc",
    interpret: bool = False,
) -> jnp.ndarray:
    """Apply the fused φ(A·B) update over a padded (n_f, z, y, x) domain.

    .. deprecated::
        ``fused_stencil3d_pallas`` is deprecated; use
        ``repro.kernels.ops.fused_stencil_nd`` (rank-generic, handles
        padding/interpret defaults) or the ``plan_stencil`` →
        ``fused_stencil_pallas`` pipeline directly.
    """
    import warnings

    warnings.warn(
        "fused_stencil3d_pallas is deprecated; use fused_stencil_nd",
        DeprecationWarning,
        stacklevel=2,
    )
    plan = plan_stencil(
        ops, f_padded.shape, n_out, strategy=strategy, block=block,
        dtype=str(f_padded.dtype),
        n_aux=aux.shape[0] if aux is not None else 0,
    )
    return fused_stencil_pallas(
        f_padded, ops, phi, plan, aux=aux, interpret=interpret
    )
