"""Fused 3-D multiphysics stencil kernel — the paper's core contribution
(Sec. 4.4, Figs. 4-5) on the TPU target.

One kernel invocation evaluates, for every grid point of its block,

    Q = A · B        (all n_s linear stencil operators × all n_f fields)
    out = φ(Q)       (all n_out nonlinear field updates)

so intermediate derivatives never round-trip through HBM — the paper's
operator-fusion strategy for cache-heavy nonlinear stencils.

Two software-managed-cache strategies are provided (DESIGN.md §2):

* ``swc``        — Fig. 5a adapted: the input block (τz+2r, τy+2r, τx+2r)
  per field is staged into VMEM by the Pallas pipeline with the z grid
  axis innermost, so consecutive steps walk z with automatic
  double-buffered prefetch. Tap evaluation is fully unrolled with static
  offsets (the point-wise-unroll codegen mode) and runs on the VPU as
  shifted-slice FMAs — the TPU-native form of the paper's per-thread MAC.
* ``swc_stream`` — Fig. 5b faithfully: the (y, x) tile is fixed per grid
  step and the kernel *streams* z-chunks through an explicitly managed
  VMEM working buffer, with a prefetch buffer updated by async DMA in
  parallel with compute, and the trailing 2r halo planes carried over
  between chunks. On TPU the paper's circular-buffer trick (avoiding the
  data shuffle) would force dynamic modular slicing, defeating static tap
  unrolling, so we carry the halo with a cheap VMEM-to-VMEM plane copy
  instead — same HBM traffic (each plane fetched exactly once), different
  on-chip mechanics; see DESIGN.md §2 for the rationale.

The HWC ("let the compiler manage residency") strategy lives in
``repro.kernels.ref`` / ``repro.core.fusion`` as pure jnp.
"""
from __future__ import annotations

import functools
from typing import Callable

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.stencil import OperatorSet
from repro.kernels.compat import element_window_spec


def _block_derivs(
    fblk: jnp.ndarray,
    ops: OperatorSet,
    rad: tuple[int, int, int],
    tile: tuple[int, int, int],
) -> dict[str, jnp.ndarray]:
    """Evaluate every operator over the VMEM-resident block.

    ``fblk``: (n_f, τz+2rz, τy+2ry, τx+2rx). Static slices per tap —
    unrolled at trace time (stencil-point-wise unrolling)."""
    rz, ry, rx = rad
    tz, ty, tx = tile
    out: dict[str, jnp.ndarray] = {}
    for spec in ops.ops:
        acc = None
        for off, c in zip(spec.offsets, spec.coeffs):
            oz, oy, ox = off
            window = fblk[
                :,
                rz + oz : rz + oz + tz,
                ry + oy : ry + oy + ty,
                rx + ox : rx + ox + tx,
            ]
            term = jnp.asarray(c, dtype=fblk.dtype) * window
            acc = term if acc is None else acc + term
        out[spec.name] = acc
    return out


def _kernel_pipelined(f_ref, o_ref, *, ops, rad, tile, phi):
    fblk = f_ref[...]
    derivs = _block_derivs(fblk, ops, rad, tile)
    o_ref[...] = phi(derivs)


def _kernel_pipelined_aux(f_ref, aux_ref, o_ref, *, ops, rad, tile, phi):
    fblk = f_ref[...]
    derivs = _block_derivs(fblk, ops, rad, tile)
    o_ref[...] = phi(derivs, aux_ref[...])


def fused_stencil3d_pallas(
    f_padded: jnp.ndarray,
    ops: OperatorSet,
    phi: Callable[..., jnp.ndarray],
    n_out: int,
    *,
    aux: jnp.ndarray | None = None,
    block: tuple[int, int, int] = (8, 8, 128),
    strategy: str = "swc",
    interpret: bool = False,
) -> jnp.ndarray:
    """Apply the fused φ(A·B) update over a padded multi-field domain.

    ``f_padded``: (n_f, nz+2rz, ny+2ry, nx+2rx) with per-axis radii from
    ``ops.radius_per_axis()``. Returns (n_out, nz, ny, nx). Block dims
    must divide the interior extents (handled by ``ops.fused_stencil3d``).

    ``aux`` (n_aux, nz, ny, nx): optional extra point-wise inputs staged
    as halo-free center tiles and passed as phi's second argument — used
    to fuse point-wise follow-up work (e.g. the RK axpy) into the stencil
    kernel, a beyond-paper extension of the fusion strategy.
    """
    if strategy == "swc_stream":
        if aux is not None:
            raise NotImplementedError("aux inputs: use strategy='swc'")
        return _fused_stream(f_padded, ops, phi, n_out, block=block,
                             interpret=interpret)
    if strategy != "swc":
        raise ValueError(f"unknown strategy {strategy!r}")
    rz, ry, rx = ops.radius_per_axis()
    tz, ty, tx = block
    n_f = f_padded.shape[0]
    nz = f_padded.shape[1] - 2 * rz
    ny = f_padded.shape[2] - 2 * ry
    nx = f_padded.shape[3] - 2 * rx
    for name, n, t in (("z", nz, tz), ("y", ny, ty), ("x", nx, tx)):
        if n % t:
            raise ValueError(f"{name} extent {n} not divisible by tile {t}")

    # Grid order (y, x, z): z is the innermost (fastest) axis, so the
    # Pallas pipeline's next-block prefetch walks the z-stream — the
    # auto-pipelined analogue of the paper's streamed z-axis.
    grid = (ny // ty, nx // tx, nz // tz)
    in_specs = [
        element_window_spec(
            (n_f, tz + 2 * rz, ty + 2 * ry, tx + 2 * rx),
            lambda j, k, i: (0, i * tz, j * ty, k * tx),
            window_dims=(1, 2, 3),
        )
    ]
    operands = [f_padded]
    if aux is None:
        kernel = functools.partial(
            _kernel_pipelined, ops=ops, rad=(rz, ry, rx),
            tile=(tz, ty, tx), phi=phi,
        )
    else:
        kernel = functools.partial(
            _kernel_pipelined_aux, ops=ops, rad=(rz, ry, rx),
            tile=(tz, ty, tx), phi=phi,
        )
        in_specs.append(
            pl.BlockSpec(
                (aux.shape[0], tz, ty, tx), lambda j, k, i: (0, i, j, k)
            )
        )
        operands.append(aux)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=in_specs,
        out_specs=pl.BlockSpec(
            (n_out, tz, ty, tx), lambda j, k, i: (0, i, j, k)
        ),
        out_shape=jax.ShapeDtypeStruct((n_out, nz, ny, nx), f_padded.dtype),
        interpret=interpret,
    )(*operands)


# ---------------------------------------------------------------------------
# Fig. 5b: explicit z-streaming with carried halo planes + prefetch DMA.
# ---------------------------------------------------------------------------


def _kernel_stream(
    f_hbm, o_hbm, work, pf0, pf1, outbuf, sem_pf, sem_out, *,
    ops, rad, tile, phi, n_chunks, n_f, n_out,
):
    """Grid step = one (y, x) tile; the kernel streams all z-chunks.

    VMEM scratch:
      ``work``  (n_f, τz+2rz, τy+2ry, τx+2rx) — the working set;
      ``pf0/1`` (n_f, τz,     τy+2ry, τx+2rx) — double-buffered prefetch
                 of the τz fresh planes for the next chunk;
      ``outbuf``(n_out, τz, τy, τx)           — staging for output DMA.
    """
    j = pl.program_id(0)
    k = pl.program_id(1)
    rz, ry, rx = rad
    tz, ty, tx = tile
    y0 = j * ty
    x0 = k * tx

    def fresh_copy(chunk, pf_ref, slot):
        """DMA the τz fresh planes of ``chunk`` into a prefetch buffer."""
        return pltpu.make_async_copy(
            f_hbm.at[
                :,
                pl.ds(chunk * tz + 2 * rz, tz),
                pl.ds(y0, ty + 2 * ry),
                pl.ds(x0, tx + 2 * rx),
            ],
            pf_ref,
            sem_pf.at[slot],
        )

    # Prologue: leading halo planes go straight into the working buffer;
    # chunk 0's fresh planes start streaming into prefetch slot 0.
    halo_cp = pltpu.make_async_copy(
        f_hbm.at[:, pl.ds(0, 2 * rz), pl.ds(y0, ty + 2 * ry),
                 pl.ds(x0, tx + 2 * rx)],
        work.at[:, pl.ds(0, 2 * rz)],
        sem_out,  # reuse; waited below before any compute
    )
    halo_cp.start()
    fresh_copy(0, pf0, 0).start()
    halo_cp.wait()

    def body(chunk, _):
        slot = jax.lax.rem(chunk, 2)

        # Kick off the NEXT chunk's fresh-plane DMA before computing this
        # one (the paper's "prefetch buffer updated in parallel with
        # computations").
        @pl.when(chunk + 1 < n_chunks)
        def _():
            @pl.when(slot == 0)
            def _():
                fresh_copy(chunk + 1, pf1, 1).start()

            @pl.when(slot == 1)
            def _():
                fresh_copy(chunk + 1, pf0, 0).start()

        # Land this chunk's fresh planes behind the carried halo.
        @pl.when(slot == 0)
        def _():
            fresh_copy(chunk, pf0, 0).wait()
            work[:, pl.ds(2 * rz, tz)] = pf0[...]

        @pl.when(slot == 1)
        def _():
            fresh_copy(chunk, pf1, 1).wait()
            work[:, pl.ds(2 * rz, tz)] = pf1[...]

        fblk = work[...]
        derivs = _block_derivs(fblk, ops, (rz, ry, rx), (tz, ty, tx))
        outbuf[...] = phi(derivs)
        out_cp = pltpu.make_async_copy(
            outbuf,
            o_hbm.at[:, pl.ds(chunk * tz, tz), pl.ds(y0, ty), pl.ds(x0, tx)],
            sem_out,
        )
        out_cp.start()

        # Carry the trailing halo: last 2rz planes become the next chunk's
        # leading halo (VMEM-to-VMEM plane copy; see module docstring on
        # why TPU prefers this over the circular buffer).
        work[:, pl.ds(0, 2 * rz)] = work[:, pl.ds(tz, 2 * rz)]
        out_cp.wait()
        return 0

    jax.lax.fori_loop(0, n_chunks, body, 0)


def _fused_stream(
    f_padded, ops, phi, n_out, *, block=(8, 8, 128), interpret=False
):
    rz, ry, rx = ops.radius_per_axis()
    tz, ty, tx = block
    n_f = f_padded.shape[0]
    nz = f_padded.shape[1] - 2 * rz
    ny = f_padded.shape[2] - 2 * ry
    nx = f_padded.shape[3] - 2 * rx
    for name, n, t in (("z", nz, tz), ("y", ny, ty), ("x", nx, tx)):
        if n % t:
            raise ValueError(f"{name} extent {n} not divisible by tile {t}")
    n_chunks = nz // tz
    dtype = f_padded.dtype

    kernel = functools.partial(
        _kernel_stream, ops=ops, rad=(rz, ry, rx), tile=(tz, ty, tx),
        phi=phi, n_chunks=n_chunks, n_f=n_f, n_out=n_out,
    )
    return pl.pallas_call(
        kernel,
        grid=(ny // ty, nx // tx),
        in_specs=[pl.BlockSpec(memory_space=pl.ANY)],
        out_specs=pl.BlockSpec(memory_space=pl.ANY),
        out_shape=jax.ShapeDtypeStruct((n_out, nz, ny, nx), dtype),
        scratch_shapes=[
            pltpu.VMEM((n_f, tz + 2 * rz, ty + 2 * ry, tx + 2 * rx), dtype),
            pltpu.VMEM((n_f, tz, ty + 2 * ry, tx + 2 * rx), dtype),
            pltpu.VMEM((n_f, tz, ty + 2 * ry, tx + 2 * rx), dtype),
            pltpu.VMEM((n_out, tz, ty, tx), dtype),
            pltpu.SemaphoreType.DMA((2,)),
            pltpu.SemaphoreType.DMA,
        ],
        interpret=interpret,
    )(f_padded)
