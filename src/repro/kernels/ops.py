"""Public jit'd wrappers for the Pallas kernels.

These handle shape padding (block divisibility), dtype plumbing, the
interpret-mode switch for CPU validation, and strategy selection, so
callers (fusion engine, physics, models) never touch BlockSpecs.

On CPU (this container) ``interpret`` defaults to True; on TPU it
defaults to False. Override explicitly for tests.
"""
from __future__ import annotations

import functools
from typing import Callable, Mapping

import jax
import jax.numpy as jnp

from repro.core.stencil import OperatorSet
from repro.kernels import ref as _ref
from repro.kernels.conv1d_depthwise import conv1d_depthwise_pallas
from repro.kernels.stencil1d import xcorr1d_pallas
from repro.kernels.stencil3d import fused_stencil3d_pallas


def _default_interpret() -> bool:
    return jax.default_backend() != "tpu"


def _round_up(n: int, m: int) -> int:
    return -(-n // m) * m


@functools.partial(
    jax.jit,
    static_argnames=("strategy", "block_size", "unroll", "interpret"),
)
def xcorr1d(
    f_padded: jnp.ndarray,
    g: jnp.ndarray,
    *,
    strategy: str = "baseline",
    block_size: int = 2048,
    unroll: int = 4,
    interpret: bool | None = None,
) -> jnp.ndarray:
    """1-D cross-correlation over the valid region (paper Eq. 3).

    Accepts any n; pads the tail to a block multiple and slices back.
    ``strategy='hwc'`` dispatches to the pure-jnp/XLA-managed path.
    """
    if interpret is None:
        interpret = _default_interpret()
    if strategy == "hwc":
        return _ref.xcorr1d(f_padded, g)
    n_taps = g.shape[0]
    n = f_padded.shape[0] - (n_taps - 1)
    n_pad = _round_up(n, block_size)
    if n_pad != n:
        f_padded = jnp.concatenate(
            [f_padded, jnp.zeros((n_pad - n,), f_padded.dtype)]
        )
    out = xcorr1d_pallas(
        f_padded, g, strategy=strategy, block_size=block_size,
        unroll=unroll, interpret=interpret,
    )
    return out[:n]


def fused_stencil3d(
    f_padded: jnp.ndarray,
    ops: OperatorSet,
    phi: Callable[..., jnp.ndarray],
    n_out: int,
    *,
    aux: jnp.ndarray | None = None,
    strategy: str = "swc",
    block: tuple[int, int, int] = (8, 8, 128),
    interpret: bool | None = None,
) -> jnp.ndarray:
    """Fused φ(A·B) over a padded (n_f, z, y, x) domain (paper Eq. 9).

    ``strategy``: 'hwc' (XLA-managed), 'swc' (Pallas pipelined blocks) or
    'swc_stream' (Pallas explicit z-streaming, paper Fig. 5b). Interior
    extents that don't divide the block are handled by shrinking the
    block to the largest divisor (physics domains are powers of two, so
    in practice blocks are used as-given).
    """
    if interpret is None:
        interpret = _default_interpret()
    if strategy == "hwc":
        return _ref.fused_stencil(f_padded, ops, phi, aux=aux)
    rads = ops.radius_per_axis()
    interior = tuple(
        f_padded.shape[1 + a] - 2 * rads[a] for a in range(3)
    )
    block = tuple(
        _largest_divisor_leq(interior[a], block[a]) for a in range(3)
    )
    return fused_stencil3d_pallas(
        f_padded, ops, phi, n_out, aux=aux, block=block, strategy=strategy,
        interpret=interpret,
    )


def _largest_divisor_leq(n: int, cap: int) -> int:
    for t in range(min(cap, n), 0, -1):
        if n % t == 0:
            return t
    return 1


@functools.partial(
    jax.jit, static_argnames=("activation", "block_seq", "interpret")
)
def conv1d_depthwise(
    x: jnp.ndarray,
    w: jnp.ndarray,
    *,
    activation: str = "none",
    block_seq: int = 512,
    interpret: bool | None = None,
) -> jnp.ndarray:
    """Fused depthwise causal conv1d (+ SiLU) — mamba2 frontend stencil."""
    if interpret is None:
        interpret = _default_interpret()
    b, s, c = x.shape
    block_seq = min(block_seq, _round_up(s, 128))
    s_pad = _round_up(s, block_seq)
    if s_pad != s:
        x = jnp.pad(x, ((0, 0), (0, s_pad - s), (0, 0)))
    out = conv1d_depthwise_pallas(
        x, w, activation=activation, block_seq=block_seq,
        interpret=interpret,
    )
    return out[:, :s, :]
