"""Public wrappers for the Pallas kernels (inner bodies jit'd).

These handle shape padding (block divisibility), dtype plumbing, the
interpret-mode switch for CPU validation, strategy selection (``"swc"``
pipelined VPU, ``"swc_stream"`` explicit streaming, ``"tc"`` banded
matrix-unit contractions, plus the compiler-managed ``"hwc"`` baseline),
and ``"auto"`` block resolution through ``repro.tuning``, so callers
(fusion engine, physics, models) never touch BlockSpecs.

On CPU (this container) ``interpret`` defaults to True; on TPU it
defaults to False. Override explicitly for tests.

Block parameters accept ``"auto"``: the persistent tuning cache
(``repro.tuning``) is consulted, and on a miss with concrete operands
the paper's rank-then-measure protocol runs once and records the winner
(under tracing the structural cost-model winner is used instead).
"""
from __future__ import annotations

import functools
from typing import Callable

import jax
import jax.numpy as jnp

from repro.core.stencil import OperatorSet
from repro.kernels import ref as _ref
from repro.kernels.conv1d_depthwise import conv1d_depthwise_pallas
from repro.kernels.emit import fused_stencil_pallas
from repro.kernels.plan import StencilPlan, plan_stencil

# ops.py IS the sanctioned facade over the legacy kernels.
from repro.kernels.stencil1d import xcorr1d_pallas  # repolint: allow[legacy-kernel-import]


def _default_interpret() -> bool:
    return jax.default_backend() != "tpu"


def _round_up(n: int, m: int) -> int:
    return -(-n // m) * m


# The public xcorr1d is un-jitted (it resolves "auto" blocks eagerly);
# keep the hwc early-return compiled like it was when xcorr1d itself
# carried @jax.jit.
_xcorr1d_hwc_jit = jax.jit(_ref.xcorr1d)


def xcorr1d(
    f_padded: jnp.ndarray,
    g: jnp.ndarray,
    *,
    strategy: str = "baseline",
    block_size: int | str = 2048,
    unroll: int = 4,
    interpret: bool | None = None,
) -> jnp.ndarray:
    """1-D cross-correlation over the valid region (paper Eq. 3).

    Accepts any n; pads the tail to a block multiple and slices back.
    ``strategy='hwc'`` dispatches to the pure-jnp/XLA-managed path.
    ``block_size="auto"`` resolves through the tuning subsystem.
    """
    if interpret is None:
        interpret = _default_interpret()
    if strategy == "hwc":
        return _xcorr1d_hwc_jit(f_padded, g)
    if block_size == "auto":
        from repro.tuning.session import auto_block_xcorr1d

        block_size = auto_block_xcorr1d(
            f_padded, g, strategy=strategy, unroll=unroll,
            interpret=interpret,
        )
    return _xcorr1d_jit(
        f_padded, g, strategy=strategy, block_size=block_size,
        unroll=unroll, interpret=interpret,
    )


@functools.partial(
    jax.jit,
    static_argnames=("strategy", "block_size", "unroll", "interpret"),
)
def _xcorr1d_jit(
    f_padded: jnp.ndarray,
    g: jnp.ndarray,
    *,
    strategy: str,
    block_size: int,
    unroll: int,
    interpret: bool,
) -> jnp.ndarray:
    n_taps = g.shape[0]
    n = f_padded.shape[0] - (n_taps - 1)
    n_pad = _round_up(n, block_size)
    if n_pad != n:
        f_padded = jnp.concatenate(
            [f_padded, jnp.zeros((n_pad - n,), f_padded.dtype)]
        )
    out = xcorr1d_pallas(
        f_padded, g, strategy=strategy, block_size=block_size,
        unroll=unroll, interpret=interpret,
    )
    return out[:n]


def fused_stencil_nd(
    f_padded: jnp.ndarray,
    ops: OperatorSet,
    phi: Callable[..., jnp.ndarray],
    n_out: int,
    *,
    aux: jnp.ndarray | None = None,
    strategy: str = "swc",
    block: tuple[int, ...] | str | None = None,
    unroll: int = 1,
    fuse_steps: int = 1,
    interpret: bool | None = None,
) -> jnp.ndarray:
    """Fused φ(A·B) over a padded (n_f, *spatial) domain of rank 1-3
    (paper Eq. 9) — the thin dispatch over :class:`StencilPlan`.

    ``strategy``: 'hwc' (XLA-managed), 'swc' (Pallas pipelined blocks,
    any rank) or 'swc_stream' (Pallas explicit streaming of the slowest
    axis with carried halo planes + prefetch DMA, paper Fig. 5b —
    z-streaming at rank 3, y-streaming at rank 2). ``block`` is a
    rank-length tile (``None`` → per-rank default; longer tuples keep
    their trailing, x-last entries; non-divisible extents shrink the
    tile to the largest divisor) or ``"auto"``, which consults the
    persistent tuning cache (measuring on a miss when eager) — for
    every rank and strategy, through the same cache.

    ``fuse_steps`` is the temporal-fusion depth: ``f_padded`` must be
    padded by ``radius * fuse_steps`` (and ``aux``, if any, by
    ``radius * (fuse_steps - 1)``), the op is applied that many times
    inside one kernel, and ``phi`` may be a sequence of per-step
    callables. One call advances ``fuse_steps`` time steps. Depth > 1
    composes with both 'swc' (halo-widened pipelined blocks) and
    'swc_stream' (the carried halo widens to ``2·r·fuse_steps`` planes).

    A batched (ensemble) operand is detected by rank: ``f_padded`` of
    shape (batch, n_f, *spatial_padded) — i.e. ``ops.ndim + 2`` axes —
    lowers every strategy through one kernel that walks all members per
    block (member-major, shared halo window; 'hwc' uses the ``vmap``
    reference). ``aux`` then carries the same leading axis. Returns
    (batch, n_out, *interior).
    """
    if interpret is None:
        interpret = _default_interpret()
    batched = f_padded.ndim == ops.ndim + 2
    if strategy == "hwc":
        if batched:
            if fuse_steps == 1:
                return _ref.fused_stencil_batched(
                    f_padded, ops, phi, aux=aux
                )
            return _ref.fused_stencil_steps_batched(
                f_padded, ops, phi, fuse_steps, aux=aux
            )
        if fuse_steps == 1:
            return _ref.fused_stencil(f_padded, ops, phi, aux=aux)
        return _ref.fused_stencil_steps(
            f_padded, ops, phi, fuse_steps, aux=aux
        )
    if block == "auto":
        from repro.tuning.session import auto_block_nd

        block = auto_block_nd(
            f_padded, ops, phi, n_out, aux=aux, strategy=strategy,
            unroll=unroll, fuse_steps=fuse_steps, interpret=interpret,
        )
    plan = plan_for_nd(
        ops, f_padded.shape, n_out,
        aux_shape=None if aux is None else aux.shape,
        strategy=strategy, block=block, dtype=str(f_padded.dtype),
        unroll=unroll, fuse_steps=fuse_steps,
    )
    return fused_stencil_pallas(
        f_padded, ops, phi, plan, aux=aux, interpret=interpret
    )


def plan_for_nd(
    ops: OperatorSet,
    padded_shape: tuple[int, ...],
    n_out: int,
    *,
    aux_shape: tuple[int, ...] | None = None,
    strategy: str = "swc",
    block: tuple[int, ...] | None = None,
    dtype: str = "float32",
    unroll: int = 1,
    fuse_steps: int = 1,
) -> StencilPlan | None:
    """The :class:`StencilPlan` a :func:`fused_stencil_nd` call with
    these arguments lowers through — the ONE construction shared by the
    dispatch above and the static auditor (``repro.analysis``), so the
    audited plan can never diverge from the launched one. ``None`` for
    ``strategy="hwc"`` (no Pallas plan); ``block`` must be concrete
    (resolve ``"auto"`` through the tuning session first)."""
    if strategy == "hwc":
        return None
    if isinstance(block, str):
        raise ValueError(
            f"plan_for_nd needs a concrete block, got {block!r} — "
            "resolve 'auto' via repro.tuning first"
        )
    n_aux = 0
    if aux_shape is not None:
        batched = len(padded_shape) == ops.ndim + 2
        n_aux = aux_shape[1] if batched else aux_shape[0]
    return plan_stencil(
        ops, padded_shape, n_out, strategy=strategy, block=block,
        dtype=dtype, n_aux=n_aux, unroll=unroll,
        fuse_steps=fuse_steps,
    )


def fused_stencil3d(
    f_padded: jnp.ndarray,
    ops: OperatorSet,
    phi: Callable[..., jnp.ndarray],
    n_out: int,
    *,
    aux: jnp.ndarray | None = None,
    strategy: str = "swc",
    block: tuple[int, int, int] | str = (8, 8, 128),
    interpret: bool | None = None,
) -> jnp.ndarray:
    """Historical rank-3 entry point.

    .. deprecated::
        ``fused_stencil3d`` is deprecated; use :func:`fused_stencil_nd`
        (rank-generic, same keyword surface plus ``unroll`` and
        ``fuse_steps``).
    """
    import warnings

    warnings.warn(
        "fused_stencil3d is deprecated; use fused_stencil_nd",
        DeprecationWarning,
        stacklevel=2,
    )
    return fused_stencil_nd(
        f_padded, ops, phi, n_out, aux=aux, strategy=strategy,
        block=block, interpret=interpret,
    )


def conv1d_depthwise(
    x: jnp.ndarray,
    w: jnp.ndarray,
    *,
    activation: str = "none",
    block_seq: int | str | None = None,
    interpret: bool | None = None,
) -> jnp.ndarray:
    """Fused depthwise causal conv1d (+ SiLU) — mamba2 frontend stencil.

    ``block_seq=None`` (model call sites) uses 512 unless auto-tuning is
    globally enabled (``repro.tuning.enable_auto()`` — the train/serve
    drivers' ``--auto-tune``), in which case it resolves like ``"auto"``:
    persistent cache first, measured tune on an eager miss.
    """
    if interpret is None:
        interpret = _default_interpret()
    if block_seq is None:
        from repro.tuning.session import AUTO_ENABLED

        block_seq = "auto" if AUTO_ENABLED else 512
    if block_seq == "auto":
        from repro.tuning.session import auto_block_conv1d

        block_seq = auto_block_conv1d(
            x, w, activation=activation, interpret=interpret
        )
    return _conv1d_depthwise_jit(
        x, w, activation=activation, block_seq=block_seq,
        interpret=interpret,
    )


@functools.partial(
    jax.jit, static_argnames=("activation", "block_seq", "interpret")
)
def _conv1d_depthwise_jit(
    x: jnp.ndarray,
    w: jnp.ndarray,
    *,
    activation: str,
    block_seq: int,
    interpret: bool,
) -> jnp.ndarray:
    b, s, c = x.shape
    block_seq = min(block_seq, _round_up(s, 128))
    s_pad = _round_up(s, block_seq)
    if s_pad != s:
        x = jnp.pad(x, ((0, 0), (0, s_pad - s), (0, 0)))
    out = conv1d_depthwise_pallas(
        x, w, activation=activation, block_seq=block_seq,
        interpret=interpret,
    )
    return out[:, :s, :]
