"""Pure-jnp oracles for every Pallas kernel in this package.

These are the ground truth for the per-kernel allclose sweeps in
``tests/test_kernels.py`` and double as the HWC ("hardware/XLA-managed
caching") strategy of the fusion engine: plain jnp code whose on-chip
residency is decided entirely by the compiler — the TPU analogue of the
paper's L1/L2-managed implementations.
"""
from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.stencil import OperatorSet


def xcorr1d(f_padded: jnp.ndarray, g: jnp.ndarray) -> jnp.ndarray:
    """1-D discrete cross-correlation, paper Eq. 3.

    ``f_padded`` has shape (n + 2r,); ``g`` has shape (2r + 1,).
    Returns (n,): f'_i = Σ_j g_j · f̂_{i+j}.
    """
    n = f_padded.shape[0] - (g.shape[0] - 1)
    acc = jnp.zeros((n,), dtype=f_padded.dtype)
    for k in range(g.shape[0]):
        acc = acc + g[k].astype(f_padded.dtype) * jnp.asarray(f_padded[k : k + n])
    return acc


def apply_operator_set(
    f_padded: jnp.ndarray, ops: OperatorSet
) -> dict[str, jnp.ndarray]:
    """Evaluate every operator of ``ops`` over a padded multi-field array.

    ``f_padded``: (n_f, *spatial_padded) where each spatial axis is padded
    by the per-axis radius of the set. Returns {op_name: (n_f, *spatial)}.
    Shifted-slice multiply-accumulate with static offsets — XLA fuses the
    whole tap set into one loop (this IS the hardware-managed-cache path).
    """
    rad = ops.radius_per_axis()
    spatial = tuple(
        f_padded.shape[1 + a] - 2 * rad[a] for a in range(ops.ndim)
    )
    out: dict[str, jnp.ndarray] = {}
    for spec in ops.ops:
        acc = jnp.zeros((f_padded.shape[0],) + spatial, dtype=f_padded.dtype)
        for off, c in zip(spec.offsets, spec.coeffs):
            sl = tuple(
                slice(rad[a] + off[a], rad[a] + off[a] + spatial[a])
                for a in range(ops.ndim)
            )
            acc = acc + jnp.asarray(c, dtype=f_padded.dtype) * f_padded[(slice(None),) + sl]
        out[spec.name] = acc
    return out


def fused_stencil(
    f_padded: jnp.ndarray,
    ops: OperatorSet,
    phi: Callable[..., jnp.ndarray],
    aux: jnp.ndarray | None = None,
) -> jnp.ndarray:
    """The paper's fused φ(A·B) evaluation (Eq. 9), reference form.

    Computes all linear operators (Q = A·B at every point) then the
    nonlinear point-wise map φ. ``phi`` maps {op_name: (n_f, *spatial)} to
    (n_out, *spatial). ``aux`` (n_aux, *spatial), if given, provides extra
    point-wise inputs (e.g. the RK3 carry) passed as phi's second arg.
    """
    derivs = apply_operator_set(f_padded, ops)
    if aux is None:
        return phi(derivs)
    return phi(derivs, aux)


def fused_stencil_steps(
    f_padded: jnp.ndarray,
    ops: OperatorSet,
    phi,
    n_steps: int,
    aux: jnp.ndarray | None = None,
) -> jnp.ndarray:
    """Sequential reference for temporal fusion: apply the fused op
    ``n_steps`` times, shrinking the valid region by one radius per
    application — the oracle BOTH depth-fused Pallas kernels (the
    halo-widened pipelined ``swc`` kernel and the carried-halo
    ``swc_stream`` streaming kernel) must match bit-for-tolerance.

    ``f_padded`` is padded by ``radius * n_steps`` per axis; ``aux`` (if
    given) by ``radius * (n_steps - 1)``. ``phi`` is one callable (same
    map every step) or a sequence of ``n_steps`` callables (e.g. RK
    substeps with different coefficients). Steps before the last must be
    self-maps — rows 0..n_f of the output feed the next step's field
    stack, the following n_aux rows the next carry. Returns
    (n_out, *interior).
    """
    phis = (
        tuple(phi) if isinstance(phi, (tuple, list)) else (phi,) * n_steps
    )
    if len(phis) != n_steps:
        raise ValueError(
            f"got {len(phis)} phi callables for {n_steps} fused steps"
        )
    rad = ops.radius_per_axis()
    n_f = f_padded.shape[0]
    cur, cur_aux = f_padded, aux
    for s, phi_s in enumerate(phis):
        out = fused_stencil(cur, ops, phi_s, aux=cur_aux)
        if s == n_steps - 1:
            return out
        cur = out[:n_f]
        if cur_aux is not None:
            n_aux = cur_aux.shape[0]
            carry = out[n_f : n_f + n_aux]
            cur_aux = carry[
                (slice(None),)
                + tuple(
                    slice(r, carry.shape[1 + a] - r) if r else slice(None)
                    for a, r in enumerate(rad)
                )
            ]
    return out  # unreachable (n_steps >= 1); keeps type checkers happy


def fused_stencil_batched(
    f_padded: jnp.ndarray,
    ops: OperatorSet,
    phi: Callable[..., jnp.ndarray],
    aux: jnp.ndarray | None = None,
) -> jnp.ndarray:
    """Batched (ensemble) oracle: ``vmap`` of :func:`fused_stencil`
    over a leading member axis.

    ``f_padded``: (batch, n_f, *spatial_padded); ``aux`` (if given):
    (batch, n_aux, *spatial). Returns (batch, n_out, *interior). This
    is the ground truth every batched Pallas lowering must match —
    member m of the batched kernel output is bit-tolerance-identical to
    the single-member path applied to member m alone.
    """
    if aux is None:
        return jax.vmap(lambda f: fused_stencil(f, ops, phi))(f_padded)
    return jax.vmap(
        lambda f, a: fused_stencil(f, ops, phi, aux=a)
    )(f_padded, aux)


def fused_stencil_steps_batched(
    f_padded: jnp.ndarray,
    ops: OperatorSet,
    phi,
    n_steps: int,
    aux: jnp.ndarray | None = None,
) -> jnp.ndarray:
    """Batched sequential reference for temporal fusion: ``vmap`` of
    :func:`fused_stencil_steps` over a leading member axis (see
    :func:`fused_stencil_batched` for the operand convention)."""
    if aux is None:
        return jax.vmap(
            lambda f: fused_stencil_steps(f, ops, phi, n_steps)
        )(f_padded)
    return jax.vmap(
        lambda f, a: fused_stencil_steps(f, ops, phi, n_steps, aux=a)
    )(f_padded, aux)


def conv1d_depthwise_causal(x: jnp.ndarray, w: jnp.ndarray) -> jnp.ndarray:
    """Depthwise causal 1-D convolution (mamba2 frontend stencil).

    ``x``: (batch, seq, channels); ``w``: (k, channels). Output (b, s, c):
    y[b, t, c] = Σ_{j<k} w[j, c] · x[b, t - (k-1) + j, c], zero-padded left.
    """
    k = w.shape[0]
    xp = jnp.pad(x, ((0, 0), (k - 1, 0), (0, 0)))
    seq = x.shape[1]
    acc = jnp.zeros_like(x)
    for j in range(k):
        acc = acc + w[j][None, None, :].astype(x.dtype) * xp[:, j : j + seq, :]
    return acc


def xcorr1d_numpy(f_padded: np.ndarray, g: np.ndarray) -> np.ndarray:
    """Float64 numpy oracle-of-the-oracle (used by property tests)."""
    f_padded = np.asarray(f_padded, dtype=np.float64)
    g = np.asarray(g, dtype=np.float64)
    n = f_padded.shape[0] - (g.shape[0] - 1)
    out = np.zeros(n)
    for k in range(g.shape[0]):
        out += g[k] * f_padded[k : k + n]
    return out
