"""1-D cross-correlation Pallas TPU kernel (paper Sec. 4.1, Figs. 8-9).

Reproduces the paper's hand-tuned CUDA/HIP baseline on the TPU target,
including its three tuning strategies:

* ``baseline``     — each grid step computes one output block; the
  multiply-accumulate loop over stencil points runs one tap per iteration.
* ``pointwise``    — *stencil point-wise unrolling*: the tap loop is
  unrolled by a static factor, deepening the instruction pipeline
  (paper: ``#pragma unroll`` over the MAC loop).
* ``elementwise``  — *element-wise unrolling*: each grid step computes
  ``unroll`` adjacent output sub-blocks from one (shared) tap coefficient
  load, raising ILP per coefficient fetch (paper: 4 outputs per thread).

TPU adaptation (DESIGN.md §2): the thread block becomes a VMEM output
block; the coefficient vector ``g`` lives wholly in VMEM (the constant-
memory analogue); overlapping input windows (block + 2r halo) are
expressed with ``pl.Element`` block dims and double-buffered HBM→VMEM by
the Pallas pipeline — the hardware equivalent of the paper's
shared-memory staging with prefetch.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.compat import element_window_spec

STRATEGIES = ("baseline", "pointwise", "elementwise")


def _mac_loop(f_blk_ref, g_ref, n_taps: int, block: int, unroll: int,
              dtype) -> jnp.ndarray:
    """Tap loop with static unroll factor; taps beyond ``n_taps`` were
    zero-padded by the wrapper so the unrolled tail is safe."""
    n_iters = -(-n_taps // unroll)

    def body(it, acc):
        for u in range(unroll):  # static: unrolled at trace time
            k = it * unroll + u
            coeff = g_ref[k]
            acc = acc + coeff * f_blk_ref[pl.ds(k, block)]
        return acc

    acc0 = jnp.zeros((block,), dtype=dtype)
    return jax.lax.fori_loop(0, n_iters, body, acc0)


def _kernel_baseline(f_ref, g_ref, o_ref, *, n_taps, block, unroll):
    o_ref[...] = _mac_loop(f_ref, g_ref, n_taps, block, unroll, o_ref.dtype)


def _kernel_elementwise(f_ref, g_ref, o_ref, *, n_taps, block, unroll):
    """``unroll`` accumulators advance together through the tap loop,
    reusing each coefficient load (ILP across output sub-blocks)."""

    def body(k, accs):
        coeff = g_ref[k]
        return tuple(
            accs[e] + coeff * f_ref[pl.ds(k + e * block, block)]
            for e in range(unroll)
        )

    accs0 = tuple(jnp.zeros((block,), dtype=o_ref.dtype) for _ in range(unroll))
    accs = jax.lax.fori_loop(0, n_taps, body, accs0)
    for e in range(unroll):
        o_ref[pl.ds(e * block, block)] = accs[e]


def xcorr1d_pallas(
    f_padded: jnp.ndarray,
    g: jnp.ndarray,
    *,
    strategy: str = "baseline",
    block_size: int = 2048,
    unroll: int = 4,
    interpret: bool = False,
) -> jnp.ndarray:
    """f'_i = Σ_j g_j f̂_{i+j} over the valid region of ``f_padded``.

    ``f_padded``: (n + 2r,); ``g``: (2r + 1,). Requires ``block_size`` | n
    (the public wrapper in ``ops.py`` handles padding/slicing).
    """
    if strategy not in STRATEGIES:
        raise ValueError(f"strategy {strategy!r} not in {STRATEGIES}")
    n_taps = g.shape[0]
    n = f_padded.shape[0] - (n_taps - 1)
    halo = n_taps - 1

    if strategy == "elementwise":
        if (block_size % unroll) != 0:
            raise ValueError("block_size must divide by unroll for elementwise")
        sub = block_size // unroll
        kernel = functools.partial(
            _kernel_elementwise, n_taps=n_taps, block=sub, unroll=unroll
        )
        g_taps = n_taps
    else:
        u = unroll if strategy == "pointwise" else 1
        # Zero-pad taps to a multiple of the unroll factor so the unrolled
        # tail reads real memory (wrapper extended the halo to match).
        pad_taps = (-n_taps) % u
        if pad_taps:
            g = jnp.concatenate([g, jnp.zeros((pad_taps,), g.dtype)])
            halo = halo + pad_taps
            f_padded = jnp.concatenate(
                [f_padded, jnp.zeros((pad_taps,), f_padded.dtype)]
            )
        kernel = functools.partial(
            _kernel_baseline, n_taps=n_taps + pad_taps, block=block_size,
            unroll=u,
        )
        g_taps = n_taps + pad_taps

    if n % block_size:
        raise ValueError(f"block_size {block_size} must divide n {n}")
    grid = (n // block_size,)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            element_window_spec(
                (block_size + halo,),
                lambda i: (i * block_size,),
                window_dims=(0,),
            ),
            pl.BlockSpec((g_taps,), lambda i: (0,)),  # g: whole, VMEM
        ],
        out_specs=pl.BlockSpec((block_size,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((n,), f_padded.dtype),
        interpret=interpret,
    )(f_padded, g.astype(f_padded.dtype))
