"""Depthwise causal conv1d Pallas kernel — the paper's stencil-fusion
technique applied to an assigned architecture (mamba2's conv frontend).

A depthwise causal convolution with k taps is a radius-(k-1) one-sided
1-D stencil per channel (DESIGN.md §4: the *direct* applicability case).
The fusion opportunity is the same as the paper's φ(A·B): the conv (linear
stencil) and the SiLU gate (nonlinear point-wise φ) execute in one kernel
so the conv output never round-trips HBM.

Layout: (batch·seq, channels) blocks with channels on the 128-lane axis;
the sequence halo (k-1 steps) is expressed with ``pl.Element`` overlap,
and batch boundaries are handled by the wrapper's zero padding between
sequences (per-sequence left padding).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.compat import element_window_spec


def _kernel(x_ref, w_ref, o_ref, *, k, block_s, activation):
    acc = None
    for j in range(k):  # static unroll: k is 4 for mamba2
        term = w_ref[j, :][None, :] * x_ref[pl.ds(j, block_s), :]
        acc = term if acc is None else acc + term
    if activation == "silu":
        acc = acc * jax.nn.sigmoid(acc)
    o_ref[...] = acc


def conv1d_depthwise_pallas(
    x: jnp.ndarray,
    w: jnp.ndarray,
    *,
    activation: str = "none",
    block_seq: int = 512,
    interpret: bool = False,
) -> jnp.ndarray:
    """Fused causal depthwise conv (+ optional SiLU).

    ``x``: (batch, seq, channels); ``w``: (k, channels). The wrapper in
    ``ops.py`` pads ``seq`` to a multiple of ``block_seq``.
    """
    b, s, c = x.shape
    k = w.shape[0]
    if s % block_seq:
        raise ValueError(f"seq {s} not divisible by block_seq {block_seq}")
    # Causal left-pad each sequence independently, then flatten batch.
    xp = jnp.pad(x, ((0, 0), (k - 1, 0), (0, 0)))  # (b, s + k - 1, c)
    kernel = functools.partial(
        _kernel, k=k, block_s=block_seq, activation=activation
    )
    out = pl.pallas_call(
        kernel,
        grid=(b, s // block_seq),
        in_specs=[
            element_window_spec(
                (None, block_seq + k - 1, c),
                lambda ib, is_: (ib, is_ * block_seq, 0),
                window_dims=(1,),
            ),
            pl.BlockSpec((k, c), lambda ib, is_: (0, 0)),
        ],
        out_specs=pl.BlockSpec(
            (None, block_seq, c), lambda ib, is_: (ib, is_, 0)
        ),
        out_shape=jax.ShapeDtypeStruct((b, s, c), x.dtype),
        interpret=interpret,
    )(xp, w.astype(x.dtype))
    return out
