"""Pallas API compatibility across jax versions.

The kernels express overlapping input windows (block + halo) with
per-element block offsets. Newer jax spells this ``pl.Element`` per
dimension; jax <= 0.4.x spells it ``indexing_mode=pl.Unblocked()`` for
the whole spec. Both semantics are identical for our specs because the
non-window dimensions always use offset 0 (full extent) or a squeezed
``None`` dim, where block index == element offset.
"""
from __future__ import annotations

from typing import Callable, Sequence

from jax.experimental import pallas as pl

HAS_ELEMENT = hasattr(pl, "Element")


def element_window_spec(
    block_shape: Sequence[int | None],
    index_map: Callable[..., tuple],
    window_dims: Sequence[int],
) -> pl.BlockSpec:
    """BlockSpec whose ``window_dims`` take *element* offsets from the
    index map (overlapping halo windows); remaining dims span the full
    extent (or are squeezed with ``None``)."""
    if HAS_ELEMENT:
        shape = tuple(
            pl.Element(s) if d in window_dims and s is not None else s
            for d, s in enumerate(block_shape)
        )
        return pl.BlockSpec(shape, index_map)
    return pl.BlockSpec(
        tuple(block_shape), index_map, indexing_mode=pl.Unblocked()
    )
