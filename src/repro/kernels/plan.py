"""StencilPlan — the explicit lowering contract between the fusion
engine, the rank-generic Pallas emitters, and the tuning subsystem.

A plan captures everything the emitter needs to lower one fused
φ(A·B) application — rank, caching strategy, block (tile) shape,
element-wise unroll factor, halo radii, field/output/aux counts and
dtype — and everything the tuning cache needs to key a record. The
pipeline is

    plan_stencil(...)  →  StencilPlan  →  emit.fused_stencil_pallas
         (planner)        (lowering IR)         (emitter)

with ``repro.tuning`` keying its persistent cache on the plan's
serialized identity (``StencilPlan.tuning_key()``), so ``block="auto"``
resolves through one cache for 1-D, 2-D and 3-D domains alike.

Array-axis convention (matches ``repro.core.stencil``): spatial axes
are ordered slowest→fastest, x always last (the TPU lane dimension);
blocks follow the same order, e.g. (τz, τy, τx) at rank 3.
"""
from __future__ import annotations

import dataclasses
from typing import TYPE_CHECKING, Sequence

from repro.core.stencil import OperatorSet, StencilSpec

if TYPE_CHECKING:
    from repro.tuning.cache import TuningKey, TuningRecord

STRATEGIES = ("swc", "swc_stream", "tc")

# The tc (matrix-unit) regime contracts each axis of the φ derivative
# sequence against a banded coefficient matrix of shape
# (tile + 2·halo, tile): its MXU work grows with the tile extent, not
# the tap count, so tiles are capped — a (8198, 8192) rank-1 band would
# be a quarter-gigabyte constant doing 16k FLOPs/point.
TC_MAX_TILE = 512

# Spatial-axis letters in array order (slowest→fastest, x last). The
# stream axis of an ``swc_stream`` plan is always axis 0 — z at rank 3,
# y at rank 2 — and its letter joins the strategy id / tuning key.
AXIS_LETTERS: dict[int, tuple[str, ...]] = {
    1: ("x",),
    2: ("y", "x"),
    3: ("z", "y", "x"),
}

# Per-rank default tiles: x spans the lane dimension (long 1-D blocks
# amortize per-grid-step pipeline overhead), y/z follow the paper's
# TPU-friendly bases.
DEFAULT_BLOCKS: dict[int, tuple[int, ...]] = {
    1: (2048,),
    2: (16, 128),
    3: (8, 8, 128),
}


def largest_divisor_leq(n: int, cap: int) -> int:
    """Largest divisor of ``n`` that is ≤ ``cap`` (≥ 1)."""
    for t in range(min(cap, n), 0, -1):
        if n % t == 0:
            return t
    return 1


def tc_axis_groups(
    spec: StencilSpec, rank: int
) -> dict[tuple[int, tuple[int, ...]], list[tuple[int, float]]]:
    """Decompose one stencil's taps into per-axis contraction groups —
    the lowering contract of the ``tc`` (matrix-unit) regime.

    Each tap is assigned a contraction axis: the LAST nonzero axis of
    its offset (x for the center tap), so every arm of a star stencil
    becomes one dense 1-D contraction along its own axis, and a mixed
    partial like ∂xy falls apart into one x-contraction per y-offset.
    The group key is ``(axis, rest)`` where ``rest`` is the offset with
    the contraction-axis component zeroed; the value lists
    ``(offset_along_axis, coeff)`` taps. Multi-tap groups lower to a
    banded-matrix `dot_general` on the MXU; singleton groups stay
    scalar slice-multiplies on the VPU (a matmul per lone tap would be
    all overhead).
    """
    groups: dict[
        tuple[int, tuple[int, ...]], list[tuple[int, float]]
    ] = {}
    for off, c in zip(spec.offsets, spec.coeffs):
        nonzero = [a for a in range(rank) if off[a] != 0]
        axis = nonzero[-1] if nonzero else rank - 1
        rest = tuple(0 if a == axis else off[a] for a in range(rank))
        groups.setdefault((axis, rest), []).append(
            (int(off[axis]), float(c))
        )
    return groups


def tc_groups_per_axis(ops: OperatorSet) -> tuple[int, ...]:
    """Number of multi-tap (i.e. matmul-lowered) contraction groups per
    axis across an operator set — the ``tc`` compute model's input (its
    MXU FLOPs scale with groups × tile extent, not tap count)."""
    counts = [0] * ops.ndim
    for spec in ops.ops:
        for (axis, _), taps in tc_axis_groups(spec, ops.ndim).items():
            if len(taps) > 1:
                counts[axis] += 1
    return tuple(counts)


# The paper's fixed operator order: accuracy-6 plans key UNMARKED (the
# legacy strategy-id form), so every pre-existing cache record, warm
# entry and golden id stays valid; any other generated order joins the
# key as an explicit ``:o{A}`` suffix. 0 means "unknown" (hand-built
# taps without OperatorSpec metadata) and also keys unmarked.
DEFAULT_ACCURACY = 6


def strategy_sid(
    strategy: str,
    rank: int,
    unroll: int = 1,
    fuse_steps: int | str = 1,
    batch: int = 1,
    accuracy: int = 0,
    n_aux: int = 0,
) -> str:
    """Canonical strategy-id derivation — the ONE place the stream
    axis, unroll factor, temporal depth, ensemble batch extent and
    operator accuracy order join the cache key.

    Used by both :attr:`StencilPlan.strategy_id` and the tuning layer's
    key mirror (``repro.tuning.session.fused_nd_key``), so the two can
    never silently derive different cache ids. ``fuse_steps`` may be
    the string ``"auto"`` (the joint block/depth search's ``:fauto``
    suffix). ``strategy`` may be ``"auto"`` (the cross-strategy search,
    which also owns the stream-axis decision — keyed ``:sauto``, so an
    auto record never collides with a per-strategy one). ``batch > 1``
    appends ``:b{B}`` — a block tuned for a B-member ensemble launch is
    never replayed for a single-member one (the VMEM working set and
    amortized traffic both change with B).

    ``"tc"`` (the matrix-unit regime) needs no extra marker of its own:
    the bare strategy name distinguishes it, and the generic suffixes
    compose — a fused batched MXU plan keys as ``tc:f{S}:b{B}``, which
    can never collide with any ``swc``-family id.

    ``accuracy`` is the operator set's finite-difference order: any
    order other than the paper default (:data:`DEFAULT_ACCURACY` = 6)
    appends ``:o{A}``, so plans for the same domain at different
    generated orders cache separately (``:o4`` never replays an
    order-6 winner — the tap count, halo radii and compute/traffic
    balance all change with the order). Order 6 and 0 ("unknown",
    hand-built taps) key unmarked — the legacy id form, which keeps
    every pre-existing record and golden key valid; distinct orders
    still never collide because the per-axis radii (``accuracy/2``)
    are part of every tuning key. The auditor
    (``repro.analysis.keys``) proves this accuracy alias is the ONE
    collision class the whole suffix grammar admits.

    ``n_aux > 0`` appends ``:a{N}``: aux operands join the staged
    working set (an extra halo-free — or, fused, ``r·(S-1)``-widened —
    block per grid step), so a block tuned without the aux residency
    must never be replayed for a call that carries it. Aux-free plans
    key unmarked — the legacy form every pre-existing record uses.
    """
    sid = strategy
    if strategy == "swc_stream":
        sid += f":s{AXIS_LETTERS[rank][0]}"
    elif strategy == "auto":
        sid += ":sauto"
    if unroll != 1:
        sid += f":u{unroll}"
    if fuse_steps == "auto":
        sid += ":fauto"
    elif fuse_steps != 1:
        sid += f":f{fuse_steps}"
    if batch != 1:
        sid += f":b{batch}"
    if n_aux:
        sid += f":a{n_aux}"
    if accuracy not in (0, DEFAULT_ACCURACY):
        sid += f":o{accuracy}"
    return sid


@dataclasses.dataclass(frozen=True)
class StencilPlan:
    """One lowered fused-stencil configuration (see module docstring).

    ``block`` is the per-grid-step tile; at rank 1 the emitter computes
    ``unroll`` adjacent x sub-tiles per grid step from one staged input
    window (the paper's element-wise unrolling, generalized), so the
    effective x extent per step is ``block[-1] * unroll``.

    ``fuse_steps`` is the temporal-fusion depth: the fused op is applied
    that many times inside ONE kernel invocation on a VMEM-resident
    block whose staged halo is widened to ``radii * fuse_steps`` — the
    valid region shrinks by one radius per sweep and intermediate steps
    never touch HBM (classic temporal blocking: redundant halo compute
    traded for memory traffic). Depth > 1 requires the op to be a
    self-map, ``n_out == n_f + n_aux``, so each sweep's output provides
    the next sweep's field stack (rows 0..n_f) and carry (the rest).

    ``strategy="swc_stream"`` (ranks 2/3) streams the slowest spatial
    axis (:attr:`stream_axis`) with carried halo planes instead of
    tiling it in the Pallas grid; it composes with ``fuse_steps`` but
    rejects aux inputs and element-wise unrolling.

    ``strategy="tc"`` (ranks 1–3) keeps the pipelined ``swc`` staging
    but lowers each axis of the derivative evaluation to a banded
    coefficient-matrix contraction placed on the MXU (f32 accumulate);
    it composes with ``fuse_steps``, ``batch`` and aux inputs, requires
    dtype float32/bfloat16 and ``unroll=1``, and caps tiles at
    ``TC_MAX_TILE`` per axis (see :func:`tc_axis_groups`).

    Raises:
        ValueError: from ``__post_init__`` for any inconsistent
            combination — unknown strategy, rank/strategy mismatch,
            tuple lengths not matching the rank, non-divisible tiles,
            or unmet temporal-fusion prerequisites.

    Example (build through the planner, not the constructor)::

        >>> from repro.core.stencil import derivative_operator_set
        >>> from repro.kernels.plan import plan_stencil
        >>> ops = derivative_operator_set(2, 6, spacing=0.5)
        >>> plan = plan_stencil(ops, (1, 262, 262), 1,
        ...                     strategy="swc_stream")
        >>> plan.block, plan.strategy_id
        ((16, 128), 'swc_stream:sy')
    """

    rank: int
    strategy: str  # "swc" | "swc_stream" | "tc"
    block: tuple[int, ...]  # rank-length tile, x last
    radii: tuple[int, ...]  # halo width per axis
    interior: tuple[int, ...]  # unpadded spatial extents
    n_f: int
    n_out: int
    dtype: str
    n_aux: int = 0
    unroll: int = 1  # element-wise unroll along x
    fuse_steps: int = 1  # temporal fusion depth (in-kernel time steps)
    # Ensemble batch extent: the kernel walks `batch` independent members
    # per block (member-major along the leading field axis), sharing one
    # halo window/prologue per launch step. batch > 1 joins strategy_id
    # as :b{B} so batched records key separately.
    batch: int = 1
    # Finite-difference accuracy order of the operator set this plan
    # lowers (0 = unknown/hand-built taps). Derived by plan_stencil from
    # the OperatorSpec metadata the weight generator attaches; joins
    # strategy_id as :o{A} for non-default orders (see strategy_sid).
    accuracy: int = 0

    def __post_init__(self) -> None:
        if self.accuracy < 0 or self.accuracy % 2:
            raise ValueError(
                "accuracy must be 0 (unknown) or a positive even "
                f"finite-difference order, got {self.accuracy}"
            )
        if self.rank not in (1, 2, 3):
            raise ValueError(f"rank must be 1, 2 or 3, got {self.rank}")
        if self.batch < 1:
            raise ValueError(f"batch must be >= 1, got {self.batch}")
        if self.strategy not in STRATEGIES:
            raise ValueError(
                f"strategy {self.strategy!r} not in {STRATEGIES}"
            )
        if self.strategy == "swc_stream" and self.rank == 1:
            raise ValueError(
                "swc_stream (explicit streaming, paper Fig. 5b) streams "
                "the slowest spatial axis while the lane tile stays "
                "fixed — it requires rank 2 (y-stream) or 3 (z-stream); "
                "at rank 1 use strategy='swc'"
            )
        if self.strategy == "swc_stream" and self.n_aux:
            raise ValueError("aux inputs: use strategy='swc'")
        if self.strategy == "tc" and self.dtype not in (
            "float32", "bfloat16",
        ):
            raise ValueError(
                "strategy='tc' lowers the φ derivative sequence to MXU "
                "matmuls with float32 accumulation — dtype must be "
                "'float32' or 'bfloat16' (bf16 inputs, f32 accumulate); "
                f"got {self.dtype!r}. For float64 fields use "
                "strategy='swc' (VPU) or 'hwc'."
            )
        if self.strategy == "tc" and self.unroll != 1:
            raise ValueError(
                "tc lowers each axis to one banded contraction per "
                "block — element-wise unrolling does not compose; use "
                "unroll=1 with strategy='tc'"
            )
        for name, t in (
            ("block", self.block),
            ("radii", self.radii),
            ("interior", self.interior),
        ):
            if len(t) != self.rank:
                raise ValueError(
                    f"{name} {t} must have rank {self.rank} entries"
                )
        if self.unroll < 1:
            raise ValueError(f"unroll must be >= 1, got {self.unroll}")
        if self.strategy == "swc_stream" and self.unroll != 1:
            raise ValueError("swc_stream does not support unroll > 1")
        if self.fuse_steps < 1:
            raise ValueError(
                f"fuse_steps must be >= 1, got {self.fuse_steps}"
            )
        if self.batch > 1 and self.n_aux and self.fuse_steps > 1:
            raise ValueError(
                "batched temporal fusion with aux carries is not "
                "supported: the member-major output interleaves field "
                "and carry rows between sweeps — use batch=1 or "
                "fuse_steps=1 with aux inputs"
            )
        if self.fuse_steps > 1:
            if self.unroll != 1:
                raise ValueError(
                    "temporal fusion composes with the staged halo "
                    "window, not element-wise unrolling — use unroll=1 "
                    "with fuse_steps > 1"
                )
            if self.n_out != self.n_f + self.n_aux:
                raise ValueError(
                    "fuse_steps > 1 requires a self-map op with "
                    f"n_out == n_f + n_aux (got n_out={self.n_out}, "
                    f"n_f={self.n_f}, n_aux={self.n_aux}) so each "
                    "in-kernel sweep can feed the next"
                )
            if self.strategy == "swc_stream":
                carried = 2 * self.radii[0] * self.fuse_steps
                if self.interior[0] < carried + self.block[0]:
                    raise ValueError(
                        "swc_stream with temporal fusion walks the "
                        "stream axis carrying 2·r·fuse_steps halo "
                        f"planes ({carried} here), so the stream-axis "
                        f"extent must hold that carried halo plus one "
                        f"chunk (block[0]={self.block[0]}); got extent "
                        f"{self.interior[0]} < {carried + self.block[0]}"
                        " — shrink fuse_steps/block[0], grow the "
                        "domain, or use strategy='swc'"
                    )
        step = self.x_step
        for a in range(self.rank):
            t = self.block[a] if a < self.rank - 1 else step
            if self.interior[a] % t:
                raise ValueError(
                    f"axis {a} extent {self.interior[a]} not divisible "
                    f"by tile {t}"
                )

    @property
    def x_step(self) -> int:
        """Output extent covered along x per grid step."""
        return self.block[-1] * self.unroll

    @property
    def stream_axis(self) -> int | None:
        """Array axis the explicit-streaming kernel walks, or None.

        ``swc_stream`` plans always stream the slowest spatial axis
        (axis 0): z at rank 3, y at rank 2 — the cross-stream tile stays
        resident while halo planes are carried chunk to chunk.
        """
        return 0 if self.strategy == "swc_stream" else None

    @property
    def stream_axis_letter(self) -> str | None:
        """Letter of :attr:`stream_axis` ("z"/"y"), or None for
        non-streaming plans; recorded in :attr:`strategy_id`."""
        if self.stream_axis is None:
            return None
        return AXIS_LETTERS[self.rank][self.stream_axis]

    @property
    def halo(self) -> tuple[int, ...]:
        """Staged halo width per axis: one radius per fused sweep."""
        return tuple(r * self.fuse_steps for r in self.radii)

    @property
    def grid(self) -> tuple[int, ...]:
        """Grid extents in axis order (the emitter may reorder for
        streaming; at rank 3 the z axis iterates innermost)."""
        steps = self.block[:-1] + (self.x_step,)
        return tuple(n // t for n, t in zip(self.interior, steps))

    # -- serialization (the tuning layer keys on this) ----------------------

    @property
    def kernel_name(self) -> str:
        """Kernel family component of the cache key (rank-specific)."""
        return f"fused_stencil{self.rank}d"

    @property
    def strategy_id(self) -> str:
        """Strategy component of the cache key; the stream axis, unroll,
        temporal fusion depth and batch extent are codegen
        configuration, so they join the key (via :func:`strategy_sid`)
        — depth-1 and depth-2 plans cache separately, a y-streaming
        rank-2 plan (``swc_stream:sy``) never collides with a pipelined
        one, a B-member ensemble plan keys as ``:b{B}``, an aux-
        carrying plan as ``:a{N}``, and a non-default operator order as
        ``:o{A}``."""
        return strategy_sid(
            self.strategy, self.rank, self.unroll, self.fuse_steps,
            self.batch, self.accuracy, self.n_aux,
        )

    def tuning_key(self, backend: str | None = None) -> TuningKey:
        """The persistent-cache key for this plan's problem identity
        (block excluded — the block IS the tuned value)."""
        from repro.tuning.cache import TuningKey, current_backend

        return TuningKey(
            kernel=self.kernel_name,
            strategy=self.strategy_id,
            domain=self.interior,
            radii=self.radii,
            n_f=self.n_f,
            n_out=self.n_out,
            dtype=self.dtype,
            backend=backend if backend is not None else current_backend(),
        )


def plan_stencil(
    ops: OperatorSet,
    padded_shape: Sequence[int],
    n_out: int,
    *,
    strategy: str = "swc",
    block: Sequence[int] | int | None = None,
    dtype: str = "float32",
    n_aux: int = 0,
    unroll: int = 1,
    fuse_steps: int = 1,
    batch: int | None = None,
    accuracy: int | None = None,
) -> StencilPlan:
    """Lower a fused-stencil problem to a :class:`StencilPlan`.

    ``padded_shape`` is the (n_f, *spatial_padded) operand shape (spatial
    axes padded by ``ops.radius_per_axis() * fuse_steps`` — temporal
    fusion consumes one radius of ghost cells per in-kernel sweep), or
    the batched (batch, n_f, *spatial_padded) shape of an ensemble
    operand — a leading extent beyond rank+1 axes is read as the batch.
    An explicit ``batch`` kwarg must agree with a batched shape (and
    turns a rank+1 shape into a plan for a B-member launch).
    ``block`` may be ``None`` (per-rank default), an int (rank-1
    shorthand), or a tuple; a tuple longer than the rank keeps its
    trailing entries (x-last convention, so a 3-D default like
    (8, 8, 128) lowers to (8, 128) at rank 2), and each axis is clamped
    to the largest divisor of the interior extent — non-block-divisible
    domains shrink the tile instead of failing.
    ``accuracy`` defaults to the operator set's own finite-difference
    order (the OperatorSpec metadata attached by the weight generator;
    0 for hand-built tap sets), keying the plan per order.
    """
    rank = ops.ndim
    if accuracy is None:
        accuracy = getattr(ops, "accuracy", 0)
    radii = ops.radius_per_axis()
    if fuse_steps < 1:
        raise ValueError(f"fuse_steps must be >= 1, got {fuse_steps}")
    padded_shape = tuple(padded_shape)
    if len(padded_shape) == rank + 2:
        shape_batch = int(padded_shape[0])
        if batch is not None and int(batch) != shape_batch:
            raise ValueError(
                f"explicit batch={batch} disagrees with the batched "
                f"operand shape {padded_shape} (leading extent "
                f"{shape_batch})"
            )
        batch = shape_batch
        padded_shape = padded_shape[1:]
    elif batch is None:
        batch = 1
    if len(padded_shape) != rank + 1:
        raise ValueError(
            f"padded operand must be (n_f, *spatial) or "
            f"(batch, n_f, *spatial) with {rank} spatial dims, got "
            f"shape {tuple(padded_shape)}"
        )
    interior = tuple(
        padded_shape[1 + a] - 2 * radii[a] * fuse_steps
        for a in range(rank)
    )
    if any(n <= 0 for n in interior):
        raise ValueError(
            f"padded shape {tuple(padded_shape)} leaves no interior for "
            f"radii {radii} at fuse_steps={fuse_steps}"
        )

    if block is None:
        block = DEFAULT_BLOCKS[rank]
    if isinstance(block, int):
        block = (block,)
    block = tuple(int(b) for b in block)
    if len(block) > rank:
        block = block[-rank:]
    if strategy == "tc":
        # Every axis is a potential contraction axis: cap the tile so
        # the banded coefficient matrices (and the per-point MXU work,
        # which grows with the contraction extent) stay bounded.
        block = tuple(min(b, TC_MAX_TILE) for b in block)
    if len(block) != rank:
        raise ValueError(
            f"block {block} must have {rank} entries (or more, trailing "
            "kept; x last)"
        )

    # Clamp to divisors. The x axis accounts for the unroll factor: the
    # per-step extent block[-1] * unroll must divide the interior; if no
    # unrolled tiling fits, unroll degrades to 1.
    clamped = [
        largest_divisor_leq(interior[a], block[a]) for a in range(rank - 1)
    ]
    if strategy == "swc_stream" and fuse_steps > 1 and clamped:
        # The fused stream chunk must leave room for the carried halo
        # (2·r·S planes) on the stream axis: shrink the chunk when a
        # smaller divisor fits, and otherwise leave the block for
        # StencilPlan validation to reject with the clear error.
        cap = interior[0] - 2 * radii[0] * fuse_steps
        if cap >= 1:
            clamped[0] = largest_divisor_leq(
                interior[0], min(clamped[0], cap)
            )
    nx = interior[-1]
    if unroll > 1 and nx % unroll == 0:
        tx = largest_divisor_leq(nx // unroll, block[-1])
    else:
        unroll = 1
        tx = largest_divisor_leq(nx, block[-1])
    clamped.append(tx)

    return StencilPlan(
        rank=rank,
        strategy=strategy,
        block=tuple(clamped),
        radii=radii,
        interior=interior,
        n_f=int(padded_shape[0]),
        n_out=int(n_out),
        dtype=str(dtype),
        n_aux=int(n_aux),
        unroll=int(unroll),
        fuse_steps=int(fuse_steps),
        batch=int(batch),
        accuracy=int(accuracy),
    )


def plan_from_record(
    ops: OperatorSet,
    interior_shape: Sequence[int],
    n_out: int,
    record: TuningRecord,
    *,
    dtype: str = "float32",
    n_aux: int = 0,
) -> StencilPlan | None:
    """Reconstruct the :class:`StencilPlan` a resolved tuning record
    lowers to — the warm-cache side of the ``strategy="auto"`` contract.

    ``interior_shape`` is the UNPADDED (n_f, *spatial) — or batched
    (batch, n_f, *spatial) — operand shape and
    ``record`` a :class:`~repro.tuning.cache.TuningRecord` whose
    ``strategy_resolved``/``stream``/``block``/``fuse_steps``/
    ``unroll`` fields were persisted by the cross-strategy search.
    Returns ``None`` for a record that resolved to ``hwc`` (the
    compiler-managed path has no Pallas plan); otherwise the plan is
    built exactly as the kernel dispatch would build it, so
    ``plan.strategy_id``/``tuning_key()`` round-trip the decision —
    the left-inverse contract ``repro.analysis.keys`` audits per axis.
    """
    strategy = record.resolved_strategy
    if strategy == "hwc":
        return None
    depth = int(record.fuse_steps)
    # Additive schema-v2 field: records persisted before the unroll
    # axis was recorded lower with the factor they were keyed under
    # (unroll joins the key as :u{N}, so an unmarked key pins 1).
    unroll = int(getattr(record, "unroll", 1))
    radii = ops.radius_per_axis()
    lead = len(tuple(interior_shape)) - ops.ndim  # 1, or 2 when batched
    padded = tuple(interior_shape[:lead]) + tuple(
        n + 2 * r * depth for n, r in zip(interior_shape[lead:], radii)
    )
    return plan_stencil(
        ops, padded, n_out, strategy=strategy,
        block=tuple(record.block), dtype=dtype, n_aux=n_aux,
        unroll=unroll, fuse_steps=depth,
    )
