"""Rank-generic Pallas emitters for :class:`~repro.kernels.plan.StencilPlan`.

This module subsumes the previously hand-written 1-D/3-D kernel bodies:
one pipelined software-managed-cache emitter serves ranks 1, 2 and 3,
and the explicit-streaming variant (paper Fig. 5b) is selected by a
plan attribute (``strategy="swc_stream"``, ranks 2 and 3, streaming the
slowest spatial axis) rather than living in a separate code path.

Strategies (paper Sec. 4.4, Figs. 4-5, on the TPU target):

* ``swc`` — the input tile plus halo, (τ…+2r…, τx+2rx) per field, is
  staged into VMEM by the Pallas pipeline with the slowest spatial axis
  iterating innermost at rank 3 (z-streaming with automatic
  double-buffered prefetch). Tap evaluation is fully unrolled with
  static offsets (stencil point-wise unrolling) and runs on the VPU as
  shifted-slice FMAs. ``plan.unroll > 1`` additionally computes several
  adjacent x sub-tiles per grid step from one staged window — the
  paper's element-wise unrolling, generalized to any rank.
  ``plan.fuse_steps > 1`` selects the temporal-fusion kernel instead:
  the staged halo widens to ``r·fuse_steps`` and the fused op is applied
  that many times on the VMEM-resident block (valid region shrinking by
  one radius per sweep), so intermediate time steps never round-trip
  through HBM.
* ``swc_stream`` — ranks 2 and 3: the cross-stream tile ((y, x) at rank
  3, (x,) at rank 2) is fixed per grid step and the kernel streams
  slowest-axis chunks (z at rank 3, y at rank 2) through an explicitly
  managed VMEM working buffer with async-DMA prefetch and carried halo
  planes (the TPU adaptation of the circular-buffer trick — see
  docs/architecture.md for the worked rank-2 lowering).
  ``plan.fuse_steps > 1`` composes: the carried halo widens to
  ``2·r·fuse_steps`` planes and each chunk runs the temporal sweeps on
  the streaming working set — the streaming variant of temporal
  blocking.
* ``tc`` — the matrix-unit regime: staging and grid are identical to
  pipelined ``swc``, but tap evaluation is lowered by
  :func:`_block_derivs_tc` instead of shifted-slice FMAs. Each
  multi-tap contraction group (see
  :func:`~repro.kernels.plan.tc_axis_groups`) becomes one
  ``jax.lax.dot_general`` of the staged window against a banded
  coefficient matrix of shape (τ_a+2r_a, τ_a) — with
  ``preferred_element_type=jnp.float32``, the form Mosaic places on
  the MXU with f32 accumulation (bf16 inputs run at double rate).
  Lone taps stay scalar slice-multiplies (a matmul per single tap
  would be all overhead). Temporal fusion reuses
  :func:`_temporal_sweeps` with the matmul derivs; the batch axis
  composes for free (members are extra rows of the contraction).

The HWC ("let the compiler manage residency") strategy lives in
``repro.kernels.ref`` as pure jnp.

Every emitter consumes the plan's tap tables verbatim: the (offset,
coefficient) sequences come from the generated Fornberg weights in
``repro.core.stencil`` (any even accuracy order — the order is a plan
axis, ``StencilPlan.accuracy``, joining the strategy id as ``:o{A}``),
so no kernel body hardwires a stencil order. See docs/stencils.md.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Callable

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.stencil import OperatorSet
from repro.kernels.compat import element_window_spec
from repro.kernels.plan import StencilPlan, tc_axis_groups


def _block_derivs(
    fblk: jnp.ndarray,
    ops: OperatorSet,
    radii: tuple[int, ...],
    tile: tuple[int, ...],
) -> dict[str, jnp.ndarray]:
    """Evaluate every operator over a VMEM-resident block of any rank.

    ``fblk``: (n_f, *(τ_a + 2r_a)). Static slices per tap — unrolled at
    trace time (stencil point-wise unrolling)."""
    rank = len(tile)
    out: dict[str, jnp.ndarray] = {}
    for spec in ops.ops:
        acc = None
        for off, c in zip(spec.offsets, spec.coeffs):
            sl = (slice(None),) + tuple(
                slice(radii[a] + off[a], radii[a] + off[a] + tile[a])
                for a in range(rank)
            )
            term = jnp.asarray(c, dtype=fblk.dtype) * fblk[sl]
            acc = term if acc is None else acc + term
        out[spec.name] = acc
    return out


def _contract(window, band, axis: int):
    """One banded contraction of ``window`` along spatial ``axis``
    (``dot_general`` against the (ext+2r, ext) band, f32 accumulate,
    output dim moved back where the contracted axis was).

    This is the ONE data-dependent MXU op of the ``tc`` lowering, kept
    behind an indirection so the static auditor (``repro.analysis``)
    can thread its interval-domain shadow arrays through the kernel
    body: a window that implements ``shadow_contract`` dispatches there
    instead of running the matmul.
    """
    shadow = getattr(window, "shadow_contract", None)
    if shadow is not None:
        return shadow(band, axis)
    term = jax.lax.dot_general(
        window,
        band,
        dimension_numbers=(((1 + axis,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )
    # dot_general appends the band's output dim last; put it back where
    # the contracted axis was.
    return jnp.moveaxis(term, -1, 1 + axis)


def _tc_band(
    taps: tuple[tuple[int, float], ...],
    out_extent: int,
    radius: int,
    dtype,
) -> jnp.ndarray:
    """Banded coefficient matrix for one tc contraction group.

    ``B[radius + j + i, i] = c`` for each tap ``(j, c)`` and output
    index ``i``: column ``i`` gathers the group's taps around the
    window position ``radius + i`` (output point ``i``'s center), so
    ``window @ B`` evaluates the whole 1-D contraction in one matmul.
    Shape (out_extent + 2·radius, out_extent). Built from 2-D iotas at
    trace time INSIDE the kernel — Pallas rejects large captured array
    constants, and the few compare/selects are noise next to the
    contraction itself. Temporal sweeps need one band per shrinking
    sub-tile extent.
    """
    shape = (out_extent + 2 * radius, out_extent)
    diag = jax.lax.broadcasted_iota(
        jnp.int32, shape, 0
    ) - jax.lax.broadcasted_iota(jnp.int32, shape, 1)
    band = jnp.zeros(shape, jnp.float32)
    for j, c in taps:
        band = band + jnp.where(
            diag == radius + j, jnp.float32(c), jnp.float32(0)
        )
    return band.astype(dtype)


def _block_derivs_tc(
    fblk: jnp.ndarray,
    ops: OperatorSet,
    radii: tuple[int, ...],
    tile: tuple[int, ...],
) -> dict[str, jnp.ndarray]:
    """MXU variant of :func:`_block_derivs`: same (n_f, *(τ_a + 2r_a))
    window, same results, but every multi-tap contraction group runs as
    a banded-matrix ``dot_general`` with f32 accumulation.

    The band is materialized in the input dtype (so bf16 coefficients
    round exactly as the VPU path's), the contraction accumulates in
    float32 (``preferred_element_type``), and the operator result is
    cast back to the block dtype at the end — the
    "bf16-input-f32-accumulate" MXU contract.
    """
    rank = len(tile)
    out: dict[str, jnp.ndarray] = {}
    for spec in ops.ops:
        acc = None
        for (axis, rest), taps in sorted(
            tc_axis_groups(spec, rank).items()
        ):
            if len(taps) == 1:
                ((j, c),) = taps
                off = tuple(
                    j if a == axis else rest[a] for a in range(rank)
                )
                sl = (slice(None),) + tuple(
                    slice(radii[a] + off[a], radii[a] + off[a] + tile[a])
                    for a in range(rank)
                )
                term = (
                    jnp.asarray(c, dtype=fblk.dtype) * fblk[sl]
                ).astype(jnp.float32)
            else:
                sl = (slice(None),) + tuple(
                    slice(0, tile[a] + 2 * radii[a]) if a == axis
                    else slice(
                        radii[a] + rest[a],
                        radii[a] + rest[a] + tile[a],
                    )
                    for a in range(rank)
                )
                band = _tc_band(
                    tuple(sorted(taps)), tile[axis], radii[axis],
                    fblk.dtype,
                )
                term = _contract(fblk[sl], band, axis)
            acc = term if acc is None else acc + term
        out[spec.name] = acc.astype(fblk.dtype)
    return out


def _kernel_pipelined(
    f_ref, *rest, ops, radii, tile, phi, unroll, has_aux,
    derivs_fn=_block_derivs,
):
    """Pipelined kernel, any rank. ``rest`` is (aux_ref, o_ref) when the
    plan carries aux inputs, else (o_ref,). ``derivs_fn`` selects the
    tap-evaluation lowering (VPU shifted slices or MXU contractions)."""
    aux_ref, o_ref = rest if has_aux else (None, rest[0])
    fblk = f_ref[...]
    tx = tile[-1]
    rx = radii[-1]
    for e in range(unroll):  # static: unrolled at trace time
        sub = fblk if unroll == 1 else fblk[..., e * tx : e * tx + tx + 2 * rx]
        derivs = derivs_fn(sub, ops, radii, tile)
        if has_aux:
            ablk = aux_ref[...]
            a_sub = ablk if unroll == 1 else ablk[..., e * tx : (e + 1) * tx]
            val = phi(derivs, a_sub)
        else:
            val = phi(derivs)
        if unroll == 1:
            o_ref[...] = val
        else:
            o_ref[..., e * tx : (e + 1) * tx] = val


def _kernel_tc(f_ref, *rest, ops, radii, tile, phi, has_aux):
    """Depth-1 MXU kernel: the pipelined body with banded-contraction
    tap evaluation (named so tc launches are identifiable in traces)."""
    _kernel_pipelined(
        f_ref, *rest, ops=ops, radii=radii, tile=tile, phi=phi,
        unroll=1, has_aux=has_aux, derivs_fn=_block_derivs_tc,
    )


def _temporal_sweeps(
    cur: jnp.ndarray,
    ops: OperatorSet,
    radii: tuple[int, ...],
    tile: tuple[int, ...],
    phis,
    derivs_fn=_block_derivs,
) -> jnp.ndarray:
    """Apply ``len(phis)`` fused sweeps to one VMEM-resident window.

    ``cur``: (n_f, *(τ_a + 2·r_a·S)) — the tile staged with a halo of
    one radius per sweep. Sweep ``s`` evaluates the operators over the
    window shrunk to a ``r·(S-1-s)`` margin, so the final sweep lands
    exactly on (·, *τ). Intermediate field stacks never leave registers/
    VMEM. No aux carry (the streaming kernel rejects aux); the aux-aware
    variant lives in :func:`_kernel_temporal`. Returns the final tile.
    """
    n_f = cur.shape[0]
    n_steps = len(phis)
    for s, phi in enumerate(phis):  # static: unrolled at trace time
        margin = n_steps - 1 - s
        sub_tile = tuple(t + 2 * r * margin for t, r in zip(tile, radii))
        derivs = derivs_fn(cur, ops, radii, sub_tile)
        val = phi(derivs)
        if margin:
            cur = val[:n_f]
    return val


def _kernel_temporal(
    f_ref, *rest, ops, radii, tile, phis, n_f, has_aux,
    derivs_fn=_block_derivs,
):
    """Temporal-fusion kernel, any rank: apply the fused op
    ``len(phis)`` times on one VMEM-resident block staged with a
    ``radii * fuse_steps`` halo. Each sweep's valid region shrinks by
    one radius per axis; intermediate field stacks (and carries) stay
    on-chip — only the final tile is written back to HBM.

    ``rest`` is (aux_ref, o_ref) when the plan carries aux inputs, else
    (o_ref,). The staged aux window is ``tile + 2r(S-1)`` so every
    intermediate sweep sees a point-wise-aligned carry. The aux-free
    case delegates to :func:`_temporal_sweeps` (shared with the
    streaming kernel) so the sweep-shrinking arithmetic lives once.
    """
    if not has_aux:
        (o_ref,) = rest
        o_ref[...] = _temporal_sweeps(
            f_ref[...], ops, radii, tile, phis, derivs_fn=derivs_fn
        )
        return
    aux_ref, o_ref = rest
    n_steps = len(phis)
    cur = f_ref[...]
    cur_aux = aux_ref[...]
    for s, phi in enumerate(phis):  # static: unrolled at trace time
        margin = n_steps - 1 - s  # sweeps remaining after this one
        sub_tile = tuple(
            t + 2 * r * margin for t, r in zip(tile, radii)
        )
        derivs = derivs_fn(cur, ops, radii, sub_tile)
        val = phi(derivs, cur_aux)
        if margin == 0:
            o_ref[...] = val
        else:
            cur = val[:n_f]
            n_aux = cur_aux.shape[0]
            cur_aux = val[n_f : n_f + n_aux][
                (slice(None),)
                + tuple(
                    slice(r, r + t + 2 * r * (margin - 1))
                    for t, r in zip(tile, radii)
                )
            ]


def _member_phi(phi, batch: int, n_f: int, n_aux: int):
    """Wrap a single-member φ for a member-major flattened ensemble.

    The batched lowering stacks B members along the leading field axis
    (rows ``m·n_f .. (m+1)·n_f`` belong to member ``m``), so every
    kernel body stays batch-oblivious: taps vectorize over the B·n_f
    rows, and only the point-wise φ needs to know member boundaries.
    The wrapper slices each member's derivative rows (and aux rows, if
    any), applies φ per member in a static Python loop (unrolled at
    trace time), and re-concatenates outputs member-major.
    """

    def wrapped(derivs, aux=None):
        outs = []
        for m in range(batch):  # static: unrolled at trace time
            d_m = {
                k: v[m * n_f : (m + 1) * n_f] for k, v in derivs.items()
            }
            if aux is None:
                outs.append(phi(d_m))
            else:
                outs.append(phi(d_m, aux[m * n_aux : (m + 1) * n_aux]))
        return jnp.concatenate(outs, axis=0)

    return wrapped


def _fused_batched(
    f_padded, ops, phis, plan: StencilPlan, *, aux, interpret
):
    """Lower a batched (ensemble) plan: one kernel walks all B members
    per block instead of B independent launches.

    Members are flattened member-major onto the field axis —
    (B, n_f, *sp) → (B·n_f, *sp) — so the staged input window (and its
    halo fetch) is shared by the whole ensemble: the per-launch-step
    pipeline/prologue cost is paid once per block, not once per member.
    Each φ is wrapped by :func:`_member_phi` and the plan is re-derived
    with ``batch=1`` and B-scaled field counts, so the pipelined,
    temporal and streaming kernel bodies all serve ensembles unchanged.
    Member-major rows stay aligned across temporal sweeps because
    depth > 1 requires per-member ``n_out == n_f`` (aux carries with
    batching are rejected at plan level). Returns
    (batch, n_out, *interior).
    """
    b = plan.batch
    if f_padded.shape[:2] != (b, plan.n_f):
        raise ValueError(
            f"batched operand must be (batch, n_f, *spatial) = "
            f"({b}, {plan.n_f}, ...), got shape {f_padded.shape}"
        )
    flat = f_padded.reshape((b * plan.n_f,) + f_padded.shape[2:])
    aux_flat = None
    if aux is not None:
        if aux.shape[:2] != (b, plan.n_aux):
            raise ValueError(
                f"batched aux must be (batch, n_aux, *spatial) = "
                f"({b}, {plan.n_aux}, ...), got shape {aux.shape}"
            )
        aux_flat = aux.reshape((b * plan.n_aux,) + aux.shape[2:])
    wrapped = tuple(
        _member_phi(p, b, plan.n_f, plan.n_aux) for p in phis
    )
    derived = dataclasses.replace(
        plan, batch=1, n_f=b * plan.n_f, n_out=b * plan.n_out,
        n_aux=b * plan.n_aux,
    )
    out = fused_stencil_pallas(
        flat, ops, wrapped, derived, aux=aux_flat, interpret=interpret
    )
    return out.reshape((b, plan.n_out) + plan.interior)


def lowering_windows(plan: StencilPlan) -> dict[str, tuple[int, ...]]:
    """Static per-grid-step extents of the pipelined lowering — the ONE
    derivation shared by :func:`fused_stencil_pallas` (which turns them
    into BlockSpecs) and the static auditor ``repro.analysis`` (which
    instantiates shadow refs of exactly these shapes), so the audited
    geometry can never diverge from the emitted one.

    Returns spatial extents (no field axis): ``window`` — the staged
    input block (halo-widened, x spanning all ``unroll`` sub-tiles);
    ``out_tile`` — the output block; ``aux_window`` — the staged aux
    block (``None`` for aux-free plans): halo-free at depth 1, widened
    by ``r·(S-1)`` per axis at temporal depth ``S > 1``.
    """
    radii, tile = plan.radii, plan.block
    window = tuple(
        (plan.x_step if a == plan.rank - 1 else tile[a]) + 2 * h
        for a, h in enumerate(plan.halo)
    )
    out_tile = tile[:-1] + (plan.x_step,)
    aux_window: tuple[int, ...] | None = None
    if plan.n_aux:
        if plan.fuse_steps == 1:
            aux_window = out_tile
        else:
            aux_window = tuple(
                t + 2 * r * (plan.fuse_steps - 1)
                for t, r in zip(tile, radii)
            )
    return {
        "window": window, "out_tile": out_tile, "aux_window": aux_window,
    }


def stream_extents(plan: StencilPlan) -> dict[str, tuple[int, ...] | int]:
    """Static scratch extents of the explicit-streaming lowering —
    shared by :func:`_fused_stream` (VMEM scratch allocation) and the
    auditor's shadow run, like :func:`lowering_windows` for the
    pipelined path. Spatial extents only (``work``/``prefetch``/
    ``outbuf``), plus the stream chunk count ``n_chunks``.
    """
    tile, halo = plan.block, plan.halo
    cross = tuple(t + 2 * h for t, h in zip(tile[1:], halo[1:]))
    return {
        "work": (tile[0] + 2 * halo[0],) + cross,
        "prefetch": (tile[0],) + cross,
        "outbuf": tile,
        "n_chunks": plan.interior[0] // tile[0],
    }


def _grid_and_maps(plan: StencilPlan):
    """Grid extents and (input, tile-indexed) index maps per rank.

    The input map returns *element* offsets on the window (spatial)
    dims; the tile map returns block indices for halo-free operands
    (aux, output). At rank 3 the grid iterates (y, x, z) with z
    innermost so the pipeline's next-block prefetch walks the z-stream.
    """
    steps = plan.block[:-1] + (plan.x_step,)
    grid_n = plan.grid
    if plan.rank == 1:
        (sx,) = steps
        return (
            grid_n,
            lambda i: (0, i * sx),
            lambda i: (0, i),
        )
    if plan.rank == 2:
        sy, sx = steps
        return (
            grid_n,
            lambda i, j: (0, i * sy, j * sx),
            lambda i, j: (0, i, j),
        )
    sz, sy, sx = steps
    return (
        (grid_n[1], grid_n[2], grid_n[0]),
        lambda j, k, i: (0, i * sz, j * sy, k * sx),
        lambda j, k, i: (0, i, j, k),
    )


def fused_stencil_pallas(
    f_padded: jnp.ndarray,
    ops: OperatorSet,
    phi: Callable[..., jnp.ndarray],
    plan: StencilPlan,
    *,
    aux: jnp.ndarray | None = None,
    interpret: bool = False,
) -> jnp.ndarray:
    """Emit and invoke the fused φ(A·B) kernel described by ``plan``.

    ``f_padded``: (n_f, *(n_a + 2r_a·fuse_steps)) with radii from the
    plan. ``aux`` — extra point-wise inputs passed as phi's second
    argument, fusing point-wise follow-up work (e.g. the RK axpy) into
    the stencil kernel: (n_aux, *interior) at depth 1 (staged as
    halo-free center tiles), (n_aux, *(interior + 2r(S-1))) at temporal
    depth S > 1 (staged as overlapping windows so intermediate sweeps
    see an aligned carry). ``phi`` may be a sequence of ``fuse_steps``
    callables (one per fused sweep). Returns (n_out, *interior).

    When ``plan.batch > 1`` the operands grow a leading ensemble axis —
    ``f_padded`` (batch, n_f, *padded), ``aux`` (batch, n_aux, ...) —
    and one kernel walks all members per block (member-major field
    rows, shared halo window; see :func:`_fused_batched`). Returns
    (batch, n_out, *interior).
    """
    if (aux is not None) != bool(plan.n_aux):
        raise ValueError("aux operand does not match plan.n_aux")
    phis = (
        tuple(phi)
        if isinstance(phi, (tuple, list))
        else (phi,) * plan.fuse_steps
    )
    if len(phis) != plan.fuse_steps:
        raise ValueError(
            f"got {len(phis)} phi callables for plan with "
            f"fuse_steps={plan.fuse_steps}"
        )
    if plan.batch > 1 or f_padded.ndim == plan.rank + 2:
        return _fused_batched(
            f_padded, ops, phis, plan, aux=aux, interpret=interpret
        )
    if plan.strategy == "swc_stream":
        return _fused_stream(
            f_padded, ops, phis, plan, interpret=interpret
        )

    radii, tile = plan.radii, plan.block
    windows = lowering_windows(plan)
    window = windows["window"]
    out_tile = windows["out_tile"]
    grid, in_map, tile_map = _grid_and_maps(plan)
    in_specs = [
        element_window_spec(
            (plan.n_f,) + window,
            in_map,
            window_dims=tuple(range(1, plan.rank + 1)),
        )
    ]
    operands = [f_padded]
    if aux is not None:
        aux_window = windows["aux_window"]
        if plan.fuse_steps == 1:
            in_specs.append(
                pl.BlockSpec((plan.n_aux,) + aux_window, tile_map)
            )
        else:
            in_specs.append(
                element_window_spec(
                    (plan.n_aux,) + aux_window,
                    in_map,
                    window_dims=tuple(range(1, plan.rank + 1)),
                )
            )
        operands.append(aux)
    tc = plan.strategy == "tc"
    if plan.fuse_steps > 1:
        kernel = functools.partial(
            _kernel_temporal, ops=ops, radii=radii, tile=tile,
            phis=phis, n_f=plan.n_f, has_aux=aux is not None,
            derivs_fn=_block_derivs_tc if tc else _block_derivs,
        )
    elif tc:
        kernel = functools.partial(
            _kernel_tc, ops=ops, radii=radii, tile=tile,
            phi=phis[0], has_aux=aux is not None,
        )
    else:
        kernel = functools.partial(
            _kernel_pipelined, ops=ops, radii=radii, tile=tile,
            phi=phis[0], unroll=plan.unroll, has_aux=aux is not None,
        )
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=in_specs,
        out_specs=pl.BlockSpec((plan.n_out,) + out_tile, tile_map),
        out_shape=jax.ShapeDtypeStruct(
            (plan.n_out,) + plan.interior, f_padded.dtype
        ),
        interpret=interpret,
    )(*operands)


# ---------------------------------------------------------------------------
# Fig. 5b: explicit streaming along the slowest axis with carried halo
# planes + prefetch DMA (rank-2/3 plans; plan.strategy == "swc_stream").
# Temporal fusion composes: the carried halo widens to 2·r·fuse_steps
# planes and each chunk runs the fused sweeps on the working set.
# ---------------------------------------------------------------------------


def _kernel_stream(
    f_hbm, o_hbm, work, pf0, pf1, outbuf, sem_pf, sem_out, *,
    ops, radii, tile, phis, n_chunks,
):
    """Grid step = one cross-stream tile; the kernel streams all chunks
    of the slowest axis (z at rank 3, y at rank 2) through VMEM.

    With ``h_a = r_a · S`` (one radius of halo per fused sweep,
    ``S = len(phis)``), the VMEM scratch is:

      ``work``  (n_f, τ₀+2h₀, *(τ_a+2h_a)) — the working set; the
                leading 2h₀ planes are the halo carried chunk to chunk
                (the circular-buffer trick, unrolled as a plane copy);
      ``pf0/1`` (n_f, τ₀, *(τ_a+2h_a)) — double-buffered prefetch of
                the τ₀ fresh planes for the next chunk;
      ``outbuf``(n_out, *τ) — staging for the output DMA.

    Each chunk applies the ``S`` fused sweeps of
    :func:`_temporal_sweeps` to the working set (valid region shrinking
    one radius per sweep on every axis, including the stream axis), so
    streaming and temporal fusion compose in one kernel.
    """
    rank = len(tile)
    halo = tuple(r * len(phis) for r in radii)
    ts, hs = tile[0], halo[0]
    cross_off = tuple(
        pl.program_id(i) * tile[1 + i] for i in range(rank - 1)
    )
    cross_halo = tuple(
        pl.ds(o, t + 2 * h)
        for o, t, h in zip(cross_off, tile[1:], halo[1:])
    )
    cross_tile = tuple(
        pl.ds(o, t) for o, t in zip(cross_off, tile[1:])
    )

    def fresh_copy(chunk, pf_ref, slot):
        """DMA the τ₀ fresh planes of ``chunk`` into a prefetch buffer."""
        return pltpu.make_async_copy(
            f_hbm.at[
                (slice(None), pl.ds(chunk * ts + 2 * hs, ts)) + cross_halo
            ],
            pf_ref,
            sem_pf.at[slot],
        )

    # Prologue: leading halo planes go straight into the working buffer;
    # chunk 0's fresh planes start streaming into prefetch slot 0.
    halo_cp = pltpu.make_async_copy(
        f_hbm.at[(slice(None), pl.ds(0, 2 * hs)) + cross_halo],
        work.at[:, pl.ds(0, 2 * hs)],
        sem_out,  # reuse; waited below before any compute
    )
    halo_cp.start()
    fresh_copy(0, pf0, 0).start()
    halo_cp.wait()

    def body(chunk, _):
        slot = jax.lax.rem(chunk, 2)

        # Kick off the NEXT chunk's fresh-plane DMA before computing this
        # one (the paper's "prefetch buffer updated in parallel with
        # computations").
        @pl.when(chunk + 1 < n_chunks)
        def _():
            @pl.when(slot == 0)
            def _():
                fresh_copy(chunk + 1, pf1, 1).start()

            @pl.when(slot == 1)
            def _():
                fresh_copy(chunk + 1, pf0, 0).start()

        # Land this chunk's fresh planes behind the carried halo.
        @pl.when(slot == 0)
        def _():
            fresh_copy(chunk, pf0, 0).wait()
            work[:, pl.ds(2 * hs, ts)] = pf0[...]

        @pl.when(slot == 1)
        def _():
            fresh_copy(chunk, pf1, 1).wait()
            work[:, pl.ds(2 * hs, ts)] = pf1[...]

        outbuf[...] = _temporal_sweeps(work[...], ops, radii, tile, phis)
        out_cp = pltpu.make_async_copy(
            outbuf,
            o_hbm.at[(slice(None), pl.ds(chunk * ts, ts)) + cross_tile],
            sem_out,
        )
        out_cp.start()

        # Carry the trailing halo: the last 2h₀ planes become the next
        # chunk's leading halo (VMEM-to-VMEM plane copy; see module
        # docstring on why TPU prefers this over the circular buffer).
        work[:, pl.ds(0, 2 * hs)] = work[:, pl.ds(ts, 2 * hs)]
        out_cp.wait()
        return 0

    jax.lax.fori_loop(0, n_chunks, body, 0)


def _fused_stream(
    f_padded, ops, phis, plan: StencilPlan, *, interpret: bool = False
):
    """Lower an ``swc_stream`` plan (rank 2 or 3, any fuse depth)."""
    tile = plan.block
    ext = stream_extents(plan)
    dtype = f_padded.dtype

    kernel = functools.partial(
        _kernel_stream, ops=ops, radii=plan.radii, tile=tile,
        phis=phis, n_chunks=ext["n_chunks"],
    )
    return pl.pallas_call(
        kernel,
        grid=tuple(n // t for n, t in zip(plan.interior[1:], tile[1:])),
        in_specs=[pl.BlockSpec(memory_space=pl.ANY)],
        out_specs=pl.BlockSpec(memory_space=pl.ANY),
        out_shape=jax.ShapeDtypeStruct(
            (plan.n_out,) + plan.interior, dtype
        ),
        scratch_shapes=[
            pltpu.VMEM((plan.n_f,) + ext["work"], dtype),
            pltpu.VMEM((plan.n_f,) + ext["prefetch"], dtype),
            pltpu.VMEM((plan.n_f,) + ext["prefetch"], dtype),
            pltpu.VMEM((plan.n_out,) + ext["outbuf"], dtype),
            pltpu.SemaphoreType.DMA((2,)),
            pltpu.SemaphoreType.DMA,
        ],
        interpret=interpret,
    )(f_padded)
