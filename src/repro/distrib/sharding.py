"""Sharding rules: parameter PartitionSpecs and activation constraints.

Mesh contract (launch/mesh.py): axes ``("pod", "data", "model")`` multi-
pod or ``("data", "model")`` single-pod. Parallelism mapping:

* **DP**    — batch over ``(pod, data)``; gradients all-reduced
              hierarchically (in-pod reduce-scatter on ``data``, 1-hop
              cross-pod all-reduce on ``pod``) by GSPMD.
* **TP**    — attention heads / FFN hidden over ``model``.
* **SP**    — sequence over ``model`` between blocks (activations only).
* **EP**    — MoE experts over ``model`` (see repro.models.moe).
* **FSDP**  — optionally parameters additionally sharded over ``data``
              (enabled for the 14B config, where replicated f32 master
              params + Adam states would not fit HBM).

Rules are *divisibility-safe*: a dim is only sharded if the named axes'
product divides it — e.g. 2 KV heads never shard over 16-way ``model``
(they replicate), exactly the fallback a hand-written Megatron layout
would pick.

``constrain`` is the activation-annotation hook used inside model code:
a no-op unless a rule set is installed (set_rules / rules_context), so
models run unmodified on CPU/single-device.
"""
from __future__ import annotations

import contextlib
import re
import threading
from typing import Any, Mapping

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

_STATE = threading.local()

# Activation rules: logical name -> tuple of (axis names or None) per dim,
# or a LIST of such tuples (candidates tried in order; first one whose every
# requested axis divides wins — e.g. EP vs expert-TP for MoE tensors).
DEFAULT_ACT_RULES: dict[str, tuple | list] = {
    "tokens_bs": (("pod", "data"), None),
    # Megatron-style TP baseline: the residual stream is replicated over
    # `model` between blocks (one all-reduce after attention + one after
    # MLP). Sequence parallelism (seq over `model`, rule below) trades
    # those all-reduces for all-gather/reduce-scatter pairs + sharded
    # norms — evaluated as a §Perf iteration, not the baseline.
    "act_bsd": (("pod", "data"), None, None),
    "act_bsd_sp": (("pod", "data"), "model", None),  # sequence-parallel
    "act_bshd": (("pod", "data"), None, "model", None),  # heads TP
    "logits_bsv": (("pod", "data"), None, "model"),  # vocab TP
    "decode_bd": (("pod", "data"), None),
    # unembedding weight AFTER dtype cast: the convert breaks GSPMD's
    # propagation from the parameter sharding, and an unconstrained
    # (d, V) operand lets the partitioner pick a d-sharded dot with a
    # full-vocab f32 all-reduce (40 GiB at qwen vocab; §Perf cell 2).
    "unembed_dv": (None, "model"),
    # decode KV cache (b, L, g, dh): head TP, else sequence-sharded
    "cache_blgd": [
        (("pod", "data"), None, "model", None),
        (("pod", "data"), "model", None, None),
    ],
    "moe_gecd": [
        (("pod", "data"), "model", None, None),  # EP: experts sharded
        (("pod", "data"), None, None, None),
    ],
    "moe_gecf": [
        (("pod", "data"), "model", None, None),  # EP
        (("pod", "data"), None, None, "model"),  # expert-TP (E < mesh)
    ],
}


def _mesh_axis_sizes(mesh: Mesh) -> dict[str, int]:
    return dict(zip(mesh.axis_names, mesh.devices.shape))


def _axes_size(axes, sizes: Mapping[str, int]) -> int:
    if axes is None:
        return 1
    if isinstance(axes, str):
        return sizes.get(axes, 1)
    return int(np.prod([sizes.get(a, 1) for a in axes]))


def _filter_axes(axes, sizes):
    """Drop axes missing from the mesh (e.g. 'pod' on single-pod)."""
    if axes is None:
        return None
    if isinstance(axes, str):
        return axes if axes in sizes else None
    kept = tuple(a for a in axes if a in sizes)
    if not kept:
        return None
    return kept if len(kept) > 1 else kept[0]


def _spec_dims(shape, wanted, sizes) -> tuple[list, bool]:
    """Per-dim axes after divisibility filtering + whether every
    *requested* (mesh-present) axis survived."""
    dims, complete = [], True
    for dim, axes in zip(shape, wanted):
        axes = _filter_axes(axes, sizes)
        n = _axes_size(axes, sizes)
        if n > 1 and dim % n == 0:
            dims.append(axes)
        else:
            dims.append(None)
            if axes is not None:
                complete = False
    return dims, complete


def safe_spec(
    shape: tuple[int, ...], wanted: tuple | list, mesh: Mesh
) -> P:
    """PartitionSpec from desired per-dim axes, dropping any assignment
    whose axis product does not divide the dim size. ``wanted`` may be a
    list of candidates — the first fully-satisfiable one wins."""
    sizes = _mesh_axis_sizes(mesh)
    candidates = wanted if isinstance(wanted, list) else [wanted]
    chosen = None
    for cand in candidates:
        dims, complete = _spec_dims(shape, cand, sizes)
        if chosen is None:
            chosen = dims
        if complete:
            chosen = dims
            break
    dims = chosen or []
    while dims and dims[-1] is None:
        dims.pop()
    return P(*dims)


# --- parameter sharding ------------------------------------------------------

# path regex -> wanted axes per dim (leading layer-stack dim always None).
# FSDP adds ("data",) to the first matching non-TP dim (see param_spec).
PARAM_RULES: list[tuple[str, tuple]] = [
    (r"embed$", ("model", None)),  # vocab-parallel embedding
    (r"unembed$", (None, "model")),
    (r"blocks/(wq|wk|wv)$", (None, None, "model")),
    (r"blocks/(bq|bk|bv)$", (None, "model")),
    (r"blocks/wo$", (None, "model", None)),
    (r"blocks/(w_gate|w_up)$", (None, None, "model")),
    (r"blocks/w_down$", (None, "model", None)),
    (r"blocks/moe/router$", (None, None, None)),
    # experts over model (EP) when E ≥ mesh; else expert-TP on d_ff.
    (r"blocks/moe/(w_gate|w_up)$", [
        (None, "model", None, None),
        (None, None, None, "model"),
    ]),
    (r"blocks/moe/w_down$", [
        (None, "model", None, None),
        (None, None, "model", None),
    ]),
    # recurrentgemma RG-LRU block
    (r"blocks_rec/(w_x|w_gate_in)$", (None, None, "model")),
    (r"blocks_rec/w_out$", (None, "model", None)),
    (r"blocks_rec/(w_a_gate|w_i_gate|a_param|conv_w|conv_b|gate_bias)", (None, "model")),
    (r"blocks_rec/(w_g|w_u)$", (None, None, "model")),
    (r"blocks_rec/w_d$", (None, "model", None)),
    # mamba2
    (r"blocks/in_proj$", (None, None, "model")),
    (r"blocks/out_proj$", (None, "model", None)),
    (r"blocks/(conv_w|conv_b|ssm_norm)$", (None, "model")),
    (r"blocks/(A_log|D|dt_bias)$", (None, "model")),
    # whisper encoder/decoder extra mats
    (r"(enc_blocks|blocks)/(wq_x|wk_x|wv_x)$", (None, None, "model")),
    (r"(enc_blocks|blocks)/wo_x$", (None, "model", None)),
    (r"(enc_blocks|blocks)/(w_in)$", (None, None, "model")),
    (r"(enc_blocks|blocks)/(w_out)$", (None, "model", None)),
]

_FSDP_ELIGIBLE = re.compile(
    r"(wq|wk|wv|wo|w_gate|w_up|w_down|in_proj|out_proj|w_in|w_out|embed|unembed)$"
)


def path_str(path) -> str:
    parts = []
    for p in path:
        if hasattr(p, "key"):
            parts.append(str(p.key))
        elif hasattr(p, "name"):
            parts.append(str(p.name))
        else:
            parts.append(str(p))
    return "/".join(parts)


def _pick_candidate(shape, wanted, sizes) -> tuple:
    if isinstance(wanted, list):
        for cand in wanted:
            _, complete = _spec_dims(shape, cand, sizes)
            if complete:
                return cand
        return wanted[0]
    return wanted


def param_spec(
    path: str, shape: tuple[int, ...], mesh: Mesh, *, fsdp: bool = False,
    profile: str = "tp",
) -> P:
    if profile == "dp":
        if fsdp:  # ZeRO over the whole device set
            n = int(np.prod(mesh.devices.shape))
            for i, dim in enumerate(shape):
                if dim % n == 0 and dim > 1:
                    wanted = [None] * len(shape)
                    wanted[i] = ("pod", "data", "model")
                    return safe_spec(shape, tuple(wanted), mesh)
        return P()
    wanted: tuple | list | None = None
    for pat, rule in PARAM_RULES:
        if re.search(pat, path):
            wanted = rule
            break
    if wanted is None:
        wanted = (None,) * len(shape)
    wanted = _pick_candidate(shape, wanted, _mesh_axis_sizes(mesh))
    wanted = tuple(wanted[: len(shape)]) + (None,) * (len(shape) - len(wanted))
    if fsdp and _FSDP_ELIGIBLE.search(path):
        # Shard the largest still-unsharded dim over data (ZeRO-3 style).
        sizes = _mesh_axis_sizes(mesh)
        n = sizes.get("data", 1)
        best, best_dim = None, 0
        for i, (dim, axes) in enumerate(zip(shape, wanted)):
            if axes is None and dim % n == 0 and dim > best_dim:
                best, best_dim = i, dim
        if best is not None:
            wanted = tuple(
                "data" if i == best else a for i, a in enumerate(wanted)
            )
    return safe_spec(shape, wanted, mesh)


def param_shardings(
    params_shape: Any, mesh: Mesh, *, fsdp: bool = False,
    profile: str = "tp",
) -> Any:
    """Pytree of NamedShardings matching a (possibly abstract) param tree."""

    def one(path, leaf):
        spec = param_spec(
            path_str(path), leaf.shape, mesh, fsdp=fsdp, profile=profile
        )
        return NamedSharding(mesh, spec)

    return jax.tree_util.tree_map_with_path(one, params_shape)


# --- activation constraints --------------------------------------------------


# Pure data-parallel profile: the `model` axis joins the batch axes and
# parameters replicate. Right for models whose per-chip matmuls are too
# small to amortize TP collectives (mamba2-780m: d=1536 → Megatron ARs
# dominate; §Perf cell 3). Gradient sync cost moves to the optimizer
# all-reduce, which overlaps with backward.
_DPM = ("pod", "data", "model")
DP_ACT_RULES: dict[str, tuple | list] = {
    "tokens_bs": (_DPM, None),
    "act_bsd": (_DPM, None, None),
    "act_bshd": (_DPM, None, None, None),
    "logits_bsv": (_DPM, None, None),
    "decode_bd": (_DPM, None),
    "cache_blgd": (_DPM, None, None, None),
    "unembed_dv": (None, None),
    "moe_gecd": (_DPM, None, None, None),
    "moe_gecf": (_DPM, None, None, None),
}

PROFILES = {"tp": DEFAULT_ACT_RULES, "dp": DP_ACT_RULES}


def profile_act_rules(profile: str):
    return PROFILES[profile]


def set_rules(mesh: Mesh | None, rules: Mapping[str, tuple] | None = None):
    _STATE.mesh = mesh
    _STATE.rules = dict(rules or DEFAULT_ACT_RULES)


@contextlib.contextmanager
def rules_context(mesh: Mesh, rules: Mapping[str, tuple] | None = None):
    prev = (getattr(_STATE, "mesh", None), getattr(_STATE, "rules", None))
    set_rules(mesh, rules)
    try:
        yield
    finally:
        _STATE.mesh, _STATE.rules = prev


def constrain(x: jax.Array, kind: str) -> jax.Array:
    """Annotate an activation with its logical sharding (no-op without
    an installed rule set)."""
    mesh = getattr(_STATE, "mesh", None)
    if mesh is None:
        return x
    rules = getattr(_STATE, "rules", DEFAULT_ACT_RULES)
    wanted = rules.get(kind)
    if wanted is None:
        return x
    spec = safe_spec(x.shape, wanted, mesh)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))
