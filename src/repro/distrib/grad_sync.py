"""Distributed-optimization tricks: compressed + hierarchical gradient
synchronization, and microbatch gradient accumulation.

GSPMD inserts the data-parallel gradient all-reduce automatically inside
the backward pass; these utilities implement the cases where you want
MANUAL control of the wire format and topology:

* ``compressed_psum_tree`` — cast f32 grads to bf16 for the wire, psum,
  decompress: halves cross-pod DCI traffic. Error feedback (the residual
  of the cast is carried into the next step) keeps the compression
  unbiased over time.
* ``hierarchical_psum_tree`` — reduce-scatter within the pod (fast ICI),
  all-reduce the 1/N shard across pods (slow DCI), all-gather within the
  pod. Wire cost on the slow axis drops from full-gradient to 1/D.
* ``accumulate_grads`` — microbatch gradient accumulation under
  ``lax.scan`` with f32 accumulators (donated), the standard way to reach
  global batch 256×4k tokens without activation blow-up.

All operate inside ``shard_map``; tests validate vs plain psum on the
512-fake-device backend.
"""
from __future__ import annotations

from typing import Any, Callable

import jax
import jax.numpy as jnp
from jax import lax


def compressed_psum_tree(
    grads: Any,
    axis_name: str,
    *,
    error_feedback: Any | None = None,
) -> tuple[Any, Any]:
    """bf16-on-the-wire psum over ``axis_name`` with error feedback.

    Returns (synced f32 grads, new error-feedback residuals).
    """

    def one(g, e):
        gf = g.astype(jnp.float32)
        if e is not None:
            gf = gf + e
        wire = gf.astype(jnp.bfloat16)
        residual = gf - wire.astype(jnp.float32)
        summed = lax.psum(wire, axis_name)
        return summed.astype(jnp.float32), residual

    if error_feedback is None:
        error_feedback = jax.tree.map(lambda g: None, grads,
                                      is_leaf=lambda x: x is None)
        out = jax.tree.map(lambda g: one(g, None), grads)
    else:
        out = jax.tree.map(one, grads, error_feedback)
    synced = jax.tree.map(lambda t: t[0], out,
                          is_leaf=lambda t: isinstance(t, tuple))
    resid = jax.tree.map(lambda t: t[1], out,
                         is_leaf=lambda t: isinstance(t, tuple))
    return synced, resid


def hierarchical_psum(
    x: jnp.ndarray, fast_axis: str, slow_axis: str
) -> jnp.ndarray:
    """reduce-scatter(fast) → all-reduce(slow) → all-gather(fast).

    Equivalent to psum over both axes; moves only 1/|fast| of the bytes
    over the slow (cross-pod) links.
    """
    from repro.core.halo import axis_size

    n_fast = axis_size(fast_axis)
    orig_shape = x.shape
    pad = (-x.shape[0]) % n_fast
    if pad:
        x = jnp.concatenate(
            [x, jnp.zeros((pad,) + x.shape[1:], x.dtype)], axis=0
        )
    shard = lax.psum_scatter(x, fast_axis, scatter_dimension=0, tiled=True)
    shard = lax.psum(shard, slow_axis)
    full = lax.all_gather(shard, fast_axis, axis=0, tiled=True)
    if pad:
        full = full[: orig_shape[0]]
    return full


def hierarchical_psum_tree(
    grads: Any, fast_axis: str, slow_axis: str
) -> Any:
    return jax.tree.map(
        lambda g: hierarchical_psum(g, fast_axis, slow_axis), grads
    )


def accumulate_grads(
    loss_fn: Callable,
    params: Any,
    microbatches: Any,  # pytree with leading (n_micro, ...) axes
) -> tuple[jnp.ndarray, Any]:
    """Scan microbatches, accumulating f32 grads. Returns (mean loss,
    mean grads)."""
    n = jax.tree_util.tree_leaves(microbatches)[0].shape[0]

    def body(carry, mb):
        loss_sum, acc = carry
        (loss, _), g = jax.value_and_grad(loss_fn, has_aux=True)(params, mb)
        acc = jax.tree.map(
            lambda a, gi: a + gi.astype(jnp.float32), acc, g
        )
        return (loss_sum + loss, acc), None

    zeros = jax.tree.map(
        lambda p: jnp.zeros(p.shape, jnp.float32), params
    )
    (loss_sum, acc), _ = lax.scan(
        body, (jnp.zeros((), jnp.float32), zeros), microbatches
    )
    scale = 1.0 / n
    return loss_sum * scale, jax.tree.map(lambda g: g * scale, acc)
