"""Distribution substrate: sharding rules (DP/FSDP/TP/EP/SP), activation
constraints, microbatching, and gradient synchronization policies."""
