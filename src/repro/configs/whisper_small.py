"""whisper-small [audio] — enc-dec 12+12L d_model=768 12H (MHA)
d_ff=3072 vocab=51865; conv/mel frontend STUBBED (input_specs provides
frame embeddings (b, 1500, 768)). Decode shapes are outside the
architecture contract (max target 448) — skipped, see DESIGN.md §4.
[arXiv:2212.04356; unverified]"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    arch_id="whisper-small",
    family="audio",
    n_layers=12,
    d_model=768,
    n_heads=12,
    n_kv_heads=12,
    d_ff=3072,
    vocab=51865,
    rope_style="none",
    tie_embeddings=True,
    n_encoder_layers=12,
    encoder_seq=1500,
    max_target_len=448,
    uses_stencil_kernel=True,  # conv frontend (stubbed) is a stencil
)
