"""mixtral-8x7b [moe] — 32L d_model=4096 32H (GQA kv=8) d_ff=14336
vocab=32000, 8 experts top-2, SWA 4096. Experts < mesh-model ⇒ the
sharding rules fall back to expert-TP (DESIGN.md §3). [arXiv:2401.04088; hf]"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    arch_id="mixtral-8x7b",
    family="moe",
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    d_ff=0,
    vocab=32000,
    mlp="swiglu",
    rope_theta=1e6,
    sliding_window=4096,
    n_experts=8,
    top_k=2,
    d_ff_expert=14336,
)
