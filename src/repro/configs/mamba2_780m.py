"""mamba2-780m [ssm] — 48L d_model=1536, attention-free SSD
(state-space duality), ssm_state=128, vocab=50280, depthwise causal
conv k=4 (the paper's stencil technique fused via
kernels/conv1d_depthwise.py). [arXiv:2405.21060; unverified]"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    arch_id="mamba2-780m",
    family="ssm",
    n_layers=48,
    d_model=1536,
    n_heads=0,
    n_kv_heads=0,
    d_ff=0,
    vocab=50280,
    ssm_state=128,
    ssm_head_dim=64,
    ssm_expand=2,
    ssm_conv_kernel=4,
    ssm_chunk=256,
    ssm_n_groups=1,
    uses_stencil_kernel=True,
)
