"""Registry: arch lookup, input-shape grid, cell applicability, reduced
smoke-test configs, and the per-family model API dispatch."""
from __future__ import annotations

import dataclasses
import importlib
from typing import Any, Callable, NamedTuple

from repro.models.config import ModelConfig

ARCH_MODULES = {
    "qwen2.5-3b": "qwen2_5_3b",
    "qwen2.5-14b": "qwen2_5_14b",
    "gemma-2b": "gemma_2b",
    "llama3-8b": "llama3_8b",
    "mixtral-8x7b": "mixtral_8x7b",
    "qwen3-moe-30b-a3b": "qwen3_moe_30b_a3b",
    "qwen2-vl-7b": "qwen2_vl_7b",
    "recurrentgemma-9b": "recurrentgemma_9b",
    "whisper-small": "whisper_small",
    "mamba2-780m": "mamba2_780m",
}

ARCH_IDS = tuple(ARCH_MODULES)


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"


SHAPES = {
    "train_4k": ShapeSpec("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeSpec("long_500k", 524288, 1, "decode"),
}

# Archs with sub-quadratic sequence mixing — the only ones that run
# long_500k (everything else would need a mechanism the model doesn't
# define; skip is per the assignment and noted in DESIGN.md §4).
# mixtral qualifies through its sliding window: ring-buffer decode is
# O(window), independent of context length.
SUBQUADRATIC = ("mamba2-780m", "recurrentgemma-9b", "mixtral-8x7b")


def get_config(arch_id: str) -> ModelConfig:
    mod = importlib.import_module(f"repro.configs.{ARCH_MODULES[arch_id]}")
    return mod.CONFIG


def uses_fsdp(arch_id: str) -> bool:
    mod = importlib.import_module(f"repro.configs.{ARCH_MODULES[arch_id]}")
    return getattr(mod, "FSDP", False)


def cell_status(arch_id: str, shape_name: str) -> str:
    """'run' or a documented skip reason for the 40-cell matrix."""
    cfg = get_config(arch_id)
    shape = SHAPES[shape_name]
    if cfg.is_encdec:
        if shape.kind == "decode":
            return "skip: enc-dec short-form (max target 448) has no long decode"
        return "run"  # seq adapted to encoder contract, see input_specs
    if shape_name == "long_500k" and arch_id not in SUBQUADRATIC:
        return "skip: full quadratic attention at 524k — no sub-quadratic mechanism"
    return "run"


def all_cells() -> list[tuple[str, str, str]]:
    return [
        (a, s, cell_status(a, s)) for a in ARCH_IDS for s in SHAPES
    ]


# --- reduced configs for CPU smoke tests -------------------------------------


def reduced_config(cfg: ModelConfig) -> ModelConfig:
    """Same family/topology, toy sizes: a few layers, narrow width, tiny
    vocab — runs a real forward/train step on CPU in seconds."""
    kw: dict[str, Any] = dict(
        n_layers=min(cfg.n_layers, 4),
        d_model=64,
        vocab=512,
        dtype="float32",
        remat="none",
    )
    if cfg.n_heads:
        ratio = max(1, cfg.n_heads // max(cfg.n_kv_heads, 1))
        kw["n_heads"] = min(cfg.n_heads, 4)
        kw["n_kv_heads"] = max(1, kw["n_heads"] // ratio)
        kw["head_dim"] = 16
    if cfg.d_ff:
        kw["d_ff"] = 128
    if cfg.family == "moe":
        kw["n_experts"] = min(cfg.n_experts, 8)
        kw["top_k"] = min(cfg.top_k, 2)
        kw["d_ff_expert"] = 96
    if cfg.family == "ssm":
        kw["ssm_state"] = 16
        kw["ssm_head_dim"] = 8
        kw["ssm_chunk"] = 16
    if cfg.hybrid_pattern:
        kw["n_layers"] = 5  # 1 super-block (rec,rec,att) + 2 tail rec
        kw["lru_width"] = 64
        kw["local_window"] = 16
    if cfg.is_encdec:
        kw["n_encoder_layers"] = 2
        kw["n_layers"] = 2
        kw["encoder_seq"] = 32
        kw["max_target_len"] = 24
    if cfg.sliding_window:
        kw["sliding_window"] = 16
    if cfg.n_patches:
        kw["n_patches"] = 8
    if cfg.rope_style == "mrope":
        kw["mrope_sections"] = (2, 3, 3)  # sums to head_dim // 2 = 8
    return dataclasses.replace(cfg, **kw)


# --- model API dispatch -------------------------------------------------------


class ModelAPI(NamedTuple):
    init_params: Callable
    lm_loss: Callable
    forward: Callable
    init_decode_cache: Callable
    decode_step: Callable


def get_model(cfg: ModelConfig) -> ModelAPI:
    if cfg.family in ("dense", "moe", "vlm"):
        from repro.models import transformer as m

        return ModelAPI(
            m.init_params, m.lm_loss, m.forward,
            m.init_decode_cache, m.decode_step,
        )
    if cfg.family == "ssm":
        from repro.models import ssm as m

        return ModelAPI(
            m.init_params, m.lm_loss, m.forward,
            m.init_decode_cache, m.decode_step,
        )
    if cfg.family == "hybrid":
        from repro.models import hybrid as m

        return ModelAPI(
            m.init_params, m.lm_loss, m.forward,
            m.init_decode_cache, m.decode_step,
        )
    if cfg.family == "audio":
        from repro.models import encdec as m

        return ModelAPI(
            m.init_params, m.lm_loss, m.forward,
            m.init_decode_cache, m.decode_step,
        )
    raise ValueError(cfg.family)
