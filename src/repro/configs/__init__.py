"""Architecture configs: one module per assigned arch (exact public
configs) + the paper's own stencil cases. See registry.py for lookup."""
