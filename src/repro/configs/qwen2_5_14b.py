"""qwen2.5-14b [dense] — 48L d_model=5120 40H (GQA kv=8) d_ff=13824
vocab=152064, QKV bias. FSDP enabled: replicated f32 master params +
Adam states would exceed per-chip HBM. [hf:Qwen/Qwen2.5-14B; hf]"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    arch_id="qwen2.5-14b",
    family="dense",
    n_layers=48,
    d_model=5120,
    n_heads=40,
    n_kv_heads=8,
    d_ff=13824,
    vocab=152064,
    qkv_bias=True,
    mlp="swiglu",
    rope_theta=1e6,
)

FSDP = True
