"""recurrentgemma-9b [hybrid] — 38L d_model=4096 16H (MQA kv=1)
d_ff=12288 vocab=256000, RG-LRU + local attention 1:2 (every third
block is window-2048 attention), temporal conv k=4 (stencil — the
paper-technique integration point). [arXiv:2402.19427; unverified]"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    arch_id="recurrentgemma-9b",
    family="hybrid",
    n_layers=38,
    d_model=4096,
    n_heads=16,
    n_kv_heads=1,
    head_dim=256,
    d_ff=12288,
    vocab=256000,
    mlp="geglu",
    rope_theta=1e4,
    hybrid_pattern=3,
    lru_width=4096,
    local_window=2048,
    ssm_conv_kernel=4,
    uses_stencil_kernel=True,
)
