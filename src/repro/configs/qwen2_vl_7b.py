"""qwen2-vl-7b [vlm] — 28L d_model=3584 28H (GQA kv=4) d_ff=18944
vocab=152064, M-RoPE; dynamic-resolution vision frontend STUBBED —
input_specs provides patch embeddings + (3, b, s) M-RoPE position
streams. [arXiv:2409.12191; hf]"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    arch_id="qwen2-vl-7b",
    family="vlm",
    n_layers=28,
    d_model=3584,
    n_heads=28,
    n_kv_heads=4,
    d_ff=18944,
    vocab=152064,
    qkv_bias=True,
    mlp="swiglu",
    rope_style="mrope",
    mrope_sections=(16, 24, 24),
    rope_theta=1e6,
    n_patches=256,
)
