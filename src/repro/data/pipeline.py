"""Deterministic, host-sharded data pipeline.

Design mirrors a production loader: the *global* batch for step ``s`` is a
pure function of ``(seed, s)`` — any host can materialize exactly its
slice (``host_index / host_count``), so restarts and elastic rescales
resume bit-identically mid-stream with NO data-state checkpointing: the
data cursor is just the step counter. That property is what makes the
fault-tolerance story (ft/supervisor.py) exact rather than approximate.

Two LM datasets:
* ``SyntheticLMDataset`` — uniform tokens (throughput benchmarking).
* ``MarkovLMDataset``    — order-1 Markov chain with a sparse transition
  structure; a model CAN learn it, so example trainings show a real,
  reproducible loss drop toward the chain's entropy rate.
"""
from __future__ import annotations

import dataclasses

import jax
import numpy as np


@dataclasses.dataclass(frozen=True)
class SyntheticLMDataset:
    vocab: int
    seq_len: int
    seed: int = 0

    def batch(self, step: int, start: int, count: int) -> dict[str, np.ndarray]:
        """Rows [start, start+count) of step ``step``'s global batch.

        Seeded PER ROW, so any host slicing of the global batch yields
        identical rows (the elastic-resharding invariant)."""
        tok = np.stack([
            np.random.default_rng(
                np.random.SeedSequence([self.seed, step, start + i])
            ).integers(0, self.vocab, size=self.seq_len + 1, dtype=np.int32)
            for i in range(count)
        ])
        return {"tokens": tok[:, :-1], "labels": tok[:, 1:]}


@dataclasses.dataclass(frozen=True)
class MarkovLMDataset:
    """Order-1 Markov chain over the vocab: each state transitions to one
    of ``branching`` successors (structure drawn once from ``seed``)."""

    vocab: int
    seq_len: int
    branching: int = 4
    seed: int = 0

    def _table(self) -> np.ndarray:
        rng = np.random.default_rng(self.seed)
        return rng.integers(
            0, self.vocab, size=(self.vocab, self.branching), dtype=np.int32
        )

    @property
    def entropy_rate(self) -> float:
        return float(np.log(self.branching))

    def batch(self, step: int, start: int, count: int) -> dict[str, np.ndarray]:
        table = self._table()
        tok = np.empty((count, self.seq_len + 1), dtype=np.int32)
        choices = np.empty((count, self.seq_len), dtype=np.int64)
        for i in range(count):  # per-row seeding: host-slicing invariant
            rng = np.random.default_rng(
                np.random.SeedSequence([self.seed + 1, step, start + i])
            )
            tok[i, 0] = rng.integers(0, self.vocab)
            choices[i] = rng.integers(0, self.branching, size=self.seq_len)
        for t in range(self.seq_len):
            tok[:, t + 1] = table[tok[:, t], choices[:, t]]
        return {"tokens": tok[:, :-1], "labels": tok[:, 1:]}


class BatchIterator:
    """Host-sharded iterator over global batches.

    ``global_batch`` rows per step; this host materializes rows
    ``[host_index·per_host, (host_index+1)·per_host)`` and (optionally)
    wraps them into a globally-sharded jax.Array for pjit consumption.
    """

    def __init__(
        self,
        dataset,
        global_batch: int,
        *,
        host_index: int | None = None,
        host_count: int | None = None,
        start_step: int = 0,
    ):
        self.dataset = dataset
        self.global_batch = global_batch
        self.host_index = (
            jax.process_index() if host_index is None else host_index
        )
        self.host_count = (
            jax.process_count() if host_count is None else host_count
        )
        if global_batch % self.host_count:
            raise ValueError("global_batch must divide by host count")
        self.per_host = global_batch // self.host_count
        self.step = start_step

    def next_local(self) -> dict[str, np.ndarray]:
        b = self.dataset.batch(
            self.step, self.host_index * self.per_host, self.per_host
        )
        self.step += 1
        return b

    def next_global(self, mesh, spec) -> dict[str, jax.Array]:
        """Assemble the global sharded batch from the local slice."""
        from jax.sharding import NamedSharding

        local = self.next_local()
        out = {}
        for k, v in local.items():
            sharding = NamedSharding(mesh, spec)
            out[k] = jax.make_array_from_process_local_data(sharding, v)
        return out


def make_physics_init(shape, n_fields: int, amplitude: float, seed: int = 0):
    """Paper Table B2 benchmark initialization for physics domains."""
    rng = np.random.default_rng(seed)
    return rng.uniform(
        -amplitude, amplitude, size=(n_fields,) + tuple(shape)
    ).astype(np.float32)
