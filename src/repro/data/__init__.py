"""Data substrate: deterministic synthetic datasets + host-sharded batch
iterator (the stand-in for a production tokenized-shard loader)."""
from repro.data.pipeline import (  # noqa: F401
    BatchIterator,
    MarkovLMDataset,
    SyntheticLMDataset,
    make_physics_init,
)
