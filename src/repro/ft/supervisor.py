"""Fault-tolerant training supervision.

At thousand-node scale the failure model is: some host dies mid-step →
the job restarts (possibly on a different node count) → training must
resume from the last durable step with bit-identical data order. The
pieces here implement that contract in-process:

* ``Supervisor.run`` drives the step loop, checkpoints every
  ``ckpt_every`` steps, and on failure restores the last checkpoint and
  REPLAYS from its step — with the deterministic data pipeline
  (data/pipeline.py) the recovery is exact.
* ``SimulatedFailure`` + ``failure_at`` inject crashes for tests/examples
  (the CPU stand-in for a node loss). ``Supervisor.recoverable`` widens
  the checkpoint-restore trigger to real runtime errors (device loss,
  flaky filesystem) — ``SimulatedFailure`` is only the default.
* ``StragglerMonitor`` tracks per-step wall times; a step slower than
  ``factor ×`` the trailing median flags a straggler. On a real cluster
  the hook triggers re-layout / hot-spare swap (we log and count; the
  decision callback is pluggable).
* elastic restarts: pass a different ``restore_shardings`` after changing
  the mesh — checkpoints store unsharded leaves, so a 2-pod job can
  resume on 1 pod (degraded) and scale back later.
"""
from __future__ import annotations

import dataclasses
import logging
import time
from typing import Any, Callable

log = logging.getLogger("repro.ft")


class SimulatedFailure(RuntimeError):
    """Injected node failure (tests/examples)."""


@dataclasses.dataclass
class StragglerMonitor:
    factor: float = 1.5
    window: int = 20
    on_straggler: Callable[[int, float, float], None] | None = None
    _times: list = dataclasses.field(default_factory=list)
    flagged: list = dataclasses.field(default_factory=list)

    def record(self, step: int, seconds: float) -> bool:
        self._times.append(seconds)
        hist = self._times[-self.window - 1 : -1]
        if len(hist) >= 5:
            med = sorted(hist)[len(hist) // 2]
            if seconds > self.factor * med:
                self.flagged.append((step, seconds, med))
                log.warning(
                    "straggler at step %d: %.3fs vs median %.3fs",
                    step, seconds, med,
                )
                if self.on_straggler:
                    self.on_straggler(step, seconds, med)
                return True
        return False


@dataclasses.dataclass
class Supervisor:
    """Restart-on-failure driver around a step function.

    ``step_fn(state, step) -> state`` must be side-effect-free w.r.t.
    recovery (all persistent state in ``state`` + the step counter).
    """

    ckpt_manager: Any
    ckpt_every: int = 50
    max_restarts: int = 3
    straggler: StragglerMonitor = dataclasses.field(
        default_factory=StragglerMonitor
    )
    # Exception types that trigger checkpoint-restore instead of
    # propagating. The default keeps the historical behavior (only the
    # injected test failure); real deployments widen it, e.g.
    # ``(SimulatedFailure, jax.errors.JaxRuntimeError, OSError)`` so a
    # device loss or a flaky filesystem also restarts from the last
    # durable step. KeyboardInterrupt/SystemExit are never caught.
    recoverable: tuple[type[BaseException], ...] = (SimulatedFailure,)

    def run(
        self,
        state: Any,
        step_fn: Callable[[Any, int], Any],
        n_steps: int,
        *,
        start_step: int = 0,
        failure_at: int | None = None,
        restore_fn: Callable[[Any, int | None], tuple[Any, int]] | None = None,
        save_filter: Callable[[Any], Any] | None = None,
    ) -> tuple[Any, dict]:
        """Run to ``n_steps`` with checkpoint/restart. Returns
        (final_state, report). ``restore_fn(state_template, step)`` must
        rebuild device state from the checkpoint (elastic reshard hook).
        ``save_filter`` maps state → the checkpointable subtree."""
        restarts = 0
        step = start_step
        report = {"restarts": 0, "stragglers": 0, "failed_steps": []}
        injected = failure_at
        while step < n_steps:
            try:
                t0 = time.perf_counter()
                if injected is not None and step == injected:
                    injected = None  # fire once
                    raise SimulatedFailure(f"injected failure at step {step}")
                state = step_fn(state, step)
                dt = time.perf_counter() - t0
                if self.straggler.record(step, dt):
                    report["stragglers"] += 1
                step += 1
                if step % self.ckpt_every == 0 or step == n_steps:
                    to_save = save_filter(state) if save_filter else state
                    self.ckpt_manager.save(step, to_save)
            except self.recoverable as e:
                restarts += 1
                report["restarts"] = restarts
                report["failed_steps"].append(step)
                log.warning("failure at step %d: %s", step, e)
                if restarts > self.max_restarts:
                    raise RuntimeError(
                        f"exceeded max_restarts={self.max_restarts}"
                    ) from e
                if restore_fn is None:
                    raise
                self.ckpt_manager.wait()
                last = self.ckpt_manager.latest_step()
                state, step = restore_fn(state, last)
                log.warning("restored at step %d, resuming", step)
        self.ckpt_manager.wait()
        return state, report
