"""Fault-tolerance substrate: supervised training loop with
checkpoint/restart, failure injection, and straggler monitoring."""
from repro.ft.supervisor import (  # noqa: F401
    SimulatedFailure,
    StragglerMonitor,
    Supervisor,
)
