"""Fault-tolerance substrate: supervised training loop with
checkpoint/restart, straggler monitoring, and the seeded deterministic
fault-injection layer the serving/tuning failure domains are tested
against (``repro.ft.faults``)."""
from repro.ft.faults import (  # noqa: F401
    FaultInjector,
    FaultSpec,
    InjectedCompileFailure,
    InjectedFault,
    InjectedResourceExhausted,
    chaos_specs,
)
from repro.ft.supervisor import (  # noqa: F401
    SimulatedFailure,
    StragglerMonitor,
    Supervisor,
)
