"""Seeded, deterministic fault injection — the chaos layer under the
serving and tuning failure domains.

Production failures the serving/tuning paths must survive (ISSUE 8):
a candidate kernel that fails to compile, a block that exhausts VMEM,
a member whose field blows up to NaN/inf, a batch that stalls, and a
``cache.json`` truncated or garbled by a crashed writer. This module
makes every one of them *injectable, targeted, and deterministic*, so
the recovery machinery (retry/backoff, the strategy degradation
ladder, batch bisection + quarantine, cache quarantine) is tested
against the exact failure it claims to handle.

Design:

* A :class:`FaultSpec` names a **site** (where in the pipeline the
  fault fires), a **kind** (what happens), selectors (which request /
  batch / strategy / candidate it targets), and a ``times`` budget
  (``1`` = transient, ``0`` = persistent). No randomness lives here —
  a spec either matches a context or it doesn't.
* A :class:`FaultInjector` holds the specs, consumes their budgets,
  and logs every firing in :attr:`FaultInjector.fired` so tests and
  the chaos smoke can assert exactly which faults happened.
* :func:`chaos_specs` derives a standard chaos plan (one NaN-poisoned
  request, one transient compile failure, one slow batch, one failing
  tuning candidate, one corrupted cache file) from a single seed via
  ``random.Random(seed)`` — same seed, same plan, every run.

Sites and kinds:

=================  =========================  ==============================
site               kinds                      fires in
=================  =========================  ==============================
``serve.batch``    compile | oom | slow       ``SimServer`` batch execution
``serve.output``   nan | inf                  post-integrate member output
``tune.candidate`` compile | oom              ``TuningSession`` measure loop
``cache.file``     truncate | garbage         on-disk ``cache.json``
=================  =========================  ==============================

The serving side receives the injector explicitly
(``SimServer(faults=...)``); the tuning side consults the module-level
active injector (:func:`activate` / the :func:`active` context
manager), because ``block="auto"`` call sites are reached deep inside
the session machinery where threading a parameter through would couple
every resolver to the chaos layer.
"""
from __future__ import annotations

import contextlib
import dataclasses
import logging
import random
import time
from pathlib import Path
from typing import Iterable, Sequence

log = logging.getLogger("repro.ft.faults")

SITES = {
    "serve.batch": ("compile", "oom", "slow"),
    "serve.output": ("nan", "inf"),
    "tune.candidate": ("compile", "oom"),
    "cache.file": ("truncate", "garbage"),
}


class InjectedFault(RuntimeError):
    """Base class for every injected failure (never raised by real
    hardware paths — catching it is always safe in tests)."""

    def __init__(self, site: str, detail: str = ""):
        self.site = site
        self.detail = detail
        super().__init__(f"injected fault at {site}: {detail}")


class InjectedCompileFailure(InjectedFault):
    """Stand-in for a Mosaic/Pallas lowering or compile error."""


class InjectedResourceExhausted(InjectedFault):
    """Stand-in for RESOURCE_EXHAUSTED (VMEM-oversized candidate)."""


_RAISING = {
    "compile": InjectedCompileFailure,
    "oom": InjectedResourceExhausted,
}


@dataclasses.dataclass
class FaultSpec:
    """One injectable fault: site + kind + selectors + firing budget.

    Selectors are conjunctive — a ``None`` selector matches anything,
    so ``FaultSpec("serve.batch", "compile", req_id=3)`` fires on every
    batch containing request 3 (any index, any strategy), while adding
    ``strategy="swc"`` restricts it to ``swc`` launches (the
    degradation-ladder trigger shape).

    ``times`` bounds how often the spec fires: ``1`` models a transient
    (a retry succeeds), ``0`` a persistent fault (every matching
    context fires — the poison-request shape).
    """

    site: str
    kind: str
    req_id: int | None = None  # fires when this request is in the batch
    index: int | None = None  # fires on this batch index
    strategy: str | None = None  # fires only under this strategy
    label: str | None = None  # candidate-label substring ("*" = any)
    times: int = 1  # firing budget; 0 = unlimited
    fired: int = 0  # consumed budget (mutated by the injector)

    def __post_init__(self):
        if self.site not in SITES:
            raise ValueError(f"unknown fault site {self.site!r}")
        if self.kind not in SITES[self.site]:
            raise ValueError(
                f"fault kind {self.kind!r} invalid for site {self.site!r}"
                f" (expected one of {SITES[self.site]})"
            )

    def exhausted(self) -> bool:
        return self.times > 0 and self.fired >= self.times

    def matches(
        self,
        *,
        req_ids: Sequence[int] = (),
        index: int | None = None,
        strategy: str | None = None,
        label: str | None = None,
    ) -> bool:
        if self.req_id is not None and self.req_id not in req_ids:
            return False
        if self.index is not None and self.index != index:
            return False
        if self.strategy is not None and self.strategy != strategy:
            return False
        if self.label is not None and self.label != "*":
            if label is None or self.label not in label:
                return False
        return True


class FaultInjector:
    """Deterministic fault scheduler over a list of :class:`FaultSpec`.

    The injector is pure bookkeeping: it never decides randomly whether
    to fire (determinism comes from the specs; seeding happens once, in
    :func:`chaos_specs`). Every firing is appended to :attr:`fired` as
    ``(site, kind, detail)`` so callers can assert the exact fault
    sequence after the fact.
    """

    def __init__(
        self, specs: Iterable[FaultSpec] = (), *, slow_s: float = 0.25
    ):
        self.specs = list(specs)
        self.slow_s = slow_s  # injected stall for "slow" batch faults
        self.fired: list[tuple[str, str, str]] = []

    def _take(self, site: str, detail: str, **ctx) -> FaultSpec | None:
        """First non-exhausted spec matching ``ctx`` at ``site`` —
        consumes one unit of its budget and logs the firing."""
        for spec in self.specs:
            if spec.site != site or spec.exhausted():
                continue
            if not spec.matches(**ctx):
                continue
            spec.fired += 1
            self.fired.append((site, spec.kind, detail))
            log.warning("injected %s fault at %s (%s)", spec.kind, site,
                        detail)
            return spec
        return None

    # -- serving hooks ------------------------------------------------------

    def on_batch(self, index: int, req_ids: Sequence[int], strategy: str):
        """Fires inside a batch execution: raise (compile/oom) or stall
        (slow). Called by ``SimServer`` in the per-batch try block."""
        spec = self._take(
            "serve.batch",
            f"index={index} reqs={list(req_ids)} strategy={strategy}",
            req_ids=req_ids, index=index, strategy=strategy,
        )
        if spec is None:
            return
        if spec.kind == "slow":
            time.sleep(self.slow_s)
            return
        raise _RAISING[spec.kind](
            "serve.batch", f"batch {index} under {strategy}"
        )

    def corrupt_output(self, req_ids: Sequence[int], out):
        """Poison matching members of a (B, ...) output stack with
        NaN/inf — the injected analogue of a member whose field blew
        up inside the kernel. Returns ``out`` (copied when modified)."""
        import numpy as np

        poisoned = out
        for member, rid in enumerate(req_ids):
            spec = self._take(
                "serve.output", f"req={rid}", req_ids=(rid,)
            )
            if spec is None:
                continue
            if poisoned is out:
                poisoned = np.array(out)  # writable copy
            poisoned[member] = (
                np.nan if spec.kind == "nan" else np.inf
            )
        return poisoned

    # -- tuning hooks -------------------------------------------------------

    def on_candidate(self, label: str):
        """Fires inside the per-candidate measurement: raise a compile
        or resource-exhausted failure for a matching candidate label."""
        spec = self._take(
            "tune.candidate", f"candidate={label}", label=label
        )
        if spec is not None:
            raise _RAISING[spec.kind](
                "tune.candidate", f"candidate {label}"
            )

    # -- cache hooks --------------------------------------------------------

    def corrupt_cache(self, path) -> bool:
        """Corrupt an on-disk cache file in place (truncate to half, or
        overwrite with non-JSON garbage). Returns True if a fault
        fired. The file is created if missing — a garbage file where a
        cache is expected is exactly the crash-mid-write shape."""
        path = Path(path)
        spec = self._take("cache.file", f"path={path}")
        if spec is None:
            return False
        if spec.kind == "truncate":
            data = path.read_bytes() if path.exists() else b'{"records'
            path.write_bytes(data[: max(1, len(data) // 2)])
        else:
            path.parent.mkdir(parents=True, exist_ok=True)
            path.write_text("{garbage: definitely, not json\x00")
        return True


# ---------------------------------------------------------------------------
# Module-level active injector — the tuning session's consultation point.
# ---------------------------------------------------------------------------

_ACTIVE: FaultInjector | None = None


def activate(injector: FaultInjector | None) -> None:
    """Install ``injector`` as the process-wide active injector (the
    one deep tuning call sites consult); ``None`` deactivates."""
    global _ACTIVE
    _ACTIVE = injector


def get_active() -> FaultInjector | None:
    return _ACTIVE


@contextlib.contextmanager
def active(injector: FaultInjector):
    """Scope ``injector`` as the active one (always deactivated on
    exit, even when the body raises)."""
    activate(injector)
    try:
        yield injector
    finally:
        activate(None)


def maybe_fail_candidate(label: str) -> None:
    """Tuning-session seam: raise the active injector's fault for this
    candidate label, or do nothing when no injector is active (the
    production fast path — one None check)."""
    if _ACTIVE is not None:
        _ACTIVE.on_candidate(label)


# ---------------------------------------------------------------------------
# The standard seeded chaos plan.
# ---------------------------------------------------------------------------


def chaos_specs(
    seed: int, req_ids: Sequence[int]
) -> tuple[list[FaultSpec], dict]:
    """The chaos-smoke fault plan, derived deterministically from
    ``seed``: one persistent NaN-poisoned request, one transient
    compile failure (its batch recovers on retry), one slow batch, one
    failing tuning candidate, and one garbled ``cache.json``.

    Returns ``(specs, plan)`` where ``plan`` names the chosen targets
    so the caller can assert exact quarantine/retry attribution.
    """
    ids = sorted(int(r) for r in req_ids)
    if not ids:
        raise ValueError("chaos_specs needs at least one request id")
    rng = random.Random(seed)
    poison = ids[rng.randrange(len(ids))]
    others = [r for r in ids if r != poison] or [poison]
    transient = others[rng.randrange(len(others))]
    slow_index = rng.randrange(2, 5)
    specs = [
        FaultSpec("serve.output", "nan", req_id=poison, times=0),
        FaultSpec("serve.batch", "compile", req_id=transient, times=1),
        FaultSpec("serve.batch", "slow", index=slow_index, times=1),
        FaultSpec("tune.candidate", "compile", label="*", times=1),
        FaultSpec("cache.file", "garbage", times=1),
    ]
    plan = {
        "seed": seed,
        "poison": poison,
        "transient": transient,
        "slow_index": slow_index,
    }
    return specs, plan
